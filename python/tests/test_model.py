"""L2 model correctness: ista_epoch descends the objective and converges;
screen_gap reproduces the duality-gap math and produces *safe* masks
(cross-checked against a high-accuracy unscreened solve).

The model functions are jitted once per shape here — interpret-mode Pallas
retraces on every eager call otherwise, which is prohibitively slow.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def make_problem(seed=0, n=20, g=5, d=4, tau=0.3, noise=0.01):
    rng = np.random.default_rng(seed)
    p = g * d
    x = rng.normal(size=(n, p))
    beta_true = np.zeros(p)
    beta_true[0] = 2.0
    beta_true[d] = -1.5
    y = x @ beta_true + noise * rng.normal(size=n)
    w = np.sqrt(np.full(g, float(d)))
    xj = np.linalg.norm(x, axis=0)
    xg = np.array(
        [np.linalg.svd(x[:, i * d : (i + 1) * d], compute_uv=False)[0] for i in range(g)]
    )
    inv_l = 1.0 / np.linalg.svd(x, compute_uv=False)[0] ** 2
    lam_max = float(
        ref.omega_dual(jnp.asarray((x.T @ y).reshape(g, d)), tau, jnp.asarray(w))
    )
    ista = jax.jit(functools.partial(model.ista_epoch, n_inner=10))
    screen = jax.jit(model.screen_gap)
    return dict(
        x=jnp.asarray(x), y=jnp.asarray(y), w=jnp.asarray(w),
        xj=jnp.asarray(xj), xg=jnp.asarray(xg), inv_l=jnp.asarray(inv_l),
        lam_max=lam_max, tau=jnp.asarray(tau), n=n, p=p, g=g, d=d,
        ista=ista, screen=screen,
    )


def objective(pb, beta, lam):
    rho = pb["y"] - pb["x"] @ beta
    return float(
        0.5 * jnp.sum(rho * rho)
        + lam * ref.omega(beta.reshape(pb["g"], pb["d"]), pb["tau"], pb["w"])
    )


def run_epoch(pb, beta, mask, lam):
    (out,) = pb["ista"](
        pb["x"], pb["y"], beta, mask, pb["w"], lam, pb["tau"], pb["inv_l"]
    )
    return out


def run_screen(pb, beta, mask, gmask, lam):
    return pb["screen"](
        pb["x"], pb["y"], beta, mask, gmask, pb["w"], pb["xj"], pb["xg"], lam, pb["tau"]
    )


def test_ista_epoch_descends():
    pb = make_problem()
    lam = 0.3 * pb["lam_max"]
    beta = jnp.zeros(pb["p"])
    mask = jnp.ones(pb["p"])
    prev = objective(pb, beta, lam)
    for _ in range(5):
        beta = run_epoch(pb, beta, mask, lam)
        cur = objective(pb, beta, lam)
        assert cur <= prev + 1e-12
        prev = cur


def test_ista_converges_and_gap_vanishes():
    pb = make_problem(seed=3)
    lam = 0.25 * pb["lam_max"]
    beta = jnp.zeros(pb["p"])
    mask = jnp.ones(pb["p"])
    gmask = jnp.ones(pb["g"])
    gap = None
    for _ in range(300):
        beta = run_epoch(pb, beta, mask, lam)
        gap, _, mask, gmask = run_screen(pb, beta, mask, gmask, lam)
        if float(gap) < 1e-10:
            break
    assert float(gap) < 1e-10, float(gap)


def test_screen_gap_matches_manual_math():
    pb = make_problem(seed=5)
    lam = 0.4 * pb["lam_max"]
    rng = np.random.default_rng(11)
    beta = jnp.asarray(rng.normal(size=pb["p"]) * 0.05)
    gap, radius, _, _ = run_screen(
        pb, beta, jnp.ones(pb["p"]), jnp.ones(pb["g"]), lam
    )
    rho = pb["y"] - pb["x"] @ beta
    xt = pb["x"].T @ rho
    dn = float(ref.omega_dual(xt.reshape(pb["g"], pb["d"]), pb["tau"], pb["w"]))
    s = max(lam, dn)
    primal = float(
        0.5 * jnp.sum(rho * rho)
        + lam * ref.omega(beta.reshape(pb["g"], pb["d"]), pb["tau"], pb["w"])
    )
    diff = rho / s - pb["y"] / lam
    dual = float(0.5 * jnp.sum(pb["y"] ** 2) - 0.5 * lam * lam * jnp.sum(diff * diff))
    np.testing.assert_allclose(float(gap), max(primal - dual, 0.0), rtol=1e-10)
    np.testing.assert_allclose(
        float(radius), np.sqrt(2 * max(primal - dual, 0.0)) / lam, rtol=1e-10
    )


def test_screening_is_safe():
    """Masks produced along the solve never kill a truly-active feature."""
    pb = make_problem(seed=7, noise=0.05)
    lam = 0.35 * pb["lam_max"]
    # High-accuracy reference solve without screening.
    beta_ref = jnp.zeros(pb["p"])
    ones = jnp.ones(pb["p"])
    for _ in range(500):
        beta_ref = run_epoch(pb, beta_ref, ones, lam)
    support_ref = np.abs(np.asarray(beta_ref)) > 1e-9

    beta = jnp.zeros(pb["p"])
    mask = jnp.ones(pb["p"])
    gmask = jnp.ones(pb["g"])
    for _ in range(40):
        gap, _, mask, gmask = run_screen(pb, beta, mask, gmask, lam)
        killed = np.asarray(mask) == 0.0
        assert not np.any(killed & support_ref), "screened an active feature!"
        beta = run_epoch(pb, beta, mask, lam)
        if float(gap) < 1e-12:
            break


def test_masks_are_monotone_and_masked_beta_stays_zero():
    pb = make_problem(seed=9)
    lam = 0.5 * pb["lam_max"]
    beta = jnp.zeros(pb["p"])
    mask = jnp.ones(pb["p"])
    gmask = jnp.ones(pb["g"])
    prev_active = pb["p"]
    for _ in range(15):
        gap, _, mask, gmask = run_screen(pb, beta, mask, gmask, lam)
        active = int(np.sum(np.asarray(mask)))
        assert active <= prev_active
        prev_active = active
        beta = run_epoch(pb, beta, mask, lam)
        assert np.all(np.asarray(beta)[np.asarray(mask) == 0.0] == 0.0)
        if float(gap) < 1e-12:
            break


def test_lambda_above_max_converges_to_zero():
    pb = make_problem(seed=13)
    lam = 1.2 * pb["lam_max"]
    beta = jnp.zeros(pb["p"])
    gap, radius, mask, gmask = run_screen(
        pb, beta, jnp.ones(pb["p"]), jnp.ones(pb["g"]), lam
    )
    assert float(gap) < 1e-10
    beta = run_epoch(pb, beta, mask, lam)
    assert np.all(np.asarray(beta) == 0.0)


def test_primal_dual_artifact_consistent():
    pb = make_problem(seed=15)
    lam = 0.3 * pb["lam_max"]
    rng = np.random.default_rng(2)
    beta = jnp.asarray(rng.normal(size=pb["p"]) * 0.02)
    p_v, d_v, gap = jax.jit(model.primal_dual)(
        pb["x"], pb["y"], beta, pb["w"], lam, pb["tau"]
    )
    assert float(gap) >= 0.0
    np.testing.assert_allclose(float(gap), float(p_v) - float(d_v), rtol=1e-12)
