"""Property tests of the pure-jnp reference layer (norm axioms, paper
lemmas) — these guard the oracles every kernel is checked against."""

import pytest

pytest.importorskip("hypothesis")  # offline images may lack it; skip, never fail

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref

SET = settings(deadline=None, max_examples=30, derandomize=True)


def arr(seed, *shape, scale=2.0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape) * scale)


# ------------------------------------------------------------ norm axioms
@given(
    g=st.integers(1, 6),
    d=st.integers(1, 10),
    eps=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_epsilon_norm_triangle_inequality(g, d, eps, seed):
    x = arr(seed, g, d)
    y = arr(seed + 1, g, d)
    nx = np.asarray(ref.epsilon_norm_rows(x, eps))
    ny = np.asarray(ref.epsilon_norm_rows(y, eps))
    nxy = np.asarray(ref.epsilon_norm_rows(x + y, eps))
    assert np.all(nxy <= nx + ny + 1e-9 * (1 + nx + ny))


@given(
    g=st.integers(1, 6),
    d=st.integers(1, 10),
    eps=st.floats(0.0, 1.0),
    c=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_epsilon_norm_homogeneity(g, d, eps, c, seed):
    x = arr(seed, g, d)
    nx = np.asarray(ref.epsilon_norm_rows(x, eps))
    ncx = np.asarray(ref.epsilon_norm_rows(c * x, eps))
    np.testing.assert_allclose(ncx, c * nx, rtol=1e-8, atol=1e-12)


@given(
    g=st.integers(1, 5),
    d=st.integers(1, 8),
    tau=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_omega_duality_inequality(g, d, tau, seed):
    """|<beta, xi>| <= Omega(beta) * Omega^D(xi)."""
    beta = arr(seed, g, d)
    xi = arr(seed + 7, g, d)
    w = jnp.asarray(np.sqrt(np.full(g, float(d))))
    ip = float(jnp.sum(beta * xi))
    bound = float(ref.omega(beta, tau, w)) * float(ref.omega_dual(xi, tau, w))
    assert abs(ip) <= bound * (1 + 1e-9) + 1e-9


# ------------------------------------------------------------ paper lemmas
@given(
    d=st.integers(1, 12),
    eps=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_lemma1_decomposition(d, eps, seed):
    """x = x^eps + x^{1-eps}; ||x^eps|| = eps*nu; ||x^{1-eps}||_inf = (1-eps)nu."""
    x = arr(seed, 1, d)
    if float(jnp.max(jnp.abs(x))) == 0.0:
        return
    nu = float(ref.epsilon_norm_rows(x, eps)[0])
    x_eps = ref.soft_threshold(x, (1 - eps) * nu)
    x_rest = x - x_eps
    np.testing.assert_allclose(float(jnp.linalg.norm(x_eps)), eps * nu, rtol=1e-8)
    np.testing.assert_allclose(
        float(jnp.max(jnp.abs(x_rest))), (1 - eps) * nu, rtol=1e-8
    )


@given(
    g=st.integers(1, 5),
    d=st.integers(1, 8),
    tau=st.floats(0.01, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_dual_ball_characterization(g, d, tau, seed):
    """Eq. 21 <=> Eq. 20: ||S_tau(xi_g)|| <= (1-tau)w_g for all g iff
    Omega^D(xi) <= 1 (away from the boundary)."""
    xi = arr(seed, g, d, scale=0.8)
    w = jnp.asarray(np.sqrt(np.full(g, float(d))))
    dn = float(ref.omega_dual(xi, tau, w))
    if abs(dn - 1.0) < 1e-6:
        return  # knife edge
    st_norms = jnp.linalg.norm(ref.soft_threshold(xi, tau), axis=1)
    inside_21 = bool(jnp.all(st_norms <= (1 - tau) * w + 1e-12))
    assert inside_21 == (dn <= 1.0), (dn, inside_21)


@given(
    g=st.integers(1, 4),
    d=st.integers(1, 6),
    tau=st.floats(0.0, 1.0),
    a=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_prox_decreases_objective(g, d, tau, a, seed):
    """prox minimizes 0.5||b-u||^2 + a*tau*||b||_1 + a*(1-tau)w||b||:
    its objective value is <= that of u itself and of 0."""
    u = arr(seed, g, d)
    w = jnp.asarray(np.sqrt(np.full(g, float(d))))
    p = ref.sgl_prox(u, a * tau, a * (1 - tau) * w)

    def obj(b):
        return (
            0.5 * float(jnp.sum((b - u) ** 2))
            + a * tau * float(jnp.sum(jnp.abs(b)))
            + a * float(jnp.sum((1 - tau) * w * jnp.linalg.norm(b, axis=1)))
        )

    assert obj(p) <= obj(u) + 1e-9
    assert obj(p) <= obj(jnp.zeros_like(u)) + 1e-9


@given(
    g=st.integers(1, 5),
    d=st.integers(1, 8),
    tau=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
@SET
def test_screen_tests_monotone_in_radius(g, d, tau, seed):
    """Larger safe spheres can only keep MORE variables."""
    xi = arr(seed, g, d, scale=0.5)
    rng = np.random.default_rng(seed + 3)
    xjn = jnp.asarray(rng.uniform(0.1, 2.0, size=(g, d)))
    xgn = jnp.asarray(rng.uniform(0.1, 2.0, size=g))
    w = jnp.asarray(np.sqrt(np.full(g, float(d))))
    gk_small, fk_small = ref.group_screen_tests(xi, tau, 0.01, xjn, xgn, w)
    gk_big, fk_big = ref.group_screen_tests(xi, tau, 1.0, xjn, xgn, w)
    assert np.all(np.asarray(gk_big) >= np.asarray(gk_small))
    assert np.all(np.asarray(fk_big) >= np.asarray(fk_small))
