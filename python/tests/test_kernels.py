"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes and values; fixed cases probe the edges
(tau in {0, 1}, zero blocks, single-group / single-feature tiles).
"""

import pytest

pytest.importorskip("hypothesis")  # offline images may lack it; skip, never fail

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import group_screen_pallas, matvec_xt_pallas, sgl_prox_pallas
from compile.kernels import ref

hypothesis.settings.register_profile(
    "sgl", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("sgl")


def rng_arrays(seed, *shapes, scale=3.0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(size=s) * scale) for s in shapes]


# ---------------------------------------------------------------- sgl_prox
@given(
    g=st.integers(1, 24),
    d=st.integers(1, 12),
    a=st.floats(0.0, 4.0),
    bscale=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgl_prox_matches_ref(g, d, a, bscale, seed):
    (u,) = rng_arrays(seed, (g, d))
    rng = np.random.default_rng(seed + 1)
    b = jnp.asarray(rng.uniform(0.0, bscale + 1e-9, size=g))
    got = sgl_prox_pallas(u, a, b)
    want = ref.sgl_prox(u, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_sgl_prox_zero_thresholds_is_identity():
    (u,) = rng_arrays(0, (8, 5))
    got = sgl_prox_pallas(u, 0.0, jnp.zeros(8))
    np.testing.assert_allclose(got, u, rtol=0, atol=0)


def test_sgl_prox_large_group_threshold_zeroes_blocks():
    (u,) = rng_arrays(1, (4, 3))
    got = sgl_prox_pallas(u, 0.0, jnp.full(4, 1e9))
    assert np.all(np.asarray(got) == 0.0)


def test_sgl_prox_respects_block_sizes():
    (u,) = rng_arrays(2, (12, 4))
    b = jnp.abs(rng_arrays(3, (12,))[0])
    full = sgl_prox_pallas(u, 0.7, b, block_g=12)
    tiled = sgl_prox_pallas(u, 0.7, b, block_g=4)
    np.testing.assert_allclose(full, tiled, rtol=0, atol=0)


# ----------------------------------------------------------------- matvec
@given(
    n=st.integers(1, 40),
    p=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(n, p, seed):
    x, rho = rng_arrays(seed, (n, p), (n,))
    got = matvec_xt_pallas(x, rho)
    want = ref.matvec_xt(x, rho)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_matvec_blocked_equals_unblocked():
    x, rho = rng_arrays(5, (30, 48), (30,))
    a = matvec_xt_pallas(x, rho, block_p=48)
    b = matvec_xt_pallas(x, rho, block_p=8)
    np.testing.assert_allclose(a, b, rtol=1e-14, atol=1e-14)


# ------------------------------------------------------------ group_screen
@given(
    g=st.integers(1, 16),
    d=st.integers(1, 10),
    tau=st.floats(0.0, 1.0),
    radius=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_group_screen_matches_ref(g, d, tau, radius, seed):
    xi, = rng_arrays(seed, (g, d), scale=1.0)
    rng = np.random.default_rng(seed + 2)
    xjn = jnp.asarray(rng.uniform(0.1, 2.0, size=(g, d)))
    xgn = jnp.asarray(rng.uniform(0.1, 3.0, size=g))
    w = jnp.asarray(np.sqrt(np.full(g, float(d))))
    gk, fk = group_screen_pallas(xi, xjn, xgn, w, tau, radius)
    gk_ref, fk_ref = ref.group_screen_tests(xi, tau, radius, xjn, xgn, w)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(gk_ref))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fk_ref))


def test_group_screen_zero_radius_zero_center_screens_all():
    g, d = 6, 4
    xi = jnp.zeros((g, d))
    xjn = jnp.ones((g, d))
    xgn = jnp.ones(g)
    w = jnp.full(g, 2.0)
    gk, fk = group_screen_pallas(xi, xjn, xgn, w, 0.5, 0.0)
    assert np.all(np.asarray(gk) == 0.0)
    assert np.all(np.asarray(fk) == 0.0)


def test_group_screen_huge_radius_keeps_all():
    g, d = 3, 5
    xi = jnp.zeros((g, d))
    xjn = jnp.ones((g, d))
    xgn = jnp.ones(g)
    w = jnp.full(g, 2.0)
    gk, fk = group_screen_pallas(xi, xjn, xgn, w, 0.5, 100.0)
    assert np.all(np.asarray(gk) == 1.0)
    assert np.all(np.asarray(fk) == 1.0)


@pytest.mark.parametrize("tau", [0.0, 1.0])
def test_group_screen_tau_extremes(tau):
    g, d = 4, 3
    xi, = rng_arrays(7, (g, d), scale=0.5)
    xjn = jnp.ones((g, d))
    xgn = jnp.ones(g)
    w = jnp.full(g, float(np.sqrt(d)))
    gk, fk = group_screen_pallas(xi, xjn, xgn, w, tau, 0.01)
    gk_ref, fk_ref = ref.group_screen_tests(xi, tau, 0.01, xjn, xgn, w)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(gk_ref))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fk_ref))
    if tau == 0.0:
        # Feature test can never screen at tau=0.
        assert np.all(np.asarray(fk) == 1.0)
