"""Minimal offline stand-in for the `hypothesis` property-testing API.

The CI image for this repository has no package index, so the property
suites used to self-skip via ``pytest.importorskip("hypothesis")``. This
shim implements exactly the subset those suites use — ``@given`` with
keyword strategies, ``settings(deadline=..., max_examples=...,
derandomize=...)``, ``assume``, and the ``strategies.integers`` /
``strategies.floats`` constructors — by drawing deterministic pseudo-
random examples. There is no shrinking and no adaptive search; the point
is that the *properties run* offline instead of silently skipping.

``conftest.py`` only places this package on ``sys.path`` when the real
hypothesis is absent, so environments that have it keep the genuine
engine (shrinking included).
"""

import random

from . import strategies  # noqa: F401  (re-exported like the real package)

__version__ = "0.0-sgl-shim"


class _Unsatisfied(Exception):
    """Raised by assume(); the current example is discarded."""


def assume(condition):
    if not condition:
        raise _Unsatisfied()
    return True


class settings:  # noqa: N801  (match hypothesis' lowercase class)
    """Records the subset of settings the suites use; usable as a
    decorator (``@settings(...)``), a plain object, or through the
    ``register_profile`` / ``load_profile`` classmethods."""

    _profiles = {}
    _current = None

    def __init__(self, deadline=None, max_examples=100, derandomize=True, **_ignored):
        self.deadline = deadline
        self.max_examples = max_examples
        self.derandomize = derandomize

    def __call__(self, fn):
        fn._shim_settings = self
        return fn

    @classmethod
    def register_profile(cls, name, parent=None, **kwargs):
        cls._profiles[name] = cls(**kwargs)

    @classmethod
    def load_profile(cls, name):
        cls._current = cls._profiles[name]


def _stable_seed(*parts):
    """FNV-1a over the test's identity: derandomized runs are repeatable
    across processes (no PYTHONHASHSEED dependence)."""
    h = 2166136261
    for ch in ".".join(parts):
        h = ((h ^ ord(ch)) * 16777619) % (1 << 32)
    return h


def given(**strategy_kwargs):
    """Run the wrapped test once per drawn example.

    Only the keyword-argument form is supported (the form every suite in
    this repository uses). The wrapper deliberately exposes a bare
    ``(*args, **kwargs)`` signature so pytest does not mistake the drawn
    parameter names for fixtures.
    """

    for name, strat in strategy_kwargs.items():
        if not hasattr(strat, "example"):
            raise TypeError(f"@given received a non-strategy for {name!r}: {strat!r}")

    def decorate(fn):
        cfg = getattr(fn, "_shim_settings", None) or settings._current
        max_examples = cfg.max_examples if cfg is not None else 50
        derandomize = cfg.derandomize if cfg is not None else True

        def wrapper(*args, **kwargs):
            base = _stable_seed(fn.__module__, fn.__qualname__)
            if not derandomize:
                base ^= random.randrange(1 << 32)
            ran = 0
            for index in range(max_examples * 4):
                if ran >= max_examples:
                    break
                rng = random.Random((base + index) & 0xFFFFFFFF)
                drawn = {k: s.example(rng, index) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _Unsatisfied:
                    continue  # discarded by assume(); draw another example
                ran += 1

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.hypothesis_shim = True
        return wrapper

    return decorate
