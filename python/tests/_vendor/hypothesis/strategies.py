"""Strategy constructors for the offline hypothesis shim.

Each strategy is an object with ``example(rng, index)`` returning one
drawn value. The first two examples of a bounded strategy are its
endpoints — cheap boundary coverage in place of hypothesis' shrinking.
"""


class SearchStrategy:
    def example(self, rng, index=0):  # pragma: no cover - interface stub
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value, max_value):
        if min_value > max_value:
            raise ValueError("integers(): min_value > max_value")
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng, index=0):
        if index == 0:
            return self.min_value
        if index == 1:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value):
        if min_value > max_value:
            raise ValueError("floats(): min_value > max_value")
        self.min_value = float(min_value)
        self.max_value = float(max_value)

    def example(self, rng, index=0):
        if index == 0:
            return self.min_value
        if index == 1:
            return self.max_value
        return rng.uniform(self.min_value, self.max_value)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from(): empty collection")

    def example(self, rng, index=0):
        if index < len(self.elements):
            return self.elements[index]
        return rng.choice(self.elements)


class _Booleans(_SampledFrom):
    def __init__(self):
        super().__init__([False, True])


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def example(self, rng, index=0):
        size = self.min_size if index == 0 else rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng, 2) for _ in range(size)]


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def floats(min_value, max_value, **_ignored):
    return _Floats(min_value, max_value)


def booleans():
    return _Booleans()


def sampled_from(elements):
    return _SampledFrom(elements)


def lists(elements, min_size=0, max_size=10):
    return _Lists(elements, min_size=min_size, max_size=max_size)
