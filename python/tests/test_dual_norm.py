"""Vectorized Algorithm 1 (`ref.lambda_rows`) against an independent
bisection root-finder, plus the paper's closed-form identities.
"""

import pytest

pytest.importorskip("hypothesis")  # offline images may lack it; skip, never fail

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import ref


def lambda_bisect(row, alpha, r, iters=200):
    """Independent scalar oracle: bisection on phi(nu) - (nu R)^2."""
    row = np.abs(np.asarray(row, dtype=np.float64))
    if row.max() == 0.0:
        return 0.0
    if alpha == 0.0:
        return float(np.linalg.norm(row) / r) if r > 0 else float("inf")
    if r == 0.0:
        return float(row.max() / alpha)

    def f(nu):
        t = np.maximum(row - nu * alpha, 0.0)
        return float(np.sum(t * t) - (nu * r) ** 2)

    lo, hi = 0.0, float(row.max() / alpha)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if f(mid) > 0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@given(
    g=st.integers(1, 8),
    d=st.integers(1, 16),
    alpha=st.floats(0.01, 1.0),
    r=st.floats(0.01, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(deadline=None, max_examples=25, derandomize=True)
def test_lambda_rows_matches_bisection(g, d, alpha, r, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(g, d)) * 3.0
    got = np.asarray(ref.lambda_rows(jnp.asarray(x), alpha, r))
    for gi in range(g):
        want = lambda_bisect(x[gi], alpha, r)
        np.testing.assert_allclose(got[gi], want, rtol=1e-8, atol=1e-10)


def test_lambda_rows_special_cases():
    x = jnp.asarray([[3.0, -4.0, 0.0]])
    # alpha=0: ||x||/R
    np.testing.assert_allclose(ref.lambda_rows(x, 0.0, 2.0)[0], 2.5)
    # R=0: ||x||_inf/alpha
    np.testing.assert_allclose(ref.lambda_rows(x, 0.5, 0.0)[0], 8.0)
    # zero row -> 0
    np.testing.assert_allclose(ref.lambda_rows(jnp.zeros((1, 4)), 0.3, 0.7)[0], 0.0)


def test_epsilon_norm_interpolates():
    x = jnp.asarray([[1.0, -2.0, 3.0]])
    np.testing.assert_allclose(ref.epsilon_norm_rows(x, 0.0)[0], 3.0)  # inf
    np.testing.assert_allclose(
        ref.epsilon_norm_rows(x, 1.0)[0], np.sqrt(14.0)
    )  # l2
    mid = float(ref.epsilon_norm_rows(x, 0.5)[0])
    assert 3.0 < mid < 2.0 * np.sqrt(14.0)


def test_defining_equation_holds():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(12, 10)) * 2.0
    alpha, r = 0.7, 0.45
    nu = np.asarray(ref.lambda_rows(jnp.asarray(x), alpha, r))
    for gi in range(12):
        t = np.maximum(np.abs(x[gi]) - nu[gi] * alpha, 0.0)
        resid = np.sum(t * t) - (nu[gi] * r) ** 2
        assert abs(resid) < 1e-9 * max(1.0, np.sum(x[gi] ** 2)), resid


def test_omega_dual_per_group_scaling():
    """Scaling xi by Omega^D(xi) lands on the unit sphere of the dual."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(5, 7)))
    w = jnp.asarray(np.sqrt(np.full(5, 7.0)))
    tau = 0.35
    dn = float(ref.omega_dual(x, tau, w))
    assert dn > 0
    dn2 = float(ref.omega_dual(x / dn, tau, w))
    np.testing.assert_allclose(dn2, 1.0, rtol=1e-10)


def test_omega_matches_manual():
    beta = jnp.asarray([[1.0, -2.0], [0.0, 3.0]])
    w = jnp.asarray([1.5, 2.0])
    tau = 0.4
    want = 0.4 * 6.0 + 0.6 * (1.5 * np.sqrt(5.0) + 2.0 * 3.0)
    np.testing.assert_allclose(float(ref.omega(beta, tau, w)), want, rtol=1e-12)
