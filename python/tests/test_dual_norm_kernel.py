"""Pallas dual-norm kernel (`lambda_rows_pallas`) vs the pure-jnp oracle
(`ref.lambda_rows`) and the defining equation."""

import pytest

pytest.importorskip("hypothesis")  # offline images may lack it; skip, never fail

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile.kernels import lambda_rows_pallas
from compile.kernels import ref


@given(
    g=st.integers(1, 16),
    d=st.integers(1, 12),
    alpha=st.floats(0.0, 1.0),
    r=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(deadline=None, max_examples=25, derandomize=True)
def test_kernel_matches_ref(g, d, alpha, r, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(g, d)) * 2.0)
    got = np.asarray(lambda_rows_pallas(x, alpha, r))
    want = np.asarray(ref.lambda_rows(x, alpha, r))
    # rtol 1e-7: at knife edges (r -> 0 with alpha -> 1) the interpret-mode
    # kernel and the oracle order float ops differently; the root itself is
    # conditioned like sqrt near the discriminant zero.
    np.testing.assert_allclose(got, want, rtol=1e-7, atol=1e-9)


def test_kernel_defining_equation():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(10, 7)) * 3.0
    alpha, r = 0.65, 0.4
    nu = np.asarray(lambda_rows_pallas(jnp.asarray(x), alpha, r))
    for gi in range(10):
        t = np.maximum(np.abs(x[gi]) - nu[gi] * alpha, 0.0)
        resid = np.sum(t * t) - (nu[gi] * r) ** 2
        assert abs(resid) < 1e-9 * max(1.0, np.sum(x[gi] ** 2))


def test_kernel_per_group_alpha_r():
    rng = np.random.default_rng(1)
    g, d = 8, 5
    x = jnp.asarray(rng.normal(size=(g, d)))
    alpha = jnp.asarray(rng.uniform(0.1, 1.0, size=g))
    r = jnp.asarray(rng.uniform(0.1, 1.0, size=g))
    got = np.asarray(lambda_rows_pallas(x, alpha, r))
    want = np.asarray(ref.lambda_rows(x, alpha, r))
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_kernel_blocked_equals_unblocked():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(12, 6)))
    a = np.asarray(lambda_rows_pallas(x, 0.7, 0.3, block_g=12))
    b = np.asarray(lambda_rows_pallas(x, 0.7, 0.3, block_g=3))
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_kernel_zero_rows():
    x = jnp.zeros((3, 4))
    nu = np.asarray(lambda_rows_pallas(x, 0.5, 0.5))
    assert np.all(nu == 0.0)
