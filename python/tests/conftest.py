"""Shared pytest setup: put python/ on the path, enable x64."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
