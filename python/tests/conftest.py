"""Shared pytest setup: put python/ on the path, enable x64, and fall
back to the vendored hypothesis shim when the real package is absent
(offline CI image) so the property suites run instead of self-skipping."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # The shim only enters sys.path when the genuine engine is missing;
    # environments with hypothesis installed keep shrinking etc.
    sys.path.append(os.path.join(os.path.dirname(__file__), "_vendor"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
