"""AOT emission smoke tests: the lowered artifacts are valid HLO text with
the expected entry signatures, and the HLO text evaluates identically to
the eager model (via jax itself re-compiling the text is not possible, so
we check the lowered module executes through jax's own executable)."""

import jax.numpy as jnp
import numpy as np

from compile import aot


def test_smoke_artifact_text():
    text = aot.to_hlo_text(aot.lower_smoke())
    assert "ENTRY" in text
    assert "f64[4]" in text


def test_ista_epoch_lowers_and_matches_eager():
    n, p, g, d = 10, 20, 4, 5
    lowered = aot.lower_ista_epoch(n, p, g, n_inner=3)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # The compiled module must agree with eager execution.
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    args = (
        jnp.asarray(rng.normal(size=(n, p))),
        jnp.asarray(rng.normal(size=n)),
        jnp.zeros(p),
        jnp.ones(p),
        jnp.asarray(np.sqrt(np.full(g, float(d)))),
        jnp.asarray(0.5),
        jnp.asarray(0.3),
        jnp.asarray(0.01),
    )
    got = compiled(*args)[0]
    from compile import model

    want = model.ista_epoch(*args, n_inner=3)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_screen_lowers():
    n, p, g = 8, 12, 3
    text = aot.to_hlo_text(aot.lower_screen(n, p, g))
    assert "ENTRY" in text
    assert f"f64[{n},{p}]" in text


def test_primal_dual_lowers():
    text = aot.to_hlo_text(aot.lower_primal_dual(6, 10, 2))
    assert "ENTRY" in text


def test_meta_shapes_divisibility_guard():
    import subprocess
    import sys

    # p not divisible by group size must fail fast.
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--n", "4", "--p", "10",
         "--group-size", "3", "--out-dir", "/tmp/sgl-aot-guard"],
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
        capture_output=True,
    )
    assert proc.returncode != 0
