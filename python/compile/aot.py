"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text**.

HLO *text* (never ``HloModuleProto.serialize()``): jax ≥ 0.5 emits protos
with 64-bit instruction ids that the rust side's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts --n 100 --p 1000 \
        --group-size 10 --n-inner 10

Emits ``ista_epoch.hlo.txt``, ``screen.hlo.txt``, ``primal_dual.hlo.txt``,
``smoke.hlo.txt`` and ``meta.toml`` (the shape contract consumed by
``rust/src/runtime/engine.rs``).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XLA computation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def lower_ista_epoch(n, p, g, n_inner):
    fn = functools.partial(model.ista_epoch, n_inner=n_inner)
    return jax.jit(fn).lower(
        _spec(n, p),  # x
        _spec(n),  # y
        _spec(p),  # beta
        _spec(p),  # feat_mask
        _spec(g),  # w
        _spec(),  # lam
        _spec(),  # tau
        _spec(),  # inv_l
    )


def lower_screen(n, p, g):
    return jax.jit(model.screen_gap).lower(
        _spec(n, p),  # x
        _spec(n),  # y
        _spec(p),  # beta
        _spec(p),  # feat_mask
        _spec(g),  # group_mask
        _spec(g),  # w
        _spec(p),  # xj_norms
        _spec(g),  # xg_norms
        _spec(),  # lam
        _spec(),  # tau
    )


def lower_primal_dual(n, p, g):
    return jax.jit(model.primal_dual).lower(
        _spec(n, p), _spec(n), _spec(p), _spec(g), _spec(), _spec()
    )


def lower_smoke():
    """Trivial artifact used by the runtime smoke test: f(x) = (2x + 1,)."""
    return jax.jit(lambda v: (2.0 * v + 1.0,)).lower(_spec(4))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=100)
    ap.add_argument("--p", type=int, default=1000)
    ap.add_argument("--group-size", type=int, default=10)
    ap.add_argument("--n-inner", type=int, default=10)
    args = ap.parse_args()

    n, p, d = args.n, args.p, args.group_size
    if p % d != 0:
        raise SystemExit(f"p={p} must be divisible by group size {d}")
    g = p // d
    os.makedirs(args.out_dir, exist_ok=True)

    artifacts = {
        "ista_epoch": lower_ista_epoch(n, p, g, args.n_inner),
        "screen": lower_screen(n, p, g),
        "primal_dual": lower_primal_dual(n, p, g),
        "smoke": lower_smoke(),
    }
    for name, lowered in artifacts.items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = (
        "# Shape contract for the AOT artifacts (see runtime/engine.rs).\n"
        "[shape]\n"
        f"n = {n}\np = {p}\nn_groups = {g}\ngroup_size = {d}\n"
        f"n_inner = {args.n_inner}\n"
    )
    with open(os.path.join(args.out_dir, "meta.toml"), "w") as f:
        f.write(meta)
    print(f"wrote {args.out_dir}/meta.toml")


if __name__ == "__main__":
    main()
