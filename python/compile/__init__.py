"""Build-time compile package (Layer 1 + Layer 2).

Never imported at runtime: `make artifacts` runs `aot.py` once, the rust
binary consumes `artifacts/*.hlo.txt` afterwards.
"""

import jax

# The solver targets duality gaps down to 1e-8: f64 end to end.
jax.config.update("jax_enable_x64", True)
