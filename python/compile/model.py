"""Layer-2 JAX model: the two computations that are AOT-lowered to HLO and
executed from rust via PJRT.

- :func:`ista_epoch` — ``n_inner`` masked proximal-gradient steps (the
  artifact the rust engine calls between screenings). Calls the Pallas
  matvec + fused-prox kernels inside a ``lax.fori_loop`` so one host call
  amortizes ``n_inner`` passes.
- :func:`screen_gap` — dual-scaled feasible point (Eq. 15), duality gap,
  GAP safe radius (Thm. 2) and the Theorem-1 masks, using the vectorized
  Algorithm 1 (``ref.lambda_rows``) for the dual norm and the Pallas
  screening kernel for the tests.

Signatures must stay in sync with ``rust/src/runtime/engine.rs``
(input order is part of the artifact ABI; see aot.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    group_screen_pallas,
    lambda_rows_pallas,
    matvec_xt_pallas,
    sgl_prox_pallas,
)
from .kernels import ref


def ista_epoch(x, y, beta, feat_mask, w, lam, tau, inv_l, *, n_inner: int = 10):
    """``n_inner`` masked ISTA steps with global step size ``inv_l = 1/‖X‖₂²``.

    Inputs: x (n, p), y (n,), beta (p,), feat_mask (p,) in {0,1}, w (G,),
    lam/tau/inv_l scalars. Group structure: p = G*d with d = p // len(w).
    Returns the updated beta (p,).
    """
    n, p = x.shape
    g = w.shape[0]
    d = p // g
    assert g * d == p, "p must equal n_groups * group_size"

    a = tau * lam * inv_l  # l1 threshold
    b = (1.0 - tau) * w * lam * inv_l  # (G,) group thresholds

    def step(_, beta_k):
        rho = y - x @ (beta_k * feat_mask)
        xt = matvec_xt_pallas(x, rho)
        u = (beta_k + xt * inv_l) * feat_mask
        prox = sgl_prox_pallas(u.reshape(g, d), a, b)
        return prox.reshape(p) * feat_mask

    return (jax.lax.fori_loop(0, n_inner, step, beta * feat_mask),)


def screen_gap(x, y, beta, feat_mask, group_mask, w, xj_norms, xg_norms, lam, tau):
    """Gap evaluation + GAP safe screening (Eq. 15, Thm. 2, Thm. 1).

    Returns ``(gap, radius, new_feat_mask (p,), new_group_mask (G,))``.
    """
    n, p = x.shape
    g = w.shape[0]
    d = p // g
    assert g * d == p

    beta = beta * feat_mask
    rho = y - x @ beta
    xt_rho = matvec_xt_pallas(x, rho)

    # Dual norm Omega^D(X^T rho) via vectorized Algorithm 1 (Eq. 23).
    scale_g = tau + (1.0 - tau) * w
    eps_g = (1.0 - tau) * w / scale_g
    dual_norm = jnp.max(
        lambda_rows_pallas(xt_rho.reshape(g, d), 1.0 - eps_g, eps_g) / scale_g
    )

    # Dual scaling (Eq. 15).
    s = jnp.maximum(lam, dual_norm)
    xt_theta = xt_rho / s

    # Primal/dual objectives and the GAP radius (Thm. 2).
    primal = 0.5 * jnp.sum(rho * rho) + lam * ref.omega(beta.reshape(g, d), tau, w)
    diff = rho / s - y / lam
    dual = 0.5 * jnp.sum(y * y) - 0.5 * lam * lam * jnp.sum(diff * diff)
    gap = jnp.maximum(primal - dual, 0.0)
    radius = jnp.sqrt(2.0 * gap) / lam

    # Theorem-1 tests (Pallas kernel).
    group_keep, feat_keep = group_screen_pallas(
        xt_theta.reshape(g, d), xj_norms.reshape(g, d), xg_norms, w, tau, radius
    )
    new_feat = feat_mask * (group_keep[:, None] * feat_keep).reshape(p)
    # A group with every feature screened is inactive.
    any_feat = jnp.max(new_feat.reshape(g, d), axis=1)
    new_group = group_mask * group_keep * any_feat
    return gap, radius, new_feat, new_group


def primal_dual(x, y, beta, w, lam, tau):
    """Monitoring artifact: (primal, dual, gap) without screening."""
    n, p = x.shape
    g = w.shape[0]
    d = p // g
    rho = y - x @ beta
    xt_rho = matvec_xt_pallas(x, rho)
    scale_g = tau + (1.0 - tau) * w
    eps_g = (1.0 - tau) * w / scale_g
    dual_norm = jnp.max(
        ref.lambda_rows(xt_rho.reshape(g, d), 1.0 - eps_g, eps_g) / scale_g
    )
    s = jnp.maximum(lam, dual_norm)
    primal = 0.5 * jnp.sum(rho * rho) + lam * ref.omega(beta.reshape(g, d), tau, w)
    diff = rho / s - y / lam
    dual = 0.5 * jnp.sum(y * y) - 0.5 * lam * lam * jnp.sum(diff * diff)
    return primal, dual, jnp.maximum(primal - dual, 0.0)
