"""Pure-jnp reference oracles for the Pallas kernels and the model math.

Everything here is straight-line jax.numpy — the "obviously correct"
implementations that the kernels and the lowered artifacts are tested
against (pytest + hypothesis). All functions are f64 (jax_enable_x64 is
set in compile/__init__.py).
"""

from __future__ import annotations

import jax.numpy as jnp

_TINY = 1e-300


def soft_threshold(x, t):
    """S_t(x) = sign(x) (|x| - t)_+ — paper Notation."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def group_soft_threshold(u, t):
    """S^gp_t per row of u (G, d): (1 - t/||u_g||)_+ u_g.

    ``t`` is scalar or shape (G,).
    """
    t = jnp.asarray(t)
    norms = jnp.linalg.norm(u, axis=-1)
    tb = jnp.broadcast_to(t, norms.shape)
    shrink = jnp.where(norms > tb, 1.0 - tb / jnp.maximum(norms, _TINY), 0.0)
    return u * shrink[..., None]


def sgl_prox(u, a, b):
    """Fused two-level SGL prox per row: S^gp_b(S_a(u)).

    u: (G, d); a: scalar; b: scalar or (G,).
    """
    return group_soft_threshold(soft_threshold(u, a), b)


def lambda_rows(x, alpha, r):
    """Vectorized Algorithm 1: per-row Lambda(x_g, alpha_g, R_g).

    x: (G, d); alpha, r: scalar or (G,) in [0, 1] x [0, inf).
    Solves sum_i S_{nu*alpha}(|x_i|)^2 = (nu*R)^2 per row.

    Fixed-shape formulation (no data-dependent early exit): sort the row,
    build prefix sums, locate the active-count j0 by a mask-argmax, then
    apply the closed-form root (paper Eq. 33/36). The special cases
    alpha=0 / R=0 / zero rows are resolved with jnp.where selects so the
    whole computation stays jittable with traced tau.
    """
    x = jnp.abs(jnp.asarray(x))
    g, d = x.shape
    alpha = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), (g,))
    r = jnp.broadcast_to(jnp.asarray(r, x.dtype), (g,))

    s = jnp.sort(x, axis=1)[:, ::-1]  # descending
    cs = jnp.cumsum(s, axis=1)  # S_k
    cs2 = jnp.cumsum(s * s, axis=1)  # S2_k
    k = jnp.arange(1, d + 1, dtype=x.dtype)[None, :]  # (1, d)

    # b_{k+1} = S2_k/x_(k+1)^2 - 2 S_k/x_(k+1) + k, with x_(d+1) := 0 -> inf.
    x_next = jnp.concatenate([s[:, 1:], jnp.zeros((g, 1), x.dtype)], axis=1)
    safe_next = jnp.maximum(x_next, _TINY)
    b_next = jnp.where(
        x_next > 0.0,
        cs2 / (safe_next * safe_next) - 2.0 * cs / safe_next + k,
        jnp.inf,
    )

    alpha_safe = jnp.maximum(alpha, _TINY)[:, None]
    ratio = (r[:, None] / alpha_safe) ** 2
    hit = ratio < b_next  # first True column gives j0 (active count j0+1)
    j0 = jnp.argmax(hit, axis=1)  # 0-based
    j0f = (j0 + 1).astype(x.dtype)
    sj = jnp.take_along_axis(cs, j0[:, None], axis=1)[:, 0]
    s2j = jnp.take_along_axis(cs2, j0[:, None], axis=1)[:, 0]

    a1 = alpha_safe[:, 0]
    denom = a1 * a1 * j0f - r * r
    disc = jnp.maximum(a1 * a1 * sj * sj - s2j * denom, 0.0)
    denom_safe = jnp.where(jnp.abs(denom) > 1e-14, denom, 1.0)
    nu_quad = (a1 * sj - jnp.sqrt(disc)) / denom_safe
    nu_lin = s2j / jnp.maximum(2.0 * a1 * sj, _TINY)
    nu_generic = jnp.where(jnp.abs(denom) > 1e-14, nu_quad, nu_lin)

    # Special cases.
    l2 = jnp.linalg.norm(x, axis=1)
    linf = jnp.max(x, axis=1)
    nu_alpha0 = l2 / jnp.maximum(r, _TINY)  # alpha = 0
    nu_r0 = linf / jnp.maximum(alpha, _TINY)  # R = 0
    nu = jnp.where(alpha == 0.0, nu_alpha0, jnp.where(r == 0.0, nu_r0, nu_generic))
    return jnp.where(linf > 0.0, nu, 0.0)


def epsilon_norm_rows(x, eps):
    """Per-row epsilon-norm ||x_g||_eps = Lambda(x_g, 1-eps, eps)."""
    eps = jnp.asarray(eps)
    return lambda_rows(x, 1.0 - eps, eps)


def omega(beta2d, tau, w):
    """Omega_{tau,w}(beta) on group-reshaped beta (G, d)."""
    l1 = jnp.sum(jnp.abs(beta2d))
    gl = jnp.sum(w * jnp.linalg.norm(beta2d, axis=1))
    return tau * l1 + (1.0 - tau) * gl


def omega_dual(xi2d, tau, w):
    """Omega^D via Eq. (20)/(23): max_g ||xi_g||_{eps_g} / (tau+(1-tau)w_g)."""
    scale = tau + (1.0 - tau) * w
    eps = (1.0 - tau) * w / scale
    return jnp.max(lambda_rows(xi2d, 1.0 - eps, eps) / scale)


def group_screen_tests(xi2d, tau, radius, xj_norms2d, xg_norms, w):
    """Theorem 1 tests against the sphere B(theta_c, radius).

    xi2d: X^T theta_c reshaped (G, d); xj_norms2d: ||X_j|| reshaped (G, d);
    xg_norms: ||X_g||_2 (G,). Returns (group_keep (G,), feat_keep (G, d))
    as 0/1 floats: keep = NOT screened.
    """
    st = soft_threshold(xi2d, tau)
    st_norm = jnp.linalg.norm(st, axis=1)
    xi_inf = jnp.max(jnp.abs(xi2d), axis=1)
    t_g = jnp.where(
        xi_inf > tau,
        st_norm + radius * xg_norms,
        jnp.maximum(xi_inf + radius * xg_norms - tau, 0.0),
    )
    group_keep = (t_g >= (1.0 - tau) * w).astype(xi2d.dtype)
    feat_keep = (jnp.abs(xi2d) + radius * xj_norms2d >= tau).astype(xi2d.dtype)
    return group_keep, feat_keep


def matvec_xt(x, rho):
    """X^T rho (the matvec kernel's oracle)."""
    return x.T @ rho
