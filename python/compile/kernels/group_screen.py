"""Pallas kernel: Theorem-1 screening tests over group tiles.

Given the sphere ``B(θ_c, r)`` in correlation space (``ξ = Xᵀθ_c``
reshaped ``(G, d)``), computes per group tile:

- the group bound ``T_g`` (paper Eq. 14):
  ``‖S_τ(ξ_g)‖ + r‖X_g‖₂``            if ``‖ξ_g‖∞ > τ``,
  ``(‖ξ_g‖∞ + r‖X_g‖₂ − τ)₊``          otherwise;
- ``group_keep_g = [T_g ≥ (1−τ)w_g]`` (group survives);
- ``feat_keep_{gj} = [|ξ_{gj}| + r‖X_j‖ ≥ τ]`` (feature survives).

Outputs are 0/1 floats so the masks multiply straight into the solver
state. One tile = one VMEM-resident block of ``block_g`` groups; all three
outputs are produced in a single pass over the tile (VPU reductions along
the lane/``d`` axis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _screen_kernel(xi_ref, xjn_ref, xgn_ref, w_ref, tau_ref, r_ref, gk_ref, fk_ref):
    xi = xi_ref[...]  # (block_g, d)
    xjn = xjn_ref[...]  # (block_g, d)
    xgn = xgn_ref[...]  # (block_g,)
    w = w_ref[...]  # (block_g,)
    tau = tau_ref[0]
    r = r_ref[0]
    ax = jnp.abs(xi)
    st = jnp.maximum(ax - tau, 0.0)  # |S_tau(xi)| elementwise
    st_norm = jnp.sqrt(jnp.sum(st * st, axis=1))
    xi_inf = jnp.max(ax, axis=1)
    t_g = jnp.where(
        xi_inf > tau,
        st_norm + r * xgn,
        jnp.maximum(xi_inf + r * xgn - tau, 0.0),
    )
    gk_ref[...] = (t_g >= (1.0 - tau) * w).astype(xi.dtype)
    fk_ref[...] = (ax + r * xjn >= tau).astype(xi.dtype)


def _pick_block(g: int, target: int = 128) -> int:
    best = 1
    for cand in range(1, min(g, target) + 1):
        if g % cand == 0:
            best = cand
    return best


def group_screen_pallas(xi2d, xj_norms2d, xg_norms, w, tau, radius, *, block_g=None):
    """Run the Theorem-1 tests. Returns ``(group_keep (G,), feat_keep (G, d))``."""
    g, d = xi2d.shape
    bg = block_g or _pick_block(g)
    assert g % bg == 0, f"block_g={bg} must divide G={g}"
    tau_arr = jnp.reshape(jnp.asarray(tau, xi2d.dtype), (1,))
    r_arr = jnp.reshape(jnp.asarray(radius, xi2d.dtype), (1,))
    return pl.pallas_call(
        _screen_kernel,
        grid=(g // bg,),
        in_specs=[
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
            pl.BlockSpec((bg,), lambda i: (i,)),
            pl.BlockSpec((bg,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bg,), lambda i: (i,)),
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g,), xi2d.dtype),
            jax.ShapeDtypeStruct((g, d), xi2d.dtype),
        ],
        interpret=True,
    )(xi2d, xj_norms2d, xg_norms, w, tau_arr, r_arr)
