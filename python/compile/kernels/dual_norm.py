"""Pallas kernel: vectorized Algorithm 1 — per-group ε-norm root
``Λ(x_g, α_g, R_g)`` over a tile of groups.

This is the paper's dual-norm evaluation (Prop. 9 / Eq. 23) in its
fixed-shape accelerator form: instead of the CPU's data-dependent
early-exit scan, each group row is fully sorted along the lane axis
(d ≈ 7–10, a single in-register sorting network on TPU), prefix sums
locate the active count ``j0`` via a mask-argmax, and the closed-form
quadratic root (Eq. 33/36) is applied — all branch-free with `where`
selects so the kernel lowers with a traced ``τ``.

One grid step processes ``(block_g, d)`` in VMEM; outputs one ``ν`` per
group. `interpret=True` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TINY = 1e-300


def _lambda_kernel(x_ref, alpha_ref, r_ref, nu_ref):
    x = jnp.abs(x_ref[...])  # (bg, d)
    alpha = alpha_ref[...]  # (bg,)
    r = r_ref[...]  # (bg,)
    bg, d = x.shape

    s = jnp.sort(x, axis=1)[:, ::-1]
    cs = jnp.cumsum(s, axis=1)
    cs2 = jnp.cumsum(s * s, axis=1)
    # broadcasted_iota instead of jnp.arange: arange materializes a concrete
    # (d,) array that pallas_call rejects as a captured constant (and 1-D
    # iota would not lower on TPU); the 2-D iota is a primitive either way.
    k = jax.lax.broadcasted_iota(x.dtype, (1, d), 1) + 1.0

    x_next = jnp.concatenate([s[:, 1:], jnp.zeros((bg, 1), x.dtype)], axis=1)
    safe_next = jnp.maximum(x_next, _TINY)
    b_next = jnp.where(
        x_next > 0.0,
        cs2 / (safe_next * safe_next) - 2.0 * cs / safe_next + k,
        jnp.inf,
    )

    alpha_safe = jnp.maximum(alpha, _TINY)[:, None]
    ratio = (r[:, None] / alpha_safe) ** 2
    j0 = jnp.argmax(ratio < b_next, axis=1)
    j0f = (j0 + 1).astype(x.dtype)
    sj = jnp.take_along_axis(cs, j0[:, None], axis=1)[:, 0]
    s2j = jnp.take_along_axis(cs2, j0[:, None], axis=1)[:, 0]

    a1 = jnp.maximum(alpha, _TINY)
    denom = a1 * a1 * j0f - r * r
    disc = jnp.maximum(a1 * a1 * sj * sj - s2j * denom, 0.0)
    denom_safe = jnp.where(jnp.abs(denom) > 1e-14, denom, 1.0)
    nu_quad = (a1 * sj - jnp.sqrt(disc)) / denom_safe
    nu_lin = s2j / jnp.maximum(2.0 * a1 * sj, _TINY)
    nu_generic = jnp.where(jnp.abs(denom) > 1e-14, nu_quad, nu_lin)

    l2 = jnp.sqrt(jnp.sum(x * x, axis=1))
    linf = jnp.max(x, axis=1)
    nu_alpha0 = l2 / jnp.maximum(r, _TINY)
    nu_r0 = linf / a1
    nu = jnp.where(alpha == 0.0, nu_alpha0, jnp.where(r == 0.0, nu_r0, nu_generic))
    nu_ref[...] = jnp.where(linf > 0.0, nu, 0.0)


def _pick_block(g: int, target: int = 128) -> int:
    best = 1
    for cand in range(1, min(g, target) + 1):
        if g % cand == 0:
            best = cand
    return best


def lambda_rows_pallas(x, alpha, r, *, block_g: int | None = None):
    """Per-row ``Λ(x_g, α_g, R_g)``: x (G, d), alpha/r scalar or (G,) → (G,)."""
    g, d = x.shape
    bg = block_g or _pick_block(g)
    assert g % bg == 0, f"block_g={bg} must divide G={g}"
    alpha_arr = jnp.broadcast_to(jnp.asarray(alpha, x.dtype), (g,))
    r_arr = jnp.broadcast_to(jnp.asarray(r, x.dtype), (g,))
    return pl.pallas_call(
        _lambda_kernel,
        grid=(g // bg,),
        in_specs=[
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
            pl.BlockSpec((bg,), lambda i: (i,)),
            pl.BlockSpec((bg,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bg,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g,), x.dtype),
        interpret=True,
    )(x, alpha_arr, r_arr)
