"""Pallas kernel: fused two-level SGL proximal operator.

Computes, per group row ``g`` of a ``(G, d)`` tile,

    out_g = S^gp_{b_g}( S_a(u_g) )

i.e. coordinate soft-thresholding at level ``a`` followed by block
soft-thresholding at level ``b_g`` — the exact prox of
``a·‖·‖₁ + b_g·‖·‖`` (paper §6), fused so the thresholded tile never
leaves VMEM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks blocks of
``block_g`` groups; each grid step streams one ``(block_g, d)`` tile
HBM→VMEM, applies both thresholds in-register on the VPU (no MXU needed —
this is elementwise + row reductions) and writes the tile back. Runs under
``interpret=True`` here because the CPU PJRT plugin cannot execute Mosaic
custom-calls; the BlockSpec structure is the TPU schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_kernel(u_ref, a_ref, b_ref, o_ref):
    u = u_ref[...]  # (block_g, d)
    a = a_ref[0]  # scalar threshold (tau * lambda / L)
    b = b_ref[...]  # (block_g,) per-group thresholds
    # S_a(u)
    st = jnp.sign(u) * jnp.maximum(jnp.abs(u) - a, 0.0)
    # S^gp_b(st) row-wise
    norms = jnp.sqrt(jnp.sum(st * st, axis=1))
    shrink = jnp.where(norms > b, 1.0 - b / jnp.maximum(norms, 1e-300), 0.0)
    o_ref[...] = st * shrink[:, None]


def _pick_block(g: int, target: int = 128) -> int:
    """Largest divisor of g that is <= target (grid must tile exactly)."""
    best = 1
    for cand in range(1, min(g, target) + 1):
        if g % cand == 0:
            best = cand
    return best


def sgl_prox_pallas(u, a, b, *, block_g: int | None = None):
    """Fused SGL prox over group tiles.

    u: (G, d) gradient-step blocks; a: scalar ℓ1 threshold; b: (G,) group
    thresholds. Returns (G, d).
    """
    g, d = u.shape
    bg = block_g or _pick_block(g)
    assert g % bg == 0, f"block_g={bg} must divide G={g}"
    a_arr = jnp.reshape(jnp.asarray(a, u.dtype), (1,))
    b_arr = jnp.asarray(b, u.dtype)
    return pl.pallas_call(
        _prox_kernel,
        grid=(g // bg,),
        in_specs=[
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bg,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bg, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, d), u.dtype),
        interpret=True,
    )(u, a_arr, b_arr)
