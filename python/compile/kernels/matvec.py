"""Pallas kernel: tiled correlation product ``Xᵀρ``.

The dominant FLOPs of every solver pass (O(np)). The grid walks feature
tiles of ``block_p`` columns; each step loads the ``(n, block_p)`` slab of
``X`` and the full residual ``ρ`` (n ≤ ~1k fits VMEM comfortably:
n=100, block_p=256, f64 → 0.2 MB ≪ 16 MB) and reduces over rows.

On a real TPU this contraction would feed the MXU as an (1, n) × (n,
block_p) matmul per tile; under ``interpret=True`` the same BlockSpec
schedule runs on CPU numpy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(x_ref, rho_ref, o_ref):
    x = x_ref[...]  # (n, block_p)
    rho = rho_ref[...]  # (n,)
    o_ref[...] = jnp.sum(x * rho[:, None], axis=0)


def _pick_block(p: int, target: int = 256) -> int:
    best = 1
    for cand in range(1, min(p, target) + 1):
        if p % cand == 0:
            best = cand
    return best


def matvec_xt_pallas(x, rho, *, block_p: int | None = None):
    """``Xᵀρ`` with X (n, p), rho (n,) → (p,)."""
    n, p = x.shape
    bp = block_p or _pick_block(p)
    assert p % bp == 0, f"block_p={bp} must divide p={p}"
    return pl.pallas_call(
        _matvec_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), x.dtype),
        interpret=True,
    )(x, rho)
