"""Layer-1 Pallas kernels (interpret mode; see DESIGN.md §Hardware-Adaptation).

- ``sgl_prox``     — fused two-level proximal operator over group tiles;
- ``group_screen`` — Theorem-1 screening tests over group tiles;
- ``matvec``       — tiled ``Xᵀρ`` (the dominant FLOPs of one pass);
- ``dual_norm``    — vectorized Algorithm 1 (per-group ε-norm root Λ).

``ref.py`` holds the pure-jnp oracles each kernel is tested against.
"""

from .dual_norm import lambda_rows_pallas  # noqa: F401
from .group_screen import group_screen_pallas  # noqa: F401
from .matvec import matvec_xt_pallas  # noqa: F401
from .sgl_prox import sgl_prox_pallas  # noqa: F401
