//! Quickstart: generate a small grouped regression problem, solve one λ
//! with the GAP safe rule, and solve a short warm-started path.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::path::{solve_path, PathOptions};
use sgl::solver::problem::SglProblem;

fn main() {
    // n=100 observations, p=1000 features in 100 groups of 10.
    let data = generate(&SyntheticConfig::small(42));
    println!("dataset: {}", data.dataset.name);

    let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, 0.2);
    let lambda_max = pb.lambda_max();
    println!("lambda_max = {lambda_max:.4e} (Eq. 22, via Algorithm 1)");

    // --- single solve at lambda_max / 10
    let lambda = 0.1 * lambda_max;
    let res = solve(&pb, lambda, None, &SolveOptions::default());
    println!(
        "single solve @ lambda={lambda:.3e}: gap={:.2e} in {} epochs ({:.3}s), \
         {}/{} features and {}/{} groups still active",
        res.gap,
        res.epochs,
        res.elapsed_s,
        res.active.n_active_features(),
        pb.p(),
        res.active.n_active_groups(),
        pb.n_groups(),
    );
    let nnz = res.beta.iter().filter(|&&b| b != 0.0).count();
    println!("solution has {nnz} nonzero coefficients");

    // --- short path, GAP safe vs no screening
    for rule in [RuleKind::None, RuleKind::GapSafe] {
        let opts = PathOptions {
            delta: 3.0,
            t_count: 20,
            solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
        };
        let path = solve_path(&pb, &opts);
        println!(
            "path ({:>8}): {:.3}s, {} total epochs, converged={}",
            rule.name(),
            path.total_s,
            path.total_epochs(),
            path.all_converged()
        );
    }
}
