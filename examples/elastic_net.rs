//! Sparse-Group Lasso + Elastic-Net (paper App. D): the ridge-augmented
//! reformulation solved with the same GAP-safe machinery, swept over λ₂.
//!
//! ```bash
//! cargo run --release --example elastic_net
//! ```

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::elastic_net::elastic_net_problem;
use sgl::util::cli::{Args, OptSpec};

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "tau", help: "mixing parameter", takes_value: true, default: Some("0.4") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("9") },
    ]);
    let tau = args.get_f64("tau", 0.4);
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 5,
        gamma1: 4,
        gamma2: 3,
        seed: args.get_u64("seed", 9),
        ..Default::default()
    };
    let data = generate(&cfg);
    println!("SGL + Elastic-Net (App. D): n={} p={}", cfg.n, cfg.p());
    println!(
        "{:>8} {:>12} {:>10} {:>8} {:>8} {:>10}",
        "lambda2", "lambda1", "gap", "nnz", "||beta||", "screened%"
    );
    for lambda2 in [0.0, 0.5, 2.0, 8.0] {
        let pb = elastic_net_problem(&data.dataset.x, &data.dataset.y, data.dataset.groups.clone(), tau, lambda2);
        let lambda1 = 0.15 * pb.lambda_max();
        let res = solve(
            &pb,
            lambda1,
            None,
            &SolveOptions { rule: RuleKind::GapSafe, tol: 1e-8, ..Default::default() },
        );
        assert!(res.converged);
        let nnz = res.beta.iter().filter(|&&b| b != 0.0).count();
        let norm: f64 = res.beta.iter().map(|b| b * b).sum::<f64>().sqrt();
        let screened =
            100.0 * (pb.p() - res.active.n_active_features()) as f64 / pb.p() as f64;
        println!(
            "{:>8.1} {:>12.4e} {:>10.2e} {:>8} {:>8.3} {:>9.1}%",
            lambda2, lambda1, res.gap, nnz, norm, screened
        );
    }
    println!("\nridge strength shrinks ||beta|| while screening keeps working (App. D).");
}
