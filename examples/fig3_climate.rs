//! Figure 3: climate experiments on the simulated NCEP/NCAR dataset
//! (DESIGN.md §Substitutions).
//!
//! - `--panel a` — held-out prediction error over (λ, τ) → fig3a.csv
//! - `--panel b` — path time vs accuracy per rule at τ★  → fig3b.csv
//!
//! ```bash
//! cargo run --release --example fig3_climate -- --scale paper
//! ```

use sgl::coordinator::jobs::RuleComparisonJob;
use sgl::coordinator::report::{render_rule_timings, write_rule_timings};
use sgl::data::climate::ClimateConfig;
use sgl::data::csvio::write_csv;
use sgl::experiments::fig3;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::pool::default_threads;
use std::path::Path;

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "panel", help: "a|b|all", takes_value: true, default: Some("all") },
        OptSpec { name: "scale", help: "small|paper", takes_value: true, default: Some("small") },
        OptSpec { name: "t-count", help: "lambdas on the path", takes_value: true, default: None },
        OptSpec { name: "tol", help: "gap tolerance for panel a", takes_value: true, default: None },
        OptSpec { name: "out-dir", help: "output directory", takes_value: true, default: Some("out") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("7") },
    ]);
    let paper = args.get_or("scale", "small") == "paper";
    let cfg = if paper {
        ClimateConfig { seed: args.get_u64("seed", 7), ..Default::default() }
    } else {
        ClimateConfig::small(args.get_u64("seed", 7))
    };
    let t_count = args.get_usize("t-count", if paper { 100 } else { 20 });
    let tol = args.get_f64("tol", if paper { 1e-8 } else { 1e-6 });
    let out_dir = args.get_or("out-dir", "out");
    let panel = args.get_or("panel", "all");
    let threads = default_threads();

    println!("Fig 3 — simulated climate {}x{} grid, n={} months, p={}",
        cfg.grid_lon, cfg.grid_lat, cfg.n_months, cfg.p());
    let data = fig3::prepared_data(&cfg);

    let mut tau_star = 0.4;
    if panel == "a" || panel == "all" {
        let taus = fig3::paper_tau_grid();
        // delta=2.5 per the paper's choice for the climate path.
        let cv = fig3::validation_grid(&data, &taus, 2.5, t_count, tol, threads, 99);
        tau_star = cv.best_tau;
        let mut rows = Vec::new();
        for curve in &cv.curves {
            for (li, (&lambda, &mse)) in
                curve.lambdas.iter().zip(&curve.test_mse).enumerate()
            {
                rows.push(vec![curve.tau, li as f64, lambda, mse]);
            }
        }
        let path_s = format!("{out_dir}/fig3a.csv");
        write_csv(Path::new(&path_s), &["tau", "lambda_idx", "lambda", "test_mse"], &rows)
            .expect("write csv");
        println!("wrote {path_s}");
        println!(
            "  best model: tau*={} lambda*={:.4e} test mse={:.5e}",
            cv.best_tau, cv.best_lambda, cv.best_mse
        );
    }

    if panel == "b" || panel == "all" {
        let job = RuleComparisonJob {
            tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
            delta: 2.5,
            t_count,
            ..Default::default()
        };
        println!("  timing rules at tau*={tau_star} (delta=2.5)...");
        let timings = fig3::rule_timings(&data, tau_star, &job, threads);
        let path_s = format!("{out_dir}/fig3b.csv");
        write_rule_timings(Path::new(&path_s), &timings).expect("write csv");
        println!("wrote {path_s}");
        println!("{}", render_rule_timings(&timings));
    }
}
