//! Figure 2: synthetic-data experiments (§7.1; ρ=0.5, γ₁=10, γ₂=4, τ=0.2).
//!
//! - `--panel a` — active-feature proportion vs (λ_t, K)   → fig2a.csv
//! - `--panel b` — active-group proportion vs (λ_t, K)     → fig2b.csv
//! - `--panel c` — time-to-convergence per screening rule  → fig2c.csv
//! - `--panel all` (default) — everything.
//!
//! `--scale paper` uses the paper's n=100, p=10000 instance (minutes);
//! `--scale small` a 10x smaller one (seconds).
//!
//! ```bash
//! cargo run --release --example fig2_synthetic -- --scale paper --panel c
//! ```

use sgl::coordinator::jobs::RuleComparisonJob;
use sgl::coordinator::report::{render_rule_timings, write_rule_timings};
use sgl::data::csvio::write_csv;
use sgl::data::synthetic::SyntheticConfig;
use sgl::experiments::fig2;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::pool::default_threads;
use std::path::Path;

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "panel", help: "a|b|c|all", takes_value: true, default: Some("all") },
        OptSpec { name: "scale", help: "small|paper", takes_value: true, default: Some("small") },
        OptSpec { name: "tau", help: "mixing parameter", takes_value: true, default: Some("0.2") },
        OptSpec { name: "t-count", help: "lambdas on the path", takes_value: true, default: None },
        OptSpec { name: "out-dir", help: "output directory", takes_value: true, default: Some("out") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("42") },
    ]);
    let paper = args.get_or("scale", "small") == "paper";
    let cfg = if paper {
        SyntheticConfig { seed: args.get_u64("seed", 42), ..Default::default() }
    } else {
        SyntheticConfig::small(args.get_u64("seed", 42))
    };
    let tau = args.get_f64("tau", 0.2);
    let t_count = args.get_usize("t-count", if paper { 100 } else { 30 });
    let out_dir = args.get_or("out-dir", "out");
    let panel = args.get_or("panel", "all");
    println!(
        "Fig 2 — synthetic n={} p={} (rho={}, gamma1={}, gamma2={}, tau={tau})",
        cfg.n,
        cfg.p(),
        cfg.rho,
        cfg.gamma1,
        cfg.gamma2
    );

    if panel == "a" || panel == "b" || panel == "all" {
        // K axis of the paper's heat maps.
        let k_values: Vec<usize> = if paper {
            vec![10, 30, 100, 300, 1000]
        } else {
            vec![10, 30, 100, 300]
        };
        let surf = fig2::active_surfaces(&cfg, tau, 3.0, t_count, &k_values, 10);
        for (name, fractions) in
            [("fig2a", &surf.feature_fractions), ("fig2b", &surf.group_fractions)]
        {
            if panel != "all" && !name.ends_with(panel.chars().next().unwrap()) {
                continue;
            }
            let mut rows = Vec::new();
            for (ki, &k) in surf.k_values.iter().enumerate() {
                for (li, &lambda) in surf.lambdas.iter().enumerate() {
                    rows.push(vec![li as f64, lambda, k as f64, fractions[ki][li]]);
                }
            }
            let path_s = format!("{out_dir}/{name}.csv");
            write_csv(
                Path::new(&path_s),
                &["lambda_idx", "lambda", "k_epochs", "active_fraction"],
                &rows,
            )
            .expect("write csv");
            println!("wrote {path_s}");
        }
        // Terminal summary: final-K active fractions across the path.
        let last = surf.feature_fractions.last().unwrap();
        println!(
            "  active-feature fraction at K={}: first lambda {:.3}, mid {:.3}, last {:.3}",
            surf.k_values.last().unwrap(),
            last[0],
            last[last.len() / 2],
            last[last.len() - 1]
        );
    }

    if panel == "c" || panel == "all" {
        let job = RuleComparisonJob {
            tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
            delta: 3.0,
            t_count,
            ..Default::default()
        };
        let timings = fig2::rule_timings(&cfg, tau, &job, default_threads());
        let path_s = format!("{out_dir}/fig2c.csv");
        write_rule_timings(Path::new(&path_s), &timings).expect("write csv");
        println!("wrote {path_s}");
        println!("{}", render_rule_timings(&timings));
    }
}
