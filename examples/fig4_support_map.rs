//! Figure 4: support map — active groups for air-temperature prediction
//! near the target ("Dakar") cell, max |coefficient| per location.
//!
//! Runs the Fig. 3a validation to pick (τ★, λ★), refits, and renders the
//! map as ASCII + CSV.
//!
//! ```bash
//! cargo run --release --example fig4_support_map -- --scale paper
//! ```

use sgl::coordinator::report::{render_support_map, write_support_map};
use sgl::data::climate::ClimateConfig;
use sgl::experiments::{fig3, fig4};
use sgl::util::cli::{Args, OptSpec};
use sgl::util::pool::default_threads;
use std::path::Path;

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "scale", help: "small|paper", takes_value: true, default: Some("small") },
        OptSpec { name: "t-count", help: "lambdas on the path", takes_value: true, default: None },
        OptSpec { name: "out-dir", help: "output directory", takes_value: true, default: Some("out") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("7") },
    ]);
    let paper = args.get_or("scale", "small") == "paper";
    let cfg = if paper {
        ClimateConfig { seed: args.get_u64("seed", 7), ..Default::default() }
    } else {
        ClimateConfig::small(args.get_u64("seed", 7))
    };
    let t_count = args.get_usize("t-count", if paper { 100 } else { 20 });
    let out_dir = args.get_or("out-dir", "out");

    let data = fig3::prepared_data(&cfg);
    println!("validating (lambda, tau) grid to pick the model...");
    let cv = fig3::validation_grid(
        &data,
        &fig3::paper_tau_grid(),
        2.5,
        t_count,
        if paper { 1e-8 } else { 1e-6 },
        default_threads(),
        99,
    );
    println!("  tau*={} lambda*={:.4e} mse={:.4e}", cv.best_tau, cv.best_lambda, cv.best_mse);

    let map = fig4::support_map(&data, &cv.best_beta);
    println!(
        "support: {} active groups of {}; coefficient-weighted mean distance to target \
         {:.2} cells (grid average {:.2})",
        map.active_groups,
        data.dataset.groups.n_groups(),
        map.weighted_mean_distance,
        map.baseline_mean_distance
    );
    println!("\nmax |coefficient| per grid cell (X = target):\n");
    println!("{}", render_support_map(&map.values, map.grid_lon, map.grid_lat, map.target));

    let path_s = format!("{out_dir}/fig4_support.csv");
    write_support_map(Path::new(&path_s), &map.values, map.grid_lon, map.grid_lat, map.target)
        .expect("write csv");
    println!("wrote {path_s}");
}
