//! End-to-end three-layer driver — the full stack on a real workload.
//!
//! Proves all layers compose: the Pallas kernels (L1) inside the JAX model
//! (L2) were AOT-lowered to HLO text by `make artifacts`; this binary (L3)
//! loads them through PJRT and solves an entire warm-started λ-path on the
//! paper's synthetic workload, cross-checking every solution against the
//! native Rust solver and reporting per-λ latency and screening rates.
//!
//! ```bash
//! make artifacts && cargo run --release --example xla_pipeline
//! ```

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::runtime::engine::XlaEngine;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::problem::SglProblem;
use sgl::util::cli::{Args, OptSpec};
use sgl::util::timer::Stopwatch;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") },
        OptSpec { name: "tau", help: "mixing parameter", takes_value: true, default: Some("0.2") },
        OptSpec { name: "t-count", help: "path grid size", takes_value: true, default: Some("8") },
        OptSpec { name: "tol", help: "duality-gap target (relative to ||y||^2)", takes_value: true, default: Some("1e-6") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("42") },
    ]);
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let tau = args.get_f64("tau", 0.2);
    let tol = args.get_f64("tol", 1e-6);
    let t_count = args.get_usize("t-count", 8);

    println!("== Layer 2/1: loading AOT artifacts (JAX + Pallas -> HLO text) ==");
    let engine = XlaEngine::load(&dir)?;
    let meta = engine.meta.clone();
    println!(
        "   {}: n={} p={} ({} groups x {}), {} inner steps per call, platform={}",
        dir.display(),
        meta.n,
        meta.p,
        meta.n_groups,
        meta.group_size,
        meta.n_inner,
        engine.rt.platform()
    );

    println!("== workload: paper synthetic (rho=0.5), shaped to the artifact ==");
    let cfg = SyntheticConfig {
        n: meta.n,
        n_groups: meta.n_groups,
        group_size: meta.group_size,
        gamma1: 5.min(meta.n_groups),
        gamma2: 4.min(meta.group_size),
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    let data = generate(&cfg);
    let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, tau);
    let session = engine.session(&pb)?;
    let lambda_max = pb.lambda_max();
    let lambdas = SglProblem::lambda_grid(lambda_max, 2.0, t_count);
    println!("   lambda_max={lambda_max:.4e}, path of {t_count} lambdas (delta=2)\n");

    println!("== Layer 3: warm-started path through PJRT ==");
    println!(
        "{:>4} {:>12} {:>10} {:>8} {:>10} {:>10} {:>10}",
        "t", "lambda", "gap", "rounds", "ms", "active", "max|dBeta|"
    );
    let mut warm: Option<Vec<f64>> = None;
    let total = Stopwatch::start();
    let mut all_ok = true;
    for (t, &lambda) in lambdas.iter().enumerate() {
        let sw = Stopwatch::start();
        let res = session.solve(lambda, tol, 20_000, warm.as_deref(), true)?;
        let ms = sw.elapsed_ms();
        // Cross-check against the native Algorithm-2 solver.
        let native = solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol: tol.min(1e-9), rule: RuleKind::GapSafe, record_history: false, ..Default::default() },
        );
        let mut max_diff = 0.0_f64;
        for j in 0..pb.p() {
            max_diff = max_diff.max((res.beta[j] - native.beta[j]).abs());
        }
        all_ok &= res.converged && max_diff < 1e-3;
        println!(
            "{:>4} {:>12.4e} {:>10.2e} {:>8} {:>10.1} {:>6}/{:<4} {:>10.2e}",
            t, lambda, res.gap, res.rounds, ms, res.active_features, pb.p(), max_diff
        );
        warm = Some(res.beta);
    }
    println!(
        "\npath complete in {:.2}s; XLA/native agreement on every lambda: {}",
        total.elapsed_s(),
        if all_ok { "OK" } else { "FAILED" }
    );
    anyhow::ensure!(all_ok, "cross-check failed");
    Ok(())
}
