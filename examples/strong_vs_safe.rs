//! Safe vs unsafe screening: GAP safe rules against the sequential
//! **strong rules** (Tibshirani et al. 2012) extended to SGL.
//!
//! The paper (§1, §7) notes that unsafe rules may discard *active*
//! variables — they need a KKT-violation/re-solve loop to stay exact,
//! which is why the paper excludes TLFre from its comparison. This driver
//! quantifies that trade-off: working-set sizes, violation counts, and the
//! end-to-end time of strong, GAP safe, and the combination.
//!
//! ```bash
//! cargo run --release --example strong_vs_safe
//! ```

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path_on_grid, PathOptions};
use sgl::solver::problem::SglProblem;
use sgl::solver::strong::solve_path_strong;
use sgl::util::cli::{Args, OptSpec};

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "t-count", help: "path grid size", takes_value: true, default: Some("40") },
        OptSpec { name: "tau", help: "mixing parameter", takes_value: true, default: Some("0.2") },
        OptSpec { name: "seed", help: "dataset seed", takes_value: true, default: Some("42") },
    ]);
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: 300,
        group_size: 10,
        gamma1: 8,
        gamma2: 4,
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    let d = generate(&cfg);
    let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, args.get_f64("tau", 0.2));
    let t_count = args.get_usize("t-count", 40);
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 3.0, t_count);
    println!(
        "safe vs unsafe screening on synthetic n={} p={} ({} lambdas, tol 1e-8)\n",
        pb.n(),
        pb.p(),
        t_count
    );

    // GAP safe path (exact by construction).
    let opts = SolveOptions { tol: 1e-8, record_history: false, ..Default::default() };
    let gap_path = solve_path_on_grid(
        &pb,
        &lambdas,
        &PathOptions { delta: 3.0, t_count, solve: opts.clone() },
    );
    println!(
        "GAP safe             : {:>7.3}s  epochs={:>7}  (safety guaranteed, no re-solves)",
        gap_path.total_s,
        gap_path.total_epochs()
    );

    // Strong rules (unsafe): need KKT recovery.
    let strong_opts = SolveOptions { rule: RuleKind::None, ..opts.clone() };
    let (s_res, s_stats, s_secs) = solve_path_strong(&pb, &lambdas, &strong_opts);
    println!(
        "strong (KKT-checked) : {:>7.3}s  subsolves={} violations={} avg working set={:.1}/{} groups",
        s_secs,
        s_stats.subsolves,
        s_stats.violations,
        s_stats.kept_groups_initial as f64 / t_count as f64,
        pb.n_groups()
    );

    // Combination: strong working set, GAP safe inside each subsolve.
    let both_opts = SolveOptions { rule: RuleKind::GapSafe, ..opts };
    let (_, b_stats, b_secs) = solve_path_strong(&pb, &lambdas, &both_opts);
    println!(
        "strong + GAP safe    : {:>7.3}s  subsolves={} violations={}",
        b_secs, b_stats.subsolves, b_stats.violations
    );

    // Agreement check: strong results equal the exact path.
    let mut max_diff = 0.0_f64;
    for (s, e) in s_res.iter().zip(&gap_path.results) {
        for j in 0..pb.p() {
            max_diff = max_diff.max((s.beta[j] - e.beta[j]).abs());
        }
    }
    println!("\nmax |beta_strong - beta_gap_safe| over the whole path: {max_diff:.2e}");
    assert!(max_diff < 1e-3, "strong-rule path must match the exact path");
    println!("exactness preserved: the KKT loop makes the unsafe rule safe at extra solve cost.");
}
