//! Figure 1: dual unit balls of the Lasso, Group-Lasso and Sparse-Group
//! Lasso (G = {{1,2},{3}}, w = 1, τ = 1/2).
//!
//! Writes the sampled point clouds to `out/fig1_balls.csv` and prints the
//! Monte-Carlo volumes plus the Eq. 20 ⇔ Eq. 21 cross-check.
//!
//! ```bash
//! cargo run --release --example fig1_dual_balls -- --samples 200000
//! ```

use sgl::data::csvio::write_csv;
use sgl::experiments::fig1;
use sgl::util::cli::{Args, OptSpec};
use std::path::Path;

fn main() {
    let args = Args::parse_or_exit(&[
        OptSpec { name: "samples", help: "Monte-Carlo samples", takes_value: true, default: Some("100000") },
        OptSpec { name: "out", help: "output CSV", takes_value: true, default: Some("out/fig1_balls.csv") },
        OptSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("1") },
    ]);
    let n = args.get_usize("samples", 100_000);
    let res = fig1::run(n, args.get_u64("seed", 1));

    println!("Fig 1 — dual unit balls (G = {{{{1,2}},{{3}}}}, w=1, tau=1/2)");
    println!("  Monte-Carlo volumes over [-1.6, 1.6]^3 with {n} samples:");
    println!("    lasso  (tau=1.0, B_inf):        {:.4} (exact 8.0)", res.vol_lasso);
    println!(
        "    group  (tau=0.0, disc x seg):    {:.4} (exact 2*pi = {:.4})",
        res.vol_group_lasso,
        2.0 * std::f64::consts::PI
    );
    println!("    sgl    (tau=0.5):                {:.4} (between the two)", res.vol_sgl);
    println!(
        "  Eq. 21 vs Eq. 20 membership mismatches: {}",
        res.characterization_mismatches
    );

    let rows: Vec<Vec<f64>> = res
        .samples
        .iter()
        .map(|s| {
            vec![
                s.point[0],
                s.point[1],
                s.point[2],
                s.in_lasso as u8 as f64,
                s.in_group_lasso as u8 as f64,
                s.in_sgl as u8 as f64,
            ]
        })
        .collect();
    let out = args.get_or("out", "out/fig1_balls.csv");
    write_csv(Path::new(&out), &["x", "y", "z", "in_lasso", "in_group", "in_sgl"], &rows)
        .expect("write csv");
    println!("wrote {out} ({} rows)", rows.len());
}
