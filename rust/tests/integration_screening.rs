//! Screening-rule integration: safety of every rule along whole paths,
//! ordering of sphere quality, convergence of active sets (Prop. 6), and
//! failure-injection (screening must be a no-op when given garbage-free
//! but useless spheres, never an unsound one).

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::screening::{make_rule, ActiveSet, RuleKind};
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::duality::DualSnapshot;
use sgl::solver::path::{solve_path, PathOptions};
use sgl::solver::problem::SglProblem;
use sgl::util::proptest::{check, forall};

fn problem(tau: f64, seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 50,
        n_groups: 25,
        group_size: 4,
        gamma1: 4,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, tau)
}

/// The master safety test: along full paths, every variable any rule ever
/// screens is zero in an independent high-precision solution.
#[test]
fn all_rules_safe_along_path() {
    let pb = problem(0.3, 1);
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 2.0, 6);
    for rule in [
        RuleKind::Static,
        RuleKind::Dynamic,
        RuleKind::Dst3,
        RuleKind::GapSafe,
        RuleKind::GapSafeSeq,
    ] {
        for &lambda in &lambdas {
            let screened = solve(
                &pb,
                lambda,
                None,
                &SolveOptions { rule, tol: 1e-9, ..Default::default() },
            );
            let reference = solve(
                &pb,
                lambda,
                None,
                &SolveOptions { rule: RuleKind::None, tol: 1e-12, ..Default::default() },
            );
            for j in 0..pb.p() {
                if !screened.active.feature[j] {
                    assert!(
                        reference.beta[j].abs() < 1e-7,
                        "{rule:?} lambda={lambda:.3e} screened live feature {j} ({})",
                        reference.beta[j]
                    );
                }
            }
        }
    }
}

/// Sphere-quality ordering at matched iterates: GAP radius -> 0 while the
/// baselines stay bounded away (the paper's Fig. 2 mechanism).
#[test]
fn gap_radius_vanishes_baselines_do_not() {
    let pb = problem(0.3, 2);
    let lambda = 0.2 * pb.lambda_max();
    // Converge well, then ask each rule for its sphere.
    let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-12, ..Default::default() });
    let xb = pb.x.matvec(&res.beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let snap = DualSnapshot::compute(&pb, &res.beta, &rho, lambda);
    let radius_of = |kind: RuleKind| {
        make_rule(kind, &pb).sphere(&pb, lambda, &snap).map(|s| s.radius)
    };
    let gap_r = radius_of(RuleKind::GapSafe).unwrap();
    let static_r = radius_of(RuleKind::Static).unwrap();
    let dyn_r = radius_of(RuleKind::Dynamic).unwrap();
    let dst3_r = radius_of(RuleKind::Dst3).unwrap();
    assert!(gap_r < 1e-5, "GAP radius must vanish at convergence: {gap_r}");
    assert!(static_r > 1e-2, "static radius stays macroscopic: {static_r}");
    assert!(dyn_r > 1e-3, "dynamic radius converges to dist(y/lambda, theta_hat) > 0");
    assert!(dst3_r <= dyn_r + 1e-12, "DST3 refines dynamic");
}

/// Prop. 6: with the converging GAP spheres, the final active set contains
/// the true support and (at reasonable lambda) little else.
#[test]
fn active_set_converges_to_support() {
    let pb = problem(0.3, 3);
    let lambda = 0.15 * pb.lambda_max();
    let res = solve(
        &pb,
        lambda,
        None,
        &SolveOptions { rule: RuleKind::GapSafe, tol: 1e-12, ..Default::default() },
    );
    assert!(res.converged);
    let support: Vec<usize> =
        (0..pb.p()).filter(|&j| res.beta[j].abs() > 1e-10).collect();
    // (i) support is contained in the active set;
    for &j in &support {
        assert!(res.active.feature[j], "support feature {j} was screened");
    }
    // (ii) the active set is not vacuous nor everything.
    let n_active = res.active.n_active_features();
    assert!(n_active >= support.len());
    assert!(n_active < pb.p(), "screening should remove something");
}

/// Property test: random spheres that *contain* the true dual optimum never
/// screen support variables (Theorem 1 exercised directly).
#[test]
fn property_valid_spheres_are_safe() {
    let pb = problem(0.35, 4);
    let lambda = 0.25 * pb.lambda_max();
    let reference = solve(
        &pb,
        lambda,
        None,
        &SolveOptions { rule: RuleKind::None, tol: 1e-12, ..Default::default() },
    );
    let xb = pb.x.matvec(&reference.beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let snap = DualSnapshot::compute(&pb, &reference.beta, &rho, lambda);
    // theta_hat ~ snap.theta at tol 1e-12.
    forall("random valid spheres are safe", 60, |g| {
        // Random center near theta_hat, radius >= distance to theta_hat.
        let jitter: Vec<f64> = (0..pb.n()).map(|_| 0.01 * g.normal()).collect();
        let center: Vec<f64> =
            snap.theta.iter().zip(&jitter).map(|(t, j)| t + j).collect();
        let dist: f64 = jitter.iter().map(|v| v * v).sum::<f64>().sqrt();
        let radius = dist * g.f64_in(1.0..3.0) + 1e-12;
        let xt_center = pb.x.tmatvec(&center);
        let sphere = sgl::screening::Sphere { xt_center, radius };
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = reference.beta.clone();
        let mut rho2 = rho.clone();
        sgl::screening::apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho2);
        for j in 0..pb.p() {
            if reference.beta[j].abs() > 1e-8 {
                check(active.feature[j], &format!("screened support feature {j}"))?;
            }
        }
        Ok(())
    });
}

/// Paths with screening return identical objective values as without.
#[test]
fn screening_never_changes_the_answer() {
    let pb = problem(0.2, 5);
    let opts = |rule| PathOptions {
        delta: 2.0,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-10, record_history: false, ..Default::default() },
    };
    let base = solve_path(&pb, &opts(RuleKind::None));
    for rule in [
        RuleKind::Static,
        RuleKind::Dynamic,
        RuleKind::Dst3,
        RuleKind::GapSafe,
        RuleKind::GapSafeSeq,
    ] {
        let path = solve_path(&pb, &opts(rule));
        for (i, (a, b)) in base.results.iter().zip(&path.results).enumerate() {
            for j in 0..pb.p() {
                assert!(
                    (a.beta[j] - b.beta[j]).abs() < 1e-4,
                    "{rule:?} lambda {i} feature {j}: {} vs {}",
                    a.beta[j],
                    b.beta[j]
                );
            }
        }
    }
}

/// GAP safe screens at least as much as every baseline at the end of each
/// solve (converging spheres dominate).
#[test]
fn gap_safe_dominates_at_convergence() {
    let pb = problem(0.3, 6);
    for frac in [0.6, 0.3, 0.1] {
        let lambda = frac * pb.lambda_max();
        let actives: Vec<usize> = [
            RuleKind::Static,
            RuleKind::Dynamic,
            RuleKind::Dst3,
            RuleKind::GapSafe,
        ]
        .iter()
        .map(|&rule| {
            solve(&pb, lambda, None, &SolveOptions { rule, tol: 1e-10, ..Default::default() })
                .active
                .n_active_features()
        })
        .collect();
        let gap_active = actives[3];
        for (i, &a) in actives[..3].iter().enumerate() {
            assert!(
                gap_active <= a,
                "frac={frac}: GAP {gap_active} vs baseline#{i} {a}"
            );
        }
    }
}
