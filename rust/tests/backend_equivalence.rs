//! Cross-backend and cross-solver equivalence properties.
//!
//! The `Design` abstraction promises that the dense and CSC backends are
//! *the same solver* on the same data — identical screening decisions,
//! objectives agreeing to rounding error — and that ISTA/FISTA driving
//! the shared active-set core follow the sequential GAP-safe rule exactly
//! like CD does. These tests pin both promises on planted random
//! problems across several seeds.

use sgl::data::sparse::{self, SparseSyntheticConfig};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design, Matrix};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::path::{solve_path_on_grid, solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;

/// A sparse planted problem with unit-norm `y`, in both backends.
fn backend_pair(seed: u64) -> (SglProblem<CscMatrix>, SglProblem<Matrix>) {
    let cfg = SparseSyntheticConfig {
        n: 40,
        n_groups: 20,
        group_size: 4,
        density: 0.08,
        gamma1: 4,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = sparse::generate(&cfg);
    let y_norm = d.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.y.iter().map(|v| v / y_norm).collect();
    let dense = SglProblem::new(d.x.to_dense(), y.clone(), d.groups.clone(), 0.3);
    let csc = SglProblem::new(d.x, y, d.groups, 0.3);
    (csc, dense)
}

fn dense_objective(pb: &SglProblem, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r2: f64 = pb.y.iter().zip(&xb).map(|(yi, v)| (yi - v) * (yi - v)).sum();
    0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

#[test]
fn backends_make_identical_screening_decisions() {
    for seed in [101u64, 102, 103] {
        let (csc, dense) = backend_pair(seed);
        let lambda = 0.3 * dense.lambda_max();
        for rule in [RuleKind::GapSafe, RuleKind::Dst3] {
            let opts = SolveOptions { rule, tol: 1e-9, ..Default::default() };
            let a = solve(&dense, lambda, None, &opts);
            let b = solve(&csc, lambda, None, &opts);
            assert!(a.converged && b.converged, "seed {seed} {rule:?}");
            assert_eq!(
                a.active.feature, b.active.feature,
                "seed {seed} {rule:?}: feature masks diverge"
            );
            assert_eq!(
                a.active.group, b.active.group,
                "seed {seed} {rule:?}: group masks diverge"
            );
            let oa = dense_objective(&dense, lambda, &a.beta);
            let ob = dense_objective(&dense, lambda, &b.beta);
            assert!(
                (oa - ob).abs() <= 1e-10,
                "seed {seed} {rule:?}: objectives {oa} vs {ob}"
            );
        }
    }
}

#[test]
fn csc_screening_is_safe_against_dense_reference() {
    let (csc, dense) = backend_pair(104);
    let lambda = 0.25 * dense.lambda_max();
    let reference = solve(
        &dense,
        lambda,
        None,
        &SolveOptions { rule: RuleKind::None, tol: 1e-12, ..Default::default() },
    );
    for rule in RuleKind::all() {
        let opts = SolveOptions { rule, tol: 1e-10, ..Default::default() };
        let res = solve(&csc, lambda, None, &opts);
        assert!(res.converged, "{rule:?}");
        for j in 0..csc.p() {
            if !res.active.feature[j] {
                assert!(
                    reference.beta[j].abs() < 1e-6,
                    "{rule:?} screened live feature {j} on the CSC backend"
                );
            }
        }
    }
}

#[test]
fn csc_path_matches_dense_path_with_sequential_rule() {
    let (csc, dense) = backend_pair(105);
    let lambdas = lambda_grid(dense.lambda_max(), 2.0, 8);
    let opts = PathOptions {
        delta: 2.0,
        t_count: lambdas.len(),
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-9,
            record_history: false,
            ..Default::default()
        },
    };
    let pd = solve_path_on_grid(&dense, &lambdas, &opts);
    let ps = solve_path_on_grid(&csc, &lambdas, &opts);
    assert!(pd.all_converged() && ps.all_converged());
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = dense_objective(&dense, lambda, &pd.results[i].beta);
        let b = dense_objective(&dense, lambda, &ps.results[i].beta);
        assert!((a - b).abs() <= 1e-7, "grid point {i}: {a} vs {b}");
    }
}

#[test]
fn ista_and_fista_seq_paths_match_cd_objectives() {
    // Unit-norm y planted dense problem: tol 1e-8 is then an absolute gap
    // bound, so per-solver objectives sit within 1e-8 of the optimum and
    // within 2e-8 of each other — comfortably inside the 1e-7 budget.
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed: 21,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    let pb = SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.25);
    let lambdas = lambda_grid(pb.lambda_max(), 1.5, 6);
    let opts = PathOptions {
        delta: 1.5,
        t_count: lambdas.len(),
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-8,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    };
    let cd_path = solve_path_with(&pb, &lambdas, &opts, SolverKind::Cd);
    assert!(cd_path.all_converged());
    for solver in [SolverKind::Ista, SolverKind::Fista] {
        let path = solve_path_with(&pb, &lambdas, &opts, solver);
        assert!(path.all_converged(), "{solver:?}");
        for (i, &lambda) in lambdas.iter().enumerate() {
            let a = dense_objective(&pb, lambda, &cd_path.results[i].beta);
            let b = dense_objective(&pb, lambda, &path.results[i].beta);
            assert!(
                (a - b).abs() <= 1e-7,
                "{solver:?} grid point {i}: {a} vs {b}"
            );
        }
    }
}

#[test]
fn csc_density_reporting_is_consistent() {
    let (csc, dense) = backend_pair(106);
    assert_eq!(csc.p(), dense.p());
    assert_eq!(csc.n(), dense.n());
    // from_dense(to_dense) round-trips the structure.
    assert_eq!(CscMatrix::from_dense(&dense.x).nnz(), csc.x.nnz());
    assert!(csc.x.density() < 0.2);
}
