//! Cross-module integration for the logistic datafit: every solver
//! reaches the same sparse-group logistic optimum on both backends, the
//! GAP safe rules are *safe* (never change the answer) on the logistic
//! path, λ-sharding is bit-identical to the monolithic path, and a mixed
//! regression+classification batch over a loopback fleet matches the
//! local engine bit for bit.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet, WorkerServer};
use sgl::coordinator::service::AnyProblem;
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::datafit::{Datafit, Logistic};
use sgl::solver::fista::solve_fista;
use sgl::solver::ista::solve_ista;
use sgl::solver::path::{solve_path, solve_path_on_grid, DualHandoff, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::sync::Arc;

/// Synthetic design with the response binarized at its mean — the same
/// construction the CLI uses for `--datafit logistic`.
fn logistic_problem(tau: f64, seed: u64) -> SglProblem<sgl::linalg::Matrix, Logistic> {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    let mean = d.dataset.y.iter().sum::<f64>() / d.dataset.y.len() as f64;
    let labels: Vec<f64> = d.dataset.y.iter().map(|&v| f64::from(v > mean)).collect();
    let weights = d.dataset.groups.sqrt_size_weights();
    SglProblem::with_datafit(d.dataset.x, labels, d.dataset.groups, tau, weights, Logistic)
}

fn csc_twin(pb: &SglProblem<sgl::linalg::Matrix, Logistic>) -> SglProblem<CscMatrix, Logistic> {
    SglProblem::with_datafit(
        CscMatrix::from_dense(&pb.x),
        pb.y.clone(),
        pb.groups.clone(),
        pb.tau,
        pb.weights.clone(),
        Logistic,
    )
}

/// Primal sparse-group logistic objective: Σ softplus(xᵢᵀβ) − yᵢ xᵢᵀβ
/// plus the λΩ penalty, evaluated from scratch.
fn objective<D: Design>(pb: &SglProblem<D, Logistic>, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    pb.datafit.loss(&pb.y, &xb, beta) + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

#[test]
fn logistic_cd_ista_fista_agree_on_dense_and_csc() {
    let dense = logistic_problem(0.25, 1);
    let csc = csc_twin(&dense);
    let lambda = 0.2 * dense.lambda_max();
    let opts = SolveOptions { tol: 1e-10, max_epochs: 500_000, ..Default::default() };

    let mut objectives = Vec::new();
    for res in [
        solve(&dense, lambda, None, &opts),
        solve_ista(&dense, lambda, None, &opts),
        solve_fista(&dense, lambda, None, &opts),
    ] {
        assert!(res.converged);
        objectives.push(objective(&dense, lambda, &res.beta));
    }
    for res in [
        solve(&csc, lambda, None, &opts),
        solve_ista(&csc, lambda, None, &opts),
        solve_fista(&csc, lambda, None, &opts),
    ] {
        assert!(res.converged);
        objectives.push(objective(&csc, lambda, &res.beta));
    }
    let lo = objectives.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = objectives.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    assert!(
        hi - lo <= 1e-8,
        "solver x backend objectives spread {:.2e}: {objectives:?}",
        hi - lo
    );
}

#[test]
fn logistic_lambda_max_yields_the_zero_solution() {
    let pb = logistic_problem(0.3, 2);
    let lmax = pb.lambda_max();
    for lambda in [lmax, 2.0 * lmax] {
        let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-10, ..Default::default() });
        assert!(res.converged);
        assert!(
            res.beta.iter().all(|&b| b == 0.0),
            "lambda={lambda}: beta must be exactly zero at/above lambda_max"
        );
    }
}

#[test]
fn logistic_gap_safe_seq_path_converges_with_decreasing_gaps() {
    let pb = logistic_problem(0.2, 3);
    let tol = 1e-8;
    let opts = PathOptions {
        delta: 1.5,
        t_count: 8,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol,
            fce: 1,
            max_epochs: 500_000,
            record_history: true,
            ..Default::default()
        },
    };
    let path = solve_path(&pb, &opts);
    assert!(path.all_converged());
    let scale = pb.datafit.gap_scale(&pb.y);
    for (t, res) in path.results.iter().enumerate() {
        assert!(res.gap <= tol * scale, "t={t}: final gap {:.2e}", res.gap);
        assert!(res.history.iter().all(|c| c.gap >= 0.0), "t={t}: negative gap");
        if let (Some(first), Some(last)) = (res.history.first(), res.history.last()) {
            assert!(
                last.gap <= first.gap,
                "t={t}: gap did not decrease: {} -> {}",
                first.gap,
                last.gap
            );
        }
    }
    // Past the first grid point the sphere must reject something: a
    // logistic path on which screening never fires would make the GAP
    // rule vacuous here.
    assert!(
        path.results[1..].iter().any(|r| r.active.n_active_features() < pb.p()),
        "GAP safe screening never fired on the logistic path"
    );
}

#[test]
fn gap_safe_rules_never_change_the_logistic_answer() {
    let pb = logistic_problem(0.2, 4);
    let opts = |rule| PathOptions {
        delta: 1.5,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-10, record_history: false, ..Default::default() },
    };
    let base = solve_path(&pb, &opts(RuleKind::None));
    assert!(base.all_converged());
    for rule in [RuleKind::GapSafe, RuleKind::GapSafeSeq] {
        let path = solve_path(&pb, &opts(rule));
        assert!(path.all_converged(), "{rule:?}");
        for (i, (a, b)) in base.results.iter().zip(&path.results).enumerate() {
            for j in 0..pb.p() {
                assert!(
                    (a.beta[j] - b.beta[j]).abs() < 1e-4,
                    "{rule:?} lambda {i} feature {j}: {} vs {}",
                    a.beta[j],
                    b.beta[j]
                );
            }
        }
    }
}

#[test]
fn sharded_logistic_path_is_bit_identical_to_monolithic() {
    let pb = csc_twin(&logistic_problem(0.2, 5));
    let lambdas = lambda_grid(pb.lambda_max(), 1.5, 8);
    let opts = PathOptions {
        delta: 1.5,
        t_count: 8,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-8,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    };
    let mono = solve_path_on_grid(&pb, &lambdas, &opts);
    assert!(mono.all_converged());
    for k in [2usize, 3, 8] {
        let sharded = solve_path_sharded(&pb, &lambdas, &opts, SolverKind::Cd, k);
        assert_eq!(mono.lambdas, sharded.lambdas, "k={k}");
        for (t, (a, b)) in mono.results.iter().zip(&sharded.results).enumerate() {
            assert_eq!(a.beta, b.beta, "k={k} t={t}: beta must be bit-identical");
            assert_eq!(a.active.feature, b.active.feature, "k={k} t={t}");
            assert_eq!(a.epochs, b.epochs, "k={k} t={t}");
            assert_eq!(a.converged, b.converged, "k={k} t={t}");
        }
    }
}

/// The tentpole serving claim: one fleet serves least-squares and
/// logistic jobs side by side, and every result is bit-identical to the
/// local sharded engine.
#[test]
fn mixed_datafit_batch_over_loopback_fleet_matches_local() {
    let metrics = Arc::new(Metrics::new());
    let servers: Vec<WorkerServer> =
        (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
        .expect("connect fleet");

    let dense_log = Arc::new(logistic_problem(0.2, 6));
    let csc_log = Arc::new(csc_twin(&dense_log));
    let quad = {
        let cfg = SyntheticConfig {
            n: 60,
            n_groups: 30,
            group_size: 4,
            gamma1: 5,
            gamma2: 2,
            seed: 6,
            ..Default::default()
        };
        let d = generate(&cfg);
        Arc::new(SglProblem::new(
            CscMatrix::from_dense(&d.dataset.x),
            d.dataset.y,
            d.dataset.groups,
            0.2,
        ))
    };

    let opts = |rule: RuleKind| PathOptions {
        delta: 1.2,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
    };
    let jobs = vec![
        InterleavedJob {
            pb: AnyProblem::Csc(quad.clone()),
            lambdas: lambda_grid(quad.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "quadratic/csc".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscLogistic(csc_log.clone()),
            lambdas: lambda_grid(csc_log.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "logistic/csc".into(),
        },
        InterleavedJob {
            pb: AnyProblem::DenseLogistic(dense_log.clone()),
            lambdas: lambda_grid(dense_log.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafe),
            solver: SolverKind::Cd,
            shards: 2,
            label: "logistic/dense".into(),
        },
    ];

    let out = solve_batch_interleaved(&jobs, fleet.capacity(), |job, grid, h: Option<&DualHandoff>| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        let want = match &job.pb {
            AnyProblem::Dense(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::Csc(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
        };
        assert_eq!(got.lambdas, want.lambdas, "{}", job.label);
        for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
            assert_eq!(a.beta, b.beta, "{} t={t}: bit-identical over the fleet", job.label);
            assert_eq!(a.active.feature, b.active.feature, "{} t={t}", job.label);
            assert_eq!(a.epochs, b.epochs, "{} t={t}", job.label);
        }
    }
    assert_eq!(metrics.counter("fleet_shards_solved"), 8);
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    assert_eq!(fleet.in_flight(), 0);
}
