//! Cross-cutting property tests of the mathematical invariants the paper
//! relies on, at integration level (random group structures, not the
//! per-module fixtures).

use sgl::norms::epsilon::{epsilon_dual_norm, epsilon_norm, lambda};
use sgl::norms::prox::{group_soft_threshold, soft_threshold_vec};
use sgl::norms::sgl::{epsilon_g, in_dual_unit_ball, omega, omega_dual};
use sgl::solver::groups::Groups;
use sgl::util::proptest::{check, check_close, forall, Gen};

fn random_groups(g: &mut Gen) -> Groups {
    let n_groups = g.usize_in(1..6);
    let sizes: Vec<usize> = (0..n_groups).map(|_| g.usize_in(1..7)).collect();
    Groups::from_sizes(&sizes)
}

#[test]
fn omega_is_a_norm() {
    forall("omega: norm axioms", 150, |g| {
        let groups = random_groups(g);
        let w = groups.sqrt_size_weights();
        let tau = g.f64_in(0.0..1.0);
        let p = groups.p();
        let x: Vec<f64> = (0..p).map(|_| g.normal()).collect();
        let y: Vec<f64> = (0..p).map(|_| g.normal()).collect();
        let c = g.f64_in(0.1..5.0);
        // homogeneity
        let cx: Vec<f64> = x.iter().map(|v| c * v).collect();
        check_close(
            omega(&cx, &groups, tau, &w),
            c * omega(&x, &groups, tau, &w),
            1e-9,
            "homogeneity",
        )?;
        // triangle inequality
        let xy: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        check(
            omega(&xy, &groups, tau, &w)
                <= omega(&x, &groups, tau, &w) + omega(&y, &groups, tau, &w) + 1e-9,
            "triangle",
        )?;
        // positivity
        check(omega(&x, &groups, tau, &w) >= 0.0, "nonneg")
    });
}

#[test]
fn dual_norm_is_dual() {
    // Omega^D(xi) = max over the omega-unit ball of <beta, xi>: verify the
    // sup is attained within tolerance by projected-gradient search and
    // never exceeded by random candidates.
    forall("dual norm dominates random candidates", 120, |g| {
        let groups = random_groups(g);
        let w = groups.sqrt_size_weights();
        let tau = g.f64_in(0.05..0.95);
        let p = groups.p();
        let xi: Vec<f64> = (0..p).map(|_| g.normal()).collect();
        let dn = omega_dual(&xi, &groups, tau, &w);
        for _ in 0..10 {
            let cand: Vec<f64> = (0..p).map(|_| g.normal()).collect();
            let norm = omega(&cand, &groups, tau, &w);
            if norm == 0.0 {
                continue;
            }
            let ip: f64 =
                cand.iter().zip(&xi).map(|(a, b)| a * b).sum::<f64>().abs() / norm;
            check(ip <= dn * (1.0 + 1e-9) + 1e-12, &format!("{ip} > {dn}"))?;
        }
        Ok(())
    });
}

#[test]
fn lambda_is_monotone_in_alpha_and_r() {
    // Lambda(x, alpha, R) decreases when alpha or R increase (thresholding
    // harder / allowing a bigger rhs shrinks the root).
    forall("Lambda monotonicity", 150, |g| {
        let x = g.vec_normal(1..20);
        if x.iter().all(|&v| v == 0.0) {
            return Ok(());
        }
        let a1 = g.f64_in(0.05..0.9);
        let a2 = a1 + g.f64_in(0.01..(1.0 - a1));
        let r1 = g.f64_in(0.05..1.5);
        let r2 = r1 + g.f64_in(0.01..1.0);
        let base = lambda(&x, a1, r1);
        check(lambda(&x, a2, r1) <= base * (1.0 + 1e-9), "monotone in alpha")?;
        check(lambda(&x, a1, r2) <= base * (1.0 + 1e-9), "monotone in R")
    });
}

#[test]
fn epsilon_norm_sandwich() {
    // max(||x||_inf, eps*||x||_2)-ish bounds: ||x||_eps >= ||x||_inf and
    // ||x||_eps >= ||x||_2 ... actually ||x||_eps interpolates:
    // ||x||_inf <= ||x||_eps (eps<1 side) and ||x||_2 <= d-dependent bound.
    forall("epsilon-norm sandwich", 150, |g| {
        let x = g.vec_normal(1..20);
        if x.iter().all(|&v| v == 0.0) {
            return Ok(());
        }
        let eps = g.f64_in(0.0..1.0);
        let ne = epsilon_norm(&x, eps);
        let inf = x.iter().fold(0.0_f64, |m, v| m.max(v.abs()));
        let l2: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        check(ne >= inf - 1e-9, "||x||_eps >= ||x||_inf")?;
        check(ne >= l2.min(inf) - 1e-9, "||x||_eps above the min")?;
        check(ne <= inf + l2 + 1e-9, "||x||_eps <= ||x||_inf + ||x||_2")
    });
}

#[test]
fn dual_scaling_lands_on_ball_boundary_or_interior() {
    forall("xi / Omega^D(xi) in the dual unit ball", 120, |g| {
        let groups = random_groups(g);
        let w = groups.sqrt_size_weights();
        let tau = g.f64_in(0.0..1.0);
        let p = groups.p();
        let xi: Vec<f64> = (0..p).map(|_| g.normal() * 3.0).collect();
        let dn = omega_dual(&xi, &groups, tau, &w);
        if dn == 0.0 {
            return Ok(());
        }
        let scaled: Vec<f64> = xi.iter().map(|v| v / dn).collect();
        check(
            in_dual_unit_ball(&scaled, &groups, tau, &w, 1e-9),
            "scaled point must be feasible",
        )
    });
}

#[test]
fn epsilon_dual_consistency_with_group_scaling() {
    // The SGL dual norm of a vector supported on ONE group reduces to the
    // per-group epsilon-norm formula (Eq. 20).
    forall("single-group dual norm", 120, |g| {
        let groups = random_groups(g);
        let w = groups.sqrt_size_weights();
        let tau = g.f64_in(0.05..0.95);
        let p = groups.p();
        let target = g.usize_in(0..groups.n_groups());
        let mut xi = vec![0.0; p];
        let (a, b) = groups.bounds(target);
        for v in xi[a..b].iter_mut() {
            *v = g.normal();
        }
        let eps = epsilon_g(tau, w[target]);
        let expect = lambda(&xi[a..b], 1.0 - eps, eps) / (tau + (1.0 - tau) * w[target]);
        check_close(omega_dual(&xi, &groups, tau, &w), expect, 1e-9, "Eq. 20")
    });
}

#[test]
fn soft_thresholds_shrink() {
    forall("thresholding shrinks norms", 150, |g| {
        let x = g.vec_normal(1..15);
        let t = g.f64_in(0.0..2.0);
        let st = soft_threshold_vec(&x, t);
        let gt = group_soft_threshold(&x, t);
        let n = |v: &[f64]| v.iter().map(|a| a * a).sum::<f64>().sqrt();
        check(n(&st) <= n(&x) + 1e-12, "S_t shrinks l2")?;
        check(n(&gt) <= n(&x) + 1e-12, "S^gp shrinks l2")?;
        for i in 0..x.len() {
            check(st[i].abs() <= x[i].abs() + 1e-12, "coordinatewise")?;
            check(
                st[i] * x[i] >= 0.0 && gt[i] * x[i] >= 0.0,
                "signs preserved",
            )?;
        }
        Ok(())
    });
}

#[test]
fn epsilon_dual_norm_is_dual_of_epsilon_norm() {
    // <x, y> <= ||x||_eps ||y||_eps^D with near-tightness over random
    // search (Lemma 4).
    forall("epsilon duality", 120, |g| {
        let n = g.usize_in(1..12);
        let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let eps = g.f64_in(0.05..0.95);
        let dual = epsilon_dual_norm(&y, eps);
        let mut best = 0.0_f64;
        for _ in 0..30 {
            let x: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let ne = epsilon_norm(&x, eps);
            if ne == 0.0 {
                continue;
            }
            let ip: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>().abs() / ne;
            check(ip <= dual * (1.0 + 1e-9) + 1e-12, "duality bound")?;
            best = best.max(ip);
        }
        // Random search should get within a factor ~3 of the sup (sanity
        // that the bound is not vacuous).
        if dual > 1e-9 {
            check(best >= dual / 5.0, &format!("bound too loose: {best} vs {dual}"))?;
        }
        Ok(())
    });
}
