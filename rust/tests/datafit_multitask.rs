//! Cross-module integration for the multi-task quadratic datafit: at
//! q = 1 the matrix-valued engine is *bit-identical* to the scalar
//! `Quadratic` one (β, gaps, screen masks) on both backends and all
//! three solvers, the GAP safe rules are *safe* (never change the
//! answer) on a q = 5 path, λ-sharding is bit-identical to the
//! monolithic path, and a mixed quadratic + logistic + multi-task batch
//! over a loopback fleet matches the local engine bit for bit.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet, WorkerServer};
use sgl::coordinator::service::AnyProblem;
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::data::synthetic::{generate, generate_multitask, SyntheticConfig};
use sgl::linalg::{CscMatrix, Matrix};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::datafit::{Logistic, MultiTaskQuadratic};
use sgl::solver::path::{solve_path, solve_path_on_grid, DualHandoff, PathOptions, PathResult};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::sync::Arc;

fn synth_cfg(seed: u64) -> SyntheticConfig {
    SyntheticConfig {
        n: 40,
        n_groups: 20,
        group_size: 4,
        gamma1: 4,
        gamma2: 2,
        seed,
        ..Default::default()
    }
}

/// Planted multi-response problem on the dense backend (the same
/// construction the CLI uses for `--datafit multitask` on synthetic
/// data).
fn mt_problem(tau: f64, seed: u64, q: usize) -> SglProblem<Matrix, MultiTaskQuadratic> {
    let d = generate_multitask(&synth_cfg(seed), q);
    let weights = d.dataset.groups.sqrt_size_weights();
    SglProblem::with_datafit(
        d.dataset.x,
        d.dataset.y,
        d.dataset.groups,
        tau,
        weights,
        MultiTaskQuadratic::new(q),
    )
}

fn csc_mt(pb: &SglProblem<Matrix, MultiTaskQuadratic>) -> SglProblem<CscMatrix, MultiTaskQuadratic> {
    SglProblem::with_datafit(
        CscMatrix::from_dense(&pb.x),
        pb.y.clone(),
        pb.groups.clone(),
        pb.tau,
        pb.weights.clone(),
        MultiTaskQuadratic::new(pb.tasks()),
    )
}

/// Binarized-at-mean logistic problem (mirrors `datafit_logistic.rs`),
/// for the mixed fleet batch.
fn logistic_problem(tau: f64, seed: u64) -> SglProblem<CscMatrix, Logistic> {
    let d = generate(&synth_cfg(seed));
    let mean = d.dataset.y.iter().sum::<f64>() / d.dataset.y.len() as f64;
    let labels: Vec<f64> = d.dataset.y.iter().map(|&v| f64::from(v > mean)).collect();
    let weights = d.dataset.groups.sqrt_size_weights();
    SglProblem::with_datafit(
        CscMatrix::from_dense(&d.dataset.x),
        labels,
        d.dataset.groups,
        tau,
        weights,
        Logistic,
    )
}

/// The strongest equality on offer: every β coefficient, final gap,
/// screen mask and epoch count identical down to the bit pattern.
fn assert_paths_bitwise(a: &PathResult, b: &PathResult, what: &str) {
    assert_eq!(a.lambdas.len(), b.lambdas.len(), "{what}: grid length");
    for (t, (ra, rb)) in a.results.iter().zip(&b.results).enumerate() {
        assert_eq!(ra.beta.len(), rb.beta.len(), "{what} t={t}: beta length");
        for (j, (x, y)) in ra.beta.iter().zip(&rb.beta).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} t={t} j={j}: {x} vs {y}");
        }
        assert_eq!(ra.gap.to_bits(), rb.gap.to_bits(), "{what} t={t}: gap bits");
        assert_eq!(ra.active.feature, rb.active.feature, "{what} t={t}: feature mask");
        assert_eq!(ra.active.group, rb.active.group, "{what} t={t}: group mask");
        assert_eq!(ra.epochs, rb.epochs, "{what} t={t}: epoch count");
        assert_eq!(ra.converged, rb.converged, "{what} t={t}: converged flag");
    }
}

/// The q = 1 contract: `MultiTaskQuadratic::new(1)` is an *extraction*
/// of the scalar engine, not an approximation of it — β, gaps and
/// screen masks agree bit for bit on both backends and all three
/// solvers.
#[test]
fn q1_multitask_is_bit_identical_to_scalar_quadratic() {
    let d = generate(&synth_cfg(11));
    let tau = 0.3;
    let scalar = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, tau);
    let mt = SglProblem::with_datafit(
        scalar.x.clone(),
        scalar.y.clone(),
        scalar.groups.clone(),
        tau,
        scalar.weights.clone(),
        MultiTaskQuadratic::new(1),
    );
    assert_eq!(
        scalar.lambda_max().to_bits(),
        mt.lambda_max().to_bits(),
        "lambda_max bits"
    );
    let scalar_csc = SglProblem::new(
        CscMatrix::from_dense(&scalar.x),
        scalar.y.clone(),
        scalar.groups.clone(),
        tau,
    );
    let mt_csc = csc_mt(&mt);

    let lambdas = lambda_grid(scalar.lambda_max(), 1.3, 6);
    let opts = |rule| PathOptions {
        delta: 1.3,
        t_count: 6,
        solve: SolveOptions {
            rule,
            tol: 1e-8,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    };
    // Every solver on the GAP safe sequential path, both backends.
    for solver in [SolverKind::Cd, SolverKind::Ista, SolverKind::Fista] {
        let o = opts(RuleKind::GapSafeSeq);
        assert_paths_bitwise(
            &solve_path_sharded(&scalar, &lambdas, &o, solver, 1),
            &solve_path_sharded(&mt, &lambdas, &o, solver, 1),
            &format!("dense/{solver:?}"),
        );
        assert_paths_bitwise(
            &solve_path_sharded(&scalar_csc, &lambdas, &o, solver, 1),
            &solve_path_sharded(&mt_csc, &lambdas, &o, solver, 1),
            &format!("csc/{solver:?}"),
        );
    }
    // Every screening rule on the CD path: identical spheres, identical
    // rejections.
    for rule in RuleKind::all() {
        let o = opts(rule);
        assert_paths_bitwise(
            &solve_path_sharded(&scalar, &lambdas, &o, SolverKind::Cd, 1),
            &solve_path_sharded(&mt, &lambdas, &o, SolverKind::Cd, 1),
            &format!("dense/{rule:?}"),
        );
    }
}

#[test]
fn sharded_multitask_path_is_bit_identical_to_monolithic() {
    let pb = csc_mt(&mt_problem(0.25, 12, 3));
    let lambdas = lambda_grid(pb.lambda_max(), 1.4, 8);
    let opts = PathOptions {
        delta: 1.4,
        t_count: 8,
        solve: SolveOptions {
            rule: RuleKind::GapSafeSeq,
            tol: 1e-8,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    };
    let mono = solve_path_on_grid(&pb, &lambdas, &opts);
    assert!(mono.all_converged());
    for k in [2usize, 4] {
        let sharded = solve_path_sharded(&pb, &lambdas, &opts, SolverKind::Cd, k);
        assert_eq!(mono.lambdas, sharded.lambdas, "k={k}");
        assert_paths_bitwise(&mono, &sharded, &format!("k={k}"));
    }
}

/// Safety on a genuinely matrix-valued problem: a q = 5 path solved
/// with each sphere matches the unscreened baseline coefficient for
/// coefficient — and the spheres are not vacuous (screening fires).
#[test]
fn gap_safe_rules_never_change_the_multitask_answer() {
    let pb = mt_problem(0.3, 13, 5);
    let opts = |rule| PathOptions {
        delta: 1.5,
        t_count: 6,
        solve: SolveOptions {
            rule,
            tol: 1e-10,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    };
    let base = solve_path(&pb, &opts(RuleKind::None));
    assert!(base.all_converged());
    let mut screened_somewhere = false;
    for rule in RuleKind::all() {
        if rule == RuleKind::None {
            continue;
        }
        let path = solve_path(&pb, &opts(rule));
        assert!(path.all_converged(), "{rule:?}");
        for (i, (a, b)) in base.results.iter().zip(&path.results).enumerate() {
            assert_eq!(a.beta.len(), b.beta.len(), "{rule:?} lambda {i}");
            for (j, (x, y)) in a.beta.iter().zip(&b.beta).enumerate() {
                assert!(
                    (x - y).abs() < 1e-4,
                    "{rule:?} lambda {i} coeff {j}: {x} vs {y}"
                );
            }
        }
        screened_somewhere |= path
            .results
            .iter()
            .any(|r| r.active.feature.iter().any(|&alive| !alive));
    }
    assert!(screened_somewhere, "no sphere ever rejected a feature on the q=5 path");
}

/// The tentpole serving claim: one fleet serves least-squares, logistic
/// and multi-task jobs side by side, and every result is bit-identical
/// to the local sharded engine.
#[test]
fn mixed_batch_with_multitask_over_loopback_fleet_matches_local() {
    let metrics = Arc::new(Metrics::new());
    let servers: Vec<WorkerServer> =
        (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
        .expect("connect fleet");

    let quad = {
        let d = generate(&synth_cfg(14));
        Arc::new(SglProblem::new(
            CscMatrix::from_dense(&d.dataset.x),
            d.dataset.y,
            d.dataset.groups,
            0.2,
        ))
    };
    let csc_log = Arc::new(logistic_problem(0.2, 15));
    let dense_mt = Arc::new(mt_problem(0.2, 16, 3));
    let csc_mt_pb = Arc::new(csc_mt(&dense_mt));

    let opts = |rule: RuleKind| PathOptions {
        delta: 1.2,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
    };
    let jobs = vec![
        InterleavedJob {
            pb: AnyProblem::Csc(quad.clone()),
            lambdas: lambda_grid(quad.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 2,
            label: "quadratic/csc".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscLogistic(csc_log.clone()),
            lambdas: lambda_grid(csc_log.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 2,
            label: "logistic/csc".into(),
        },
        InterleavedJob {
            pb: AnyProblem::DenseMultiTask(dense_mt.clone()),
            lambdas: lambda_grid(dense_mt.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafe),
            solver: SolverKind::Cd,
            shards: 2,
            label: "multitask/dense".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscMultiTask(csc_mt_pb.clone()),
            lambdas: lambda_grid(csc_mt_pb.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Fista,
            shards: 3,
            label: "multitask/csc".into(),
        },
    ];

    let out = solve_batch_interleaved(&jobs, fleet.capacity(), |job, grid, h: Option<&DualHandoff>| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        let want = match &job.pb {
            AnyProblem::Dense(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::Csc(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
        };
        assert_eq!(got.lambdas, want.lambdas, "{}", job.label);
        for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
            assert_eq!(a.beta, b.beta, "{} t={t}: bit-identical over the fleet", job.label);
            assert_eq!(a.active.feature, b.active.feature, "{} t={t}", job.label);
            assert_eq!(a.epochs, b.epochs, "{} t={t}", job.label);
        }
    }
    assert_eq!(metrics.counter("fleet_shards_solved"), 9);
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    assert_eq!(fleet.in_flight(), 0);
}
