//! Full-solver behaviour of the process-global kernel policy
//! (`linalg::simd::set_policy`, the `--kernels` flag's engine):
//!
//! - under `scalar` the solver is bit-reproducible run to run (the
//!   scalar branches *are* the historical kernels, so this pins the
//!   pre-SIMD trajectory);
//! - `simd` reaches the same objective within the duality-gap budget
//!   and makes the same terminal screening decisions — the reductions
//!   reassociate, so bit-identity across policies is *not* promised,
//!   objective agreement is.
//!
//! One `#[test]` on purpose: the policy is process-global (like
//! `SGL_THREADS`), so flipping it from concurrently running tests would
//! race. Everything here runs sequentially inside the single test.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design, KernelPolicy};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions, SolveResult};
use sgl::solver::problem::SglProblem;

fn planted() -> SglProblem {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 40,
        group_size: 5,
        gamma1: 6,
        gamma2: 3,
        seed: 5,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2)
}

fn objective<D: Design>(pb: &SglProblem<D>, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
    0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

fn assert_bit_identical(a: &SolveResult, b: &SolveResult, tag: &str) {
    assert_eq!(a.beta.len(), b.beta.len(), "{tag}: beta length");
    for (i, (x, y)) in a.beta.iter().zip(&b.beta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag}: beta[{i}] {x} vs {y}");
    }
    assert_eq!(a.epochs, b.epochs, "{tag}: epoch count");
    assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "{tag}: terminal gap");
}

#[test]
fn scalar_policy_is_reproducible_and_simd_agrees_on_the_objective() {
    let pb = planted();
    let pb_csc = SglProblem::new(
        CscMatrix::from_dense(&pb.x),
        pb.y.clone(),
        pb.groups.clone(),
        pb.tau,
    );
    let opts = SolveOptions {
        rule: RuleKind::GapSafe,
        tol: 5e-9,
        max_epochs: 500_000,
        record_history: false,
        ..Default::default()
    };
    let lambdas = [0.5 * pb.lambda_max(), 0.1 * pb.lambda_max()];

    for &lambda in &lambdas {
        // -- scalar: deterministic, run to run, on both backends.
        sgl::linalg::simd::set_policy(KernelPolicy::Scalar);
        let s1 = solve(&pb, lambda, None, &opts);
        let s2 = solve(&pb, lambda, None, &opts);
        assert!(s1.converged, "scalar dense converged");
        assert_bit_identical(&s1, &s2, "scalar dense rerun");
        let c1 = solve(&pb_csc, lambda, None, &opts);
        let c2 = solve(&pb_csc, lambda, None, &opts);
        assert_bit_identical(&c1, &c2, "scalar csc rerun");

        // -- simd: same solution quality, same support.
        sgl::linalg::simd::set_policy(KernelPolicy::Simd);
        let v = solve(&pb, lambda, None, &opts);
        assert!(v.converged, "simd dense converged");
        let obj_s = objective(&pb, lambda, &s1.beta);
        let obj_v = objective(&pb, lambda, &v.beta);
        // Both are within tol = 5e-9 of the optimum on a unit-norm y.
        assert!(
            (obj_s - obj_v).abs() <= 1e-8,
            "objective divergence at lambda={lambda}: {obj_s} vs {obj_v}"
        );
        assert_eq!(
            s1.active.group,
            v.active.group,
            "terminal group screening decisions differ at lambda={lambda}"
        );
        // And simd is itself deterministic.
        let v2 = solve(&pb, lambda, None, &opts);
        assert_bit_identical(&v, &v2, "simd dense rerun");
    }

    // Leave the process default in place for any later in-process use.
    sgl::linalg::simd::set_policy(KernelPolicy::Auto);
}
