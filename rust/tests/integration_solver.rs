//! Cross-module solver integration: CD vs ISTA vs paths vs special cases,
//! on realistically-sized problems built by the data generators.

use sgl::data::climate::{self, ClimateConfig};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::norms::sgl::omega;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::duality::duality_gap;
use sgl::solver::ista::solve_ista;
use sgl::solver::path::{solve_path, PathOptions};
use sgl::solver::problem::SglProblem;

fn synthetic_problem(tau: f64, seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 40,
        group_size: 5,
        gamma1: 6,
        gamma2: 3,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, tau)
}

#[test]
fn cd_and_ista_agree_on_synthetic() {
    let pb = synthetic_problem(0.25, 1);
    let lambda = 0.15 * pb.lambda_max();
    let opts = SolveOptions { tol: 1e-10, max_epochs: 500_000, ..Default::default() };
    let a = solve(&pb, lambda, None, &opts);
    let b = solve_ista(&pb, lambda, None, &opts);
    assert!(a.converged && b.converged);
    for j in 0..pb.p() {
        assert!((a.beta[j] - b.beta[j]).abs() < 5e-4, "feature {j}");
    }
}

#[test]
fn kkt_conditions_hold_at_solution() {
    // Subdifferential inclusion (Eq. 8): for beta_g != 0,
    // X_g^T rho = lambda (tau * sign + (1-tau) w_g beta_g/||beta_g||).
    let pb = synthetic_problem(0.4, 2);
    let lambda = 0.2 * pb.lambda_max();
    let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-12, ..Default::default() });
    assert!(res.converged);
    let xb = pb.x.matvec(&res.beta);
    let rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
    let corr = pb.x.tmatvec(&rho);
    for (g, a, b) in pb.groups.iter() {
        let bg = &res.beta[a..b];
        let ng = bg.iter().map(|v| v * v).sum::<f64>().sqrt();
        if ng == 0.0 {
            // Zero group: ||S_{tau*lambda}(X_g^T rho)|| <= lambda(1-tau)w_g.
            let st: Vec<f64> = corr[a..b]
                .iter()
                .map(|&c| {
                    let t = c.abs() - pb.tau * lambda;
                    if t > 0.0 {
                        t * c.signum()
                    } else {
                        0.0
                    }
                })
                .collect();
            let stn = st.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(
                stn <= lambda * (1.0 - pb.tau) * pb.weights[g] + 1e-6,
                "group {g} violates zero-block KKT: {stn}"
            );
            continue;
        }
        for (k, j) in (a..b).enumerate() {
            if bg[k] != 0.0 {
                let rhs = lambda
                    * (pb.tau * bg[k].signum()
                        + (1.0 - pb.tau) * pb.weights[g] * bg[k] / ng);
                assert!(
                    (corr[j] - rhs).abs() < 1e-5,
                    "feature {j}: corr {} vs rhs {rhs}",
                    corr[j]
                );
            } else {
                // Inactive coord of an active group: the l2 part is 0 here,
                // so |X_j^T rho| <= lambda * tau.
                assert!(corr[j].abs() <= lambda * pb.tau + 1e-6);
            }
        }
    }
}

#[test]
fn warm_path_equals_cold_solves_on_climate() {
    let mut data = climate::generate(&ClimateConfig::small(3));
    climate::preprocess(&mut data);
    let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, 0.4);
    let opts = PathOptions {
        delta: 1.5,
        t_count: 6,
        solve: SolveOptions { tol: 1e-9, record_history: false, ..Default::default() },
    };
    let path = solve_path(&pb, &opts);
    assert!(path.all_converged());
    for (i, &lambda) in path.lambdas.iter().enumerate() {
        let single = solve(&pb, lambda, None, &opts.solve);
        let obj = |beta: &[f64]| {
            let xb = pb.x.matvec(beta);
            let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
            0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
        };
        let obj_path = obj(&path.results[i].beta);
        let obj_single = obj(&single.beta);
        assert!(
            (obj_path - obj_single).abs() < 1e-6 * obj_single.abs().max(1.0),
            "lambda {i}: {obj_path} vs {obj_single}"
        );
    }
}

#[test]
fn tau_limits_match_dedicated_problems() {
    // tau=1 (lasso) and tau=0 (group lasso) run through the same machinery
    // and reach their own optima.
    for (tau, seed) in [(1.0, 4), (0.0, 5)] {
        let pb = synthetic_problem(tau, seed);
        let lambda = 0.3 * pb.lambda_max();
        let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-11, ..Default::default() });
        assert!(res.converged, "tau={tau}");
        let g = duality_gap(&pb, &res.beta, lambda);
        let tol_abs = 1e-11 * pb.y.iter().map(|v| v * v).sum::<f64>();
        assert!(g <= 2.0 * tol_abs, "tau={tau}: gap {g}");
    }
}

#[test]
fn solutions_get_denser_as_lambda_decreases() {
    let pb = synthetic_problem(0.2, 6);
    let opts = PathOptions {
        delta: 2.5,
        t_count: 10,
        solve: SolveOptions { tol: 1e-8, record_history: false, ..Default::default() },
    };
    let path = solve_path(&pb, &opts);
    let nnz: Vec<usize> = path
        .results
        .iter()
        .map(|r| r.beta.iter().filter(|&&b| b != 0.0).count())
        .collect();
    assert_eq!(nnz[0], 0, "zero solution at lambda_max");
    assert!(nnz[9] > nnz[1], "sparsity must decrease along the path: {nnz:?}");
}

#[test]
fn recovers_planted_groups_at_moderate_lambda() {
    let cfg = SyntheticConfig {
        n: 80,
        n_groups: 30,
        group_size: 5,
        gamma1: 3,
        gamma2: 3,
        noise: 0.01,
        seed: 7,
        ..Default::default()
    };
    let d = generate(&cfg);
    let truth = d.active_groups_true.clone();
    let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3);
    let lambda = 0.05 * pb.lambda_max();
    let res = solve(&pb, lambda, None, &SolveOptions { tol: 1e-9, ..Default::default() });
    assert!(res.converged);
    for &g in &truth {
        let (a, b) = pb.groups.bounds(g);
        let norm: f64 = res.beta[a..b].iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm > 0.1, "planted group {g} missing (norm {norm})");
    }
}

#[test]
fn solution_is_independent_of_fce() {
    // The gap-check frequency affects cost, never the answer.
    let pb = synthetic_problem(0.3, 8);
    let lambda = 0.2 * pb.lambda_max();
    let solve_at = |fce: usize| {
        solve(
            &pb,
            lambda,
            None,
            &SolveOptions { tol: 1e-10, fce, record_history: false, ..Default::default() },
        )
    };
    let base = solve_at(10);
    for fce in [1usize, 3, 25] {
        let res = solve_at(fce);
        assert!(res.converged, "fce={fce}");
        for j in 0..pb.p() {
            assert!(
                (res.beta[j] - base.beta[j]).abs() < 1e-5,
                "fce={fce} feature {j}"
            );
        }
    }
}

#[test]
fn fista_agrees_with_cd_on_larger_instance() {
    let pb = synthetic_problem(0.25, 9);
    let lambda = 0.12 * pb.lambda_max();
    let opts = SolveOptions { tol: 1e-10, max_epochs: 500_000, ..Default::default() };
    let a = solve(&pb, lambda, None, &opts);
    let f = sgl::solver::fista::solve_fista(&pb, lambda, None, &opts);
    assert!(a.converged && f.converged);
    for j in 0..pb.p() {
        assert!((a.beta[j] - f.beta[j]).abs() < 5e-4, "feature {j}");
    }
}
