//! Integration tests for the L4 layer: λ-shard equivalence against the
//! monolithic path engine (across backends, solvers and shard counts)
//! and the solve service's queue / result-store / cache semantics.

use sgl::coordinator::service::{
    AnyProblem, JobStatus, QueueFullError, ServiceConfig, SolveRequest, SolveService,
};
use sgl::coordinator::shard::solve_path_sharded;
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design, Matrix};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path, solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::sync::Arc;

/// Sized service config with default capacities.
fn svc_cfg(workers: usize, queue_depth: usize) -> ServiceConfig {
    ServiceConfig { workers, queue_depth, ..Default::default() }
}

/// Planted-sparse instance with unit-norm `y` (absolute objective budgets).
fn planted(seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2)
}

fn objective<D: Design>(pb: &SglProblem<D>, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
    0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

/// Sharded (k ∈ {2, 4}) must match monolithic: objectives to ≤ 1e-8 and
/// identical screening decisions at every λ.
fn check_shard_equivalence<D: Design>(
    pb: &SglProblem<D>,
    lambdas: &[f64],
    opts: &PathOptions,
    solver: SolverKind,
    tag: &str,
) {
    let mono = solve_path_with(pb, lambdas, opts, solver);
    for k in [2usize, 4] {
        let sharded = solve_path_sharded(pb, lambdas, opts, solver, k);
        assert_eq!(sharded.lambdas, mono.lambdas, "{tag} k={k}");
        assert_eq!(sharded.results.len(), mono.results.len(), "{tag} k={k}");
        for (t, (a, b)) in mono.results.iter().zip(&sharded.results).enumerate() {
            // Screening decisions are identical across the shard boundary.
            assert_eq!(a.active.feature, b.active.feature, "{tag} k={k} t={t}");
            assert_eq!(a.active.group, b.active.group, "{tag} k={k} t={t}");
            assert_eq!(a.epochs, b.epochs, "{tag} k={k} t={t}");
            for (x, y) in a.beta.iter().zip(&b.beta) {
                assert!((x - y).abs() <= 1e-10, "{tag} k={k} t={t}");
            }
            let oa = objective(pb, mono.lambdas[t], &a.beta);
            let ob = objective(pb, mono.lambdas[t], &b.beta);
            assert!(
                (oa - ob).abs() <= 1e-8,
                "{tag} k={k} t={t}: objectives {oa} vs {ob}"
            );
        }
    }
}

#[test]
fn sharded_paths_match_monolithic_dense_and_csc_across_solvers() {
    let pb_dense = planted(1);
    let pb_csc: SglProblem<CscMatrix> = SglProblem::new(
        CscMatrix::from_dense(&pb_dense.x),
        pb_dense.y.clone(),
        pb_dense.groups.clone(),
        pb_dense.tau,
    );
    for solver in SolverKind::all() {
        // The equivalence is bit-level whatever the tolerance (both sides
        // run the same arithmetic), so the slow full-gradient solvers get
        // a shallower, looser path to keep debug-profile test time sane.
        let (delta, t_count, tol) = match solver {
            SolverKind::Cd => (1.0, 8, 1e-8),
            _ => (0.8, 5, 1e-7),
        };
        let lambdas = lambda_grid(pb_dense.lambda_max(), delta, t_count);
        let opts = PathOptions {
            delta,
            t_count,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol,
                max_epochs: 500_000,
                record_history: false,
                ..Default::default()
            },
        };
        check_shard_equivalence(
            &pb_dense,
            &lambdas,
            &opts,
            solver,
            &format!("dense/{}", solver.name()),
        );
        check_shard_equivalence(
            &pb_csc,
            &lambdas,
            &opts,
            solver,
            &format!("csc/{}", solver.name()),
        );
    }
}

#[test]
fn sharding_is_rule_agnostic() {
    // Every rule's cross-λ state factors through `on_solve_complete`
    // (GapSafeSeq) or is derived from the problem alone (the rest), so
    // the boundary is invisible whichever rule runs the path.
    let pb = planted(2);
    let lambdas = lambda_grid(pb.lambda_max(), 1.5, 9);
    for rule in [
        RuleKind::None,
        RuleKind::Static,
        RuleKind::Dynamic,
        RuleKind::Dst3,
        RuleKind::GapSafe,
    ] {
        let opts = PathOptions {
            delta: 1.5,
            t_count: lambdas.len(),
            solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
        };
        check_shard_equivalence(&pb, &lambdas, &opts, SolverKind::Cd, rule.name());
    }
}

fn dense_req(pb: &Arc<SglProblem<Matrix>>, rule: RuleKind, tol: f64) -> SolveRequest {
    SolveRequest {
        label: format!("{}@{tol:.0e}", rule.name()),
        ..SolveRequest::new(
            AnyProblem::Dense(pb.clone()),
            PathOptions {
                delta: 1.5,
                t_count: 8,
                solve: SolveOptions { tol, rule, record_history: false, ..Default::default() },
            },
        )
    }
}

#[test]
fn concurrent_submissions_all_complete_and_match_direct_solves() {
    let pb = Arc::new(planted(3));
    let svc = SolveService::start(svc_cfg(4, 64));
    let rules = [RuleKind::None, RuleKind::GapSafe, RuleKind::GapSafeSeq];
    let tols = [1e-4, 1e-6, 1e-8];
    let mut ids = Vec::new();
    for &rule in &rules {
        for &tol in &tols {
            ids.push((svc.submit(dense_req(&pb, rule, tol)).unwrap(), rule, tol));
        }
    }
    for &(id, rule, tol) in &ids {
        let res = svc.wait(id).unwrap();
        assert!(res.all_converged(), "{rule:?}@{tol:.0e}");
        assert_eq!(res.lambdas.len(), 8);
        // The service answer is bit-identical to the direct engine.
        let direct = solve_path(
            &pb,
            &PathOptions {
                delta: 1.5,
                t_count: 8,
                solve: SolveOptions { tol, rule, record_history: false, ..Default::default() },
            },
        );
        for (a, b) in res.results.iter().zip(&direct.results) {
            assert_eq!(a.beta, b.beta, "{rule:?}@{tol:.0e}");
        }
    }
    let m = svc.metrics();
    assert_eq!(m.counter("service_submitted"), 9);
    assert_eq!(m.counter("service_completed"), 9);
    assert_eq!(m.counter("service_cache_hits"), 0);
    // Latency/queue-wait timers recorded one observation per job.
    assert_eq!(m.timer("service_job_latency_s").unwrap().count, 9);
    assert_eq!(m.timer("service_queue_wait_s").unwrap().count, 9);
    assert!(m.timer("service_shard_solve_s").unwrap().count >= 9);
}

#[test]
fn duplicate_traffic_hits_the_fingerprint_cache_without_resolving() {
    let pb = Arc::new(planted(4));
    let svc = SolveService::start(svc_cfg(2, 16));
    let first = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-6)).unwrap();
    let r1 = svc.wait(first).unwrap();
    let m = svc.metrics();
    let shards_before = m.counter("service_shards_solved");
    // Same fingerprint: answered from cache, sharing the result Arc.
    let dup = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-6)).unwrap();
    let r2 = svc.wait(dup).unwrap();
    assert!(Arc::ptr_eq(&r1, &r2), "cache must return the identical result");
    assert!(svc.was_cached(dup));
    assert!(!svc.was_cached(first));
    assert_eq!(m.counter("service_cache_hits"), 1);
    assert_eq!(m.counter("service_shards_solved"), shards_before, "no re-solve");
    // A different tolerance is a different fingerprint: real solve.
    let other = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-4)).unwrap();
    svc.wait(other).unwrap();
    assert!(!svc.was_cached(other));
}

#[test]
fn sharded_service_job_matches_monolithic_service_job() {
    let pb = Arc::new(planted(5));
    let svc = SolveService::start(svc_cfg(2, 16));
    let mut mono = dense_req(&pb, RuleKind::GapSafeSeq, 1e-8);
    mono.opts.t_count = 12;
    let mut sharded = mono.clone();
    sharded.shards = 4;
    sharded.label = "sharded".into();
    let a = svc.wait(svc.submit(mono).unwrap()).unwrap();
    let b = svc.wait(svc.submit(sharded).unwrap()).unwrap();
    assert_eq!(a.lambdas, b.lambdas);
    assert_eq!(b.lambdas.len(), 12);
    for (ra, rb) in a.results.iter().zip(&b.results) {
        assert_eq!(ra.beta, rb.beta);
        assert_eq!(ra.active.feature, rb.active.feature);
        assert_eq!(ra.epochs, rb.epochs);
    }
    // 1 monolithic shard + 4 pipeline shards.
    assert_eq!(svc.metrics().counter("service_shards_solved"), 5);
}

/// A request whose duration is dominated by a fixed epoch budget (the
/// gap is only ever checked at epoch 0, and the tolerance is
/// unreachable), so tests can hold a worker busy for a predictable,
/// profile-appropriate stretch without flakiness.
fn blocker_req(pb: &Arc<SglProblem<Matrix>>) -> SolveRequest {
    let epochs = if cfg!(debug_assertions) { 4_000 } else { 80_000 };
    SolveRequest {
        label: "blocker".into(),
        lambdas: Some(vec![0.5 * pb.lambda_max()]),
        ..SolveRequest::new(
            AnyProblem::Dense(pb.clone()),
            PathOptions {
                delta: 1.0,
                t_count: 1,
                solve: SolveOptions {
                    tol: 1e-300,
                    fce: usize::MAX,
                    max_epochs: epochs,
                    rule: RuleKind::None,
                    record_history: false,
                    ..Default::default()
                },
            },
        )
    }
}

#[test]
fn cancel_prevents_queued_jobs_from_running() {
    let pb = Arc::new(planted(6));
    let svc = SolveService::start(svc_cfg(1, 16));
    // Highest priority first: the single worker is pinned on the blocker
    // while the victim waits in the queue.
    let mut blocker = blocker_req(&pb);
    blocker.priority = 9;
    let b = svc.submit(blocker).unwrap();
    let victim = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-6)).unwrap();
    assert!(svc.cancel(victim), "victim was queued, cancel must land");
    assert!(!svc.cancel(victim), "second cancel is a no-op");
    assert_eq!(svc.poll(victim), Some(JobStatus::Cancelled));
    let err = svc.wait(victim).unwrap_err();
    assert!(format!("{err}").contains("cancelled"), "{err}");
    // The blocker is unaffected (it never converges — that's its job).
    let res = svc.wait(b).unwrap();
    assert!(!res.all_converged());
    let m = svc.metrics();
    assert_eq!(m.counter("service_cancelled"), 1);
    assert_eq!(m.counter("service_completed"), 1);
    assert!(!svc.cancel(b), "completed jobs cannot be cancelled");
}

#[test]
fn priority_classes_jump_the_fifo_queue() {
    let pb = Arc::new(planted(7));
    let svc = SolveService::start(svc_cfg(1, 16));
    let mut blocker = blocker_req(&pb);
    blocker.priority = 9;
    let b = svc.submit(blocker).unwrap();
    // Submitted low before high: the high-priority job must still
    // complete first once the worker frees up.
    let lo = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-4)).unwrap();
    let mut hi_req = dense_req(&pb, RuleKind::GapSafeSeq, 1e-4);
    hi_req.priority = 5;
    let hi = svc.submit(hi_req).unwrap();
    let order: Vec<_> = std::iter::from_fn(|| svc.wait_next()).collect();
    assert_eq!(order, vec![b, hi, lo]);
}

#[test]
fn bounded_caches_survive_a_duplicate_heavy_request_stream() {
    let pb = Arc::new(planted(9));
    let svc = SolveService::start(ServiceConfig {
        workers: 2,
        queue_depth: 32,
        result_capacity: 6,
        cache_capacity: 4,
    });
    // Duplicate-heavy traffic: 8 distinct configs, each submitted 4
    // times across interleaved rounds. Without bounds the result store
    // would hold 32 jobs and the cache 8 entries for the process
    // lifetime; with them both stay within their configured capacities.
    let tols = [1e-3, 1e-4, 1e-5, 1e-6];
    let rules = [RuleKind::GapSafe, RuleKind::GapSafeSeq];
    for _round in 0..4 {
        let mut ids = Vec::new();
        for &tol in &tols {
            for &rule in &rules {
                ids.push(svc.submit(dense_req(&pb, rule, tol)).unwrap());
            }
        }
        for id in ids {
            svc.wait(id).unwrap();
        }
    }
    let m = svc.metrics();
    assert!(
        svc.cache_len() <= 4,
        "cache over capacity: {} entries",
        svc.cache_len()
    );
    assert!(
        svc.job_count() <= 6,
        "result store over capacity: {} jobs",
        svc.job_count()
    );
    assert!(m.counter("service_cache_evictions") >= 4);
    assert!(m.counter("service_jobs_reaped") >= 24);
    // Every duplicate round after the first is served from cache for the
    // entries that survived eviction; the traffic still all completed.
    assert_eq!(m.counter("service_submitted"), 32);
    assert!(m.counter("service_cache_hits") >= 1);
    assert_eq!(m.counter("service_failed"), 0);
}

#[test]
fn full_queue_backpressures_with_a_typed_error() {
    let pb = Arc::new(planted(8));
    let svc = SolveService::start(svc_cfg(1, 1));
    let b = svc.submit(blocker_req(&pb)).unwrap();
    // Wait until the worker has demonstrably popped the blocker off the
    // queue (it then runs far longer than the submits below take).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while svc.poll(b) != Some(JobStatus::Running) {
        assert!(
            std::time::Instant::now() < deadline,
            "worker never picked up the blocker"
        );
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let queued = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-4)).unwrap();
    let err = svc.submit(dense_req(&pb, RuleKind::GapSafe, 1e-6)).unwrap_err();
    let qf = err.downcast_ref::<QueueFullError>().expect("typed backpressure");
    assert_eq!(qf.depth, 1);
    svc.wait(b).unwrap();
    svc.wait(queued).unwrap();
}
