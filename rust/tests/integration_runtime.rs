//! Integration: the XLA engine (AOT artifacts via PJRT) against the native
//! solvers. Requires `make artifacts` (the default `n=100, p=1000, d=10`
//! shape); tests self-skip when the artifacts are absent so `cargo test`
//! stays runnable before the first build.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::runtime::engine::XlaEngine;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::problem::SglProblem;
use std::path::PathBuf;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load_engine() -> Option<XlaEngine> {
    let dir = artifact_dir();
    if !dir.join("meta.toml").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaEngine::load(&dir).expect("artifacts present but failed to load"))
}

/// A problem matching the default artifact shape (n=100, p=1000, d=10).
fn artifact_problem(tau: f64, seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 100,
        n_groups: 100,
        group_size: 10,
        gamma1: 5,
        gamma2: 4,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, tau)
}

#[test]
fn engine_matches_native_solver() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.3, 11);
    let session = engine.session(&pb).unwrap();
    let lambda = 0.3 * pb.lambda_max();

    let xla = session.solve(lambda, 1e-8, 5000, None, true).unwrap();
    assert!(xla.converged, "xla gap={}", xla.gap);

    let native = solve(
        &pb,
        lambda,
        None,
        &SolveOptions { tol: 1e-10, rule: RuleKind::GapSafe, ..Default::default() },
    );
    assert!(native.converged);

    let mut max_diff = 0.0_f64;
    for j in 0..pb.p() {
        max_diff = max_diff.max((xla.beta[j] - native.beta[j]).abs());
    }
    assert!(max_diff < 5e-4, "max coefficient diff {max_diff}");
}

#[test]
fn engine_screens_and_reports_active_sets() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.3, 12);
    let session = engine.session(&pb).unwrap();
    let lambda = 0.5 * pb.lambda_max();
    let res = session.solve(lambda, 1e-8, 5000, None, true).unwrap();
    assert!(res.converged);
    assert!(
        res.active_features < pb.p(),
        "screening must eliminate features at lambda = lmax/2 ({} of {})",
        res.active_features,
        pb.p()
    );
    assert!(res.active_groups < pb.n_groups());
}

#[test]
fn engine_screening_accelerates_or_matches() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.3, 13);
    let session = engine.session(&pb).unwrap();
    let lambda = 0.4 * pb.lambda_max();
    let with = session.solve(lambda, 1e-8, 5000, None, true).unwrap();
    let without = session.solve(lambda, 1e-8, 5000, None, false).unwrap();
    assert!(with.converged && without.converged);
    // Same solution either way.
    let mut max_diff = 0.0_f64;
    for j in 0..pb.p() {
        max_diff = max_diff.max((with.beta[j] - without.beta[j]).abs());
    }
    assert!(max_diff < 1e-5, "screening changed the solution: {max_diff}");
    assert!(with.rounds <= without.rounds + 1);
}

#[test]
fn engine_zero_solution_above_lambda_max() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.4, 14);
    let session = engine.session(&pb).unwrap();
    let res = session.solve(1.2 * pb.lambda_max(), 1e-10, 50, None, true).unwrap();
    assert!(res.converged);
    assert_eq!(res.rounds, 1, "must converge at the first gap check");
    assert!(res.beta.iter().all(|&b| b == 0.0));
}

#[test]
fn engine_warm_start_reduces_rounds() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.3, 15);
    let session = engine.session(&pb).unwrap();
    let lmax = pb.lambda_max();
    let first = session.solve(0.5 * lmax, 1e-8, 5000, None, true).unwrap();
    let cold = session.solve(0.4 * lmax, 1e-8, 5000, None, true).unwrap();
    let warm = session.solve(0.4 * lmax, 1e-8, 5000, Some(&first.beta), true).unwrap();
    assert!(warm.converged && cold.converged);
    assert!(warm.rounds <= cold.rounds, "warm {} vs cold {}", warm.rounds, cold.rounds);
}

#[test]
fn engine_shape_mismatch_rejected() {
    let Some(engine) = load_engine() else { return };
    let cfg = SyntheticConfig {
        n: 50,
        n_groups: 10,
        group_size: 10,
        gamma1: 2,
        gamma2: 2,
        seed: 1,
        ..Default::default()
    };
    let d = generate(&cfg);
    let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3);
    assert!(engine.session(&pb).is_err());
}

#[test]
fn engine_warm_path_matches_native_path() {
    // The serving pattern: a warm-started path through PJRT must land on
    // the same solutions as the native warm-started path.
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.25, 16);
    let session = engine.session(&pb).unwrap();
    let lambdas = SglProblem::lambda_grid(pb.lambda_max(), 1.5, 6);
    let native = sgl::solver::path::solve_path_on_grid(
        &pb,
        &lambdas,
        &sgl::solver::path::PathOptions {
            delta: 1.5,
            t_count: 6,
            solve: SolveOptions { tol: 1e-9, record_history: false, ..Default::default() },
        },
    );
    let mut warm: Option<Vec<f64>> = None;
    for (i, &lambda) in lambdas.iter().enumerate() {
        let res = session.solve(lambda, 1e-9, 20_000, warm.as_deref(), true).unwrap();
        assert!(res.converged, "lambda {i}");
        let mut max_diff = 0.0_f64;
        for j in 0..pb.p() {
            max_diff = max_diff.max((res.beta[j] - native.results[i].beta[j]).abs());
        }
        assert!(max_diff < 1e-3, "lambda {i}: max diff {max_diff}");
        warm = Some(res.beta);
    }
}

#[test]
fn engine_results_deterministic() {
    let Some(engine) = load_engine() else { return };
    let pb = artifact_problem(0.3, 17);
    let session = engine.session(&pb).unwrap();
    let lambda = 0.4 * pb.lambda_max();
    let a = session.solve(lambda, 1e-8, 5000, None, true).unwrap();
    let b = session.solve(lambda, 1e-8, 5000, None, true).unwrap();
    assert_eq!(a.rounds, b.rounds);
    assert_eq!(a.beta, b.beta, "PJRT execution must be bit-deterministic");
}
