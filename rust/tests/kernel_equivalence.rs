//! Scalar-vs-SIMD kernel equivalence (the `linalg::simd` contract):
//!
//! - every reduction kernel's SIMD path agrees with its scalar path to
//!   ≤ 1e-12 *relative* error, across odd lengths, empty inputs,
//!   subnormals, and signed zeros (exercised through the explicit
//!   `_with(…, simd: bool)` variants, so the process-global policy is
//!   never touched and the tests are race-free under parallel runs);
//! - elementwise kernels (axpy, windowed axpy, sub) are bit-identical to
//!   their naive loops under any policy;
//! - the dense and CSC `Design` column kernels agree across *every* row
//!   window, and neither backend ever falls back to the allocating
//!   trait-default `col_axpy_rows` on a real solve.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::design::generic_axpy_rows_calls;
use sgl::linalg::{simd, CscMatrix, Design, Matrix};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::sweep::SweepMode;
use sgl::util::rng::Pcg;

/// Relative gap, safe at zero: |a−b| / max(|a|, |b|, 1e-300).
fn rel(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-300)
}

const REL_TOL: f64 = 1e-12;

/// Lengths that hit every code shape: empty, sub-lane tails, exact lane
/// multiples, one-off-the-lane, panel boundaries (PANEL_ROWS = 2048),
/// and multi-panel.
fn lengths() -> Vec<usize> {
    vec![0, 1, 2, 3, 7, 8, 9, 15, 16, 17, 63, 64, 65, 100, 1000, 2047, 2048, 2049, 5000]
}

/// A value mix with the full pathology set: ordinary magnitudes,
/// subnormals, and both signed zeros.
fn edgy_vec(rng: &mut Pcg, n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| match i % 11 {
            0 => 0.0,
            1 => -0.0,
            2 => f64::from_bits(3), // subnormal
            3 => -f64::MIN_POSITIVE / 2.0,
            4 => 1e-30,
            5 => -1e30,
            _ => rng.normal(),
        })
        .collect()
}

#[test]
fn dot_scalar_vs_simd() {
    let mut rng = Pcg::seeded(11);
    for n in lengths() {
        let a = edgy_vec(&mut rng, n);
        let b = edgy_vec(&mut rng, n);
        let s = simd::dot_with(&a, &b, false);
        let v = simd::dot_with(&a, &b, true);
        assert!(rel(s, v) <= REL_TOL, "dot n={n}: {s} vs {v}");
        // The scalar branch IS the historical kernel, bit for bit.
        assert_eq!(s.to_bits(), sgl::linalg::ops::dot(&a, &b).to_bits(), "scalar drifted n={n}");
    }
}

#[test]
fn sq_norm_scalar_vs_simd() {
    let mut rng = Pcg::seeded(12);
    for n in lengths() {
        let x = edgy_vec(&mut rng, n);
        let s = simd::sq_norm_with(&x, false);
        let v = simd::sq_norm_with(&x, true);
        assert!(rel(s, v) <= REL_TOL, "sq_norm n={n}: {s} vs {v}");
    }
}

#[test]
fn max_abs_scalar_vs_simd_is_exact() {
    let mut rng = Pcg::seeded(13);
    for n in lengths() {
        let x = edgy_vec(&mut rng, n);
        let s = simd::max_abs_with(&x, false);
        let v = simd::max_abs_with(&x, true);
        // max is order-independent: the two paths must agree exactly.
        assert_eq!(s.to_bits(), v.to_bits(), "max_abs n={n}: {s} vs {v}");
    }
}

#[test]
fn sparse_dot_scalar_vs_simd() {
    let mut rng = Pcg::seeded(14);
    for n in lengths() {
        let x = edgy_vec(&mut rng, n.max(1) * 2);
        // Strictly increasing row pattern with gaps, like a CSC column.
        let rows: Vec<usize> = (0..n).map(|i| 2 * i).collect();
        let vals = edgy_vec(&mut rng, n);
        let s = simd::sparse_dot_with(&rows, &vals, &x, false);
        let v = simd::sparse_dot_with(&rows, &vals, &x, true);
        assert!(rel(s, v) <= REL_TOL, "sparse_dot n={n}: {s} vs {v}");
    }
}

#[test]
fn dist_sq_scaled_scalar_vs_simd() {
    let mut rng = Pcg::seeded(15);
    for n in lengths() {
        let y = edgy_vec(&mut rng, n);
        let theta = edgy_vec(&mut rng, n);
        for lambda in [1.0, 0.037, 1e6] {
            let s = simd::dist_sq_scaled_with(&y, &theta, lambda, false);
            let v = simd::dist_sq_scaled_with(&y, &theta, lambda, true);
            assert!(rel(s, v) <= REL_TOL, "dist_sq n={n} lambda={lambda}: {s} vs {v}");
        }
    }
}

#[test]
fn elementwise_kernels_are_bit_identical_to_naive_loops() {
    let mut rng = Pcg::seeded(16);
    for n in lengths() {
        let x = edgy_vec(&mut rng, n);
        let y0 = edgy_vec(&mut rng, n);
        for alpha in [0.0, -0.0, 1.0, -2.5e-7, 3.0e8] {
            // axpy vs the naive loop.
            let mut got = y0.clone();
            simd::axpy(alpha, &x, &mut got);
            let mut want = y0.clone();
            if alpha != 0.0 {
                for (w, xi) in want.iter_mut().zip(&x) {
                    *w += alpha * xi;
                }
            }
            for i in 0..n {
                assert_eq!(got[i].to_bits(), want[i].to_bits(), "axpy n={n} i={i}");
            }
            // axpy_rows == axpy on the window.
            let (row0, row1) = (n / 4, n - n / 3);
            let mut got_w = y0[row0..row1].to_vec();
            simd::axpy_rows(alpha, &x, row0, row1, &mut got_w);
            for (i, g) in got_w.iter().enumerate() {
                assert_eq!(g.to_bits(), want[row0 + i].to_bits(), "axpy_rows n={n} i={i}");
            }
        }
        // sub_into vs ops::sub.
        let mut out = vec![0.0; n];
        simd::sub_into(&x, &y0, &mut out);
        let want = sgl::linalg::ops::sub(&x, &y0);
        for i in 0..n {
            assert_eq!(out[i].to_bits(), want[i].to_bits(), "sub_into n={n} i={i}");
        }
    }
}

/// Dense and CSC instantiations of the same matrix: column kernels agree
/// (≤ 1e-12 relative on reductions, bitwise on the windowed axpy vs its
/// full-column reference) over *every* row window of a small design.
#[test]
fn dense_and_csc_column_kernels_agree_on_all_row_windows() {
    let n = 13;
    let p = 7;
    let mut rng = Pcg::seeded(17);
    // ~40% sparse entries so the CSC columns have ragged row patterns.
    let data: Vec<f64> =
        (0..n * p).map(|_| if rng.normal() > -0.3 { rng.normal() } else { 0.0 }).collect();
    let dense = Matrix::from_row_major(&data, n, p);
    let csc = CscMatrix::from_dense(&dense);
    let v = edgy_vec(&mut rng, n);
    for j in 0..p {
        let dd = dense.col_dot(j, &v);
        let sd = csc.col_dot(j, &v);
        assert!(rel(dd, sd) <= REL_TOL, "col_dot j={j}: {dd} vs {sd}");
        assert!(rel(dense.col_norm(j), csc.col_norm(j)) <= REL_TOL, "col_norm j={j}");
        for row0 in 0..=n {
            for row1 in row0..=n {
                // Reference: full-column axpy, then slice the window.
                let mut full_d = vec![0.25; n];
                dense.col_axpy(j, -1.5, &mut full_d);
                let mut wd = vec![0.25; row1 - row0];
                dense.col_axpy_rows(j, -1.5, row0, row1, &mut wd);
                let mut ws = vec![0.25; row1 - row0];
                csc.col_axpy_rows(j, -1.5, row0, row1, &mut ws);
                for i in 0..(row1 - row0) {
                    assert_eq!(
                        wd[i].to_bits(),
                        full_d[row0 + i].to_bits(),
                        "dense window j={j} [{row0},{row1}) i={i}"
                    );
                    assert_eq!(
                        ws[i].to_bits(),
                        full_d[row0 + i].to_bits(),
                        "csc window j={j} [{row0},{row1}) i={i}"
                    );
                }
            }
        }
    }
}

/// Neither shipped backend may ever route through the allocating
/// trait-default `col_axpy_rows` — both override it with windowed
/// kernels, and the row-partitioned parallel sweeps would quietly
/// allocate a full column per worker per round if that regressed.
#[test]
fn shipped_backends_never_take_the_generic_axpy_rows_fallback() {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 40,
        group_size: 5,
        gamma1: 6,
        gamma2: 3,
        seed: 9,
        ..Default::default()
    };
    let d = generate(&cfg);
    let pb = sgl::solver::problem::SglProblem::new(
        d.dataset.x.clone(),
        d.dataset.y.clone(),
        d.dataset.groups.clone(),
        0.2,
    );
    let pb_csc = sgl::solver::problem::SglProblem::new(
        CscMatrix::from_dense(&pb.x),
        pb.y.clone(),
        pb.groups.clone(),
        pb.tau,
    );
    let opts = SolveOptions {
        rule: RuleKind::GapSafe,
        tol: 1e-8,
        record_history: false,
        sweep: SweepMode::Parallel,
        sweep_threads: 2,
        ..Default::default()
    };
    let before = generic_axpy_rows_calls();
    let lambda = 0.1 * pb.lambda_max();
    let a = sgl::solver::cd::solve(&pb, lambda, None, &opts);
    let b = sgl::solver::cd::solve(&pb_csc, lambda, None, &opts);
    assert!(a.converged && b.converged, "solves must converge for the probe to mean anything");
    assert_eq!(
        generic_axpy_rows_calls(),
        before,
        "a shipped backend fell back to the allocating generic col_axpy_rows"
    );
}
