//! Regression tests for solver-state honesty:
//!
//! 1. **Residual drift** — the CD loop maintains `ρ = y − Xβ`
//!    incrementally (`O(n)` per touched coordinate) and refreshes it from
//!    scratch every 10th gap evaluation. After thousands of incremental
//!    updates, the gap reported from the maintained residual must agree
//!    with a from-scratch `y − Xβ` recomputation.
//! 2. **Engine equivalence** — the sequential GAP rule and the
//!    compacted-column sweep are pure optimizations: every rule must land
//!    on the same path objectives to 1e-7.

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::{solve, SolveOptions};
use sgl::solver::duality::duality_gap;
use sgl::solver::path::{solve_path, PathOptions};
use sgl::solver::problem::SglProblem;

/// Strongly correlated design + small λ: the coordinate-descent loop needs
/// thousands of coordinate updates, exercising the incremental-residual
/// path hard.
fn correlated_problem(seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 5,
        rho: 0.9,
        gamma1: 6,
        gamma2: 3,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3)
}

#[test]
fn reported_gap_matches_from_scratch_residual_after_long_runs() {
    for rule in [RuleKind::None, RuleKind::GapSafe] {
        let pb = correlated_problem(1);
        let lambda = 0.005 * pb.lambda_max();
        let opts = SolveOptions {
            tol: 1e-15,
            fce: 1, // gap evaluation (+ screening) every epoch
            max_epochs: 5000,
            rule,
            record_history: false,
            ..Default::default()
        };
        let res = solve(&pb, lambda, None, &opts);
        // Sanity: the scenario must actually run long enough to matter —
        // each epoch touches up to p coordinates, each an incremental
        // update of rho.
        assert!(
            res.epochs >= 300,
            "{rule:?}: scenario converged too fast ({} epochs)",
            res.epochs
        );
        let scratch = duality_gap(&pb, &res.beta, lambda);
        let y2: f64 = pb.y.iter().map(|v| v * v).sum();
        assert!(
            (res.gap - scratch).abs() <= 1e-9 * y2,
            "{rule:?}: incrementally-maintained gap {} vs from-scratch {} \
             — residual drift beyond budget",
            res.gap,
            scratch
        );
    }
}

#[test]
fn periodic_refresh_keeps_history_gaps_honest() {
    // With record_history on, every 10th gap evaluation happens right
    // after a from-scratch residual refresh; the whole gap sequence must
    // be non-negative and end below where it started.
    let pb = correlated_problem(2);
    let lambda = 0.01 * pb.lambda_max();
    let opts = SolveOptions {
        tol: 1e-14,
        fce: 1,
        max_epochs: 3000,
        rule: RuleKind::GapSafe,
        record_history: true,
        ..Default::default()
    };
    let res = solve(&pb, lambda, None, &opts);
    assert!(res.history.len() >= 100, "history too short: {}", res.history.len());
    assert!(res.history.iter().all(|c| c.gap >= 0.0));
    let first = res.history.first().unwrap().gap;
    let last = res.history.last().unwrap().gap;
    assert!(last < first, "gap did not decrease: {first} -> {last}");
}

/// All six rules — including the sequential GAP rule, which screens from
/// the carried dual point at epoch 0 — drive the same compacted-column CD
/// engine and must reach identical path objectives to 1e-7. `y` is scaled
/// to unit norm so the absolute 1e-7 budget is scale-free.
#[test]
fn every_rule_matches_reference_objectives_to_1e7() {
    let d = generate(&SyntheticConfig {
        n: 80,
        n_groups: 40,
        group_size: 5,
        gamma1: 5,
        gamma2: 3,
        seed: 9,
        ..Default::default()
    });
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt();
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    let pb = SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2);
    let objective = |lambda: f64, beta: &[f64]| {
        let xb = pb.x.matvec(beta);
        let r2: f64 = pb.y.iter().zip(&xb).map(|(yi, v)| (yi - v) * (yi - v)).sum();
        0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
    };
    let opts = |rule| PathOptions {
        delta: 2.0,
        t_count: 8,
        solve: SolveOptions { rule, tol: 1e-10, record_history: false, ..Default::default() },
    };
    let base = solve_path(&pb, &opts(RuleKind::None));
    assert!(base.all_converged());
    for rule in RuleKind::all() {
        if rule == RuleKind::None {
            continue;
        }
        let path = solve_path(&pb, &opts(rule));
        assert!(path.all_converged(), "{rule:?}");
        for (i, &lambda) in base.lambdas.iter().enumerate() {
            let a = objective(lambda, &base.results[i].beta);
            let b = objective(lambda, &path.results[i].beta);
            assert!(
                (a - b).abs() <= 1e-7,
                "{rule:?} lambda {i}: objective {a} vs reference {b}"
            );
        }
    }
}
