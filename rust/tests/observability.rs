//! End-to-end observability tests (L6): the solve trace must bracket a
//! real CD path correctly and export loadable Chrome trace-event JSON;
//! toggling tracing must be bit-invisible to solver output; sampling must
//! thin the gap-check instants; `render_text` must be line-clean
//! Prometheus exposition; and a two-worker loopback fleet scrape must
//! surface per-worker latency histograms in the coordinator registry.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet, WorkerServer};
use sgl::coordinator::service::AnyProblem;
use sgl::coordinator::shard::{solve_batch_interleaved, InterleavedJob};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use sgl::util::trace::{self, Phase};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// The trace collector is process-global: serialize every test that
/// enables it or runs solves (instrumented sites) so parallel tests in
/// this binary can't interleave events or toggle it under each other.
fn trace_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Planted-sparse instance with unit-norm `y` (same shape as the fleet
/// suite: small enough for debug-profile paths, sparse enough to screen).
fn planted(seed: u64) -> Arc<SglProblem> {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    Arc::new(SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2))
}

fn path_opts(rule: RuleKind, tol: f64, t_count: usize) -> PathOptions {
    PathOptions {
        delta: 1.0,
        t_count,
        solve: SolveOptions {
            rule,
            tol,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    }
}

#[test]
fn traced_cd_path_exports_balanced_chrome_json() {
    let _g = trace_lock();
    trace::clear();
    trace::enable(1);
    let pb = planted(11);
    let opts = path_opts(RuleKind::GapSafe, 1e-8, 6);
    let lambdas = lambda_grid(pb.lambda_max(), opts.delta, opts.t_count);
    let res = solve_path_with(pb.as_ref(), &lambdas, &opts, SolverKind::Cd);
    trace::disable();
    let events = trace::drain();
    assert!(res.all_converged());

    // Span brackets balance per thread in LIFO order, and every
    // gap_check instant fires inside an open "solve" span with the full
    // argument set the dashboards key on.
    let mut stacks: HashMap<u64, Vec<&str>> = HashMap::new();
    let mut gap_checks = 0usize;
    let mut solves = 0usize;
    for e in &events {
        let stack = stacks.entry(e.tid).or_default();
        match e.phase {
            Phase::Begin => {
                if e.name == "solve" {
                    solves += 1;
                }
                stack.push(e.name);
            }
            Phase::End => {
                assert_eq!(stack.pop(), Some(e.name), "unbalanced span {:?}", e.name);
            }
            Phase::Instant => {
                if e.name != "gap_check" {
                    continue;
                }
                gap_checks += 1;
                assert!(stack.contains(&"solve"), "gap_check outside a solve span");
                let keys: Vec<&str> = e.args.iter().map(|(k, _)| *k).collect();
                for k in [
                    "lambda",
                    "epoch",
                    "gap",
                    "screened",
                    "active_features",
                    "active_groups",
                    "rule",
                    "datafit",
                    "tasks",
                    "kernel",
                ] {
                    assert!(keys.contains(&k), "gap_check missing arg {k:?}: {keys:?}");
                }
            }
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "tid {tid} left open spans {stack:?}");
    }
    assert_eq!(solves, lambdas.len(), "one solve span per grid point");
    assert!(gap_checks >= lambdas.len(), "every solve gap-checks at least once");
    let path_brackets = events.iter().filter(|e| e.name == "solve_path").count();
    assert_eq!(path_brackets, 2, "solve_path opens and closes exactly once");

    // The export is the Chrome trace-event document Perfetto loads:
    // one object, a traceEvents array, B/E/i phases.
    let dump = trace::chrome_trace(&events).dump();
    assert!(dump.starts_with("{\"traceEvents\":["), "{}", &dump[..40.min(dump.len())]);
    assert!(dump.ends_with("\"displayTimeUnit\":\"ms\"}"));
    for needle in ["\"ph\":\"B\"", "\"ph\":\"E\"", "\"ph\":\"i\"", "\"name\":\"gap_check\""] {
        assert!(dump.contains(needle), "export missing {needle}");
    }
}

#[test]
fn tracing_toggle_is_bit_invisible_to_solver_output() {
    let _g = trace_lock();
    trace::disable();
    trace::clear();
    let pb = planted(12);
    let opts = path_opts(RuleKind::GapSafeSeq, 1e-8, 6);
    let lambdas = lambda_grid(pb.lambda_max(), opts.delta, opts.t_count);
    let off = solve_path_with(pb.as_ref(), &lambdas, &opts, SolverKind::Cd);
    trace::enable(1);
    let on = solve_path_with(pb.as_ref(), &lambdas, &opts, SolverKind::Cd);
    trace::disable();
    trace::clear();
    assert_eq!(off.lambdas, on.lambdas);
    for (t, (a, b)) in off.results.iter().zip(&on.results).enumerate() {
        let ab: Vec<u64> = a.beta.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u64> = b.beta.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb, "t={t}: beta bits diverged with tracing on");
        assert_eq!(a.epochs, b.epochs, "t={t}: epoch count diverged");
        assert_eq!(a.gap.to_bits(), b.gap.to_bits(), "t={t}: terminal gap diverged");
        assert_eq!(a.active.feature, b.active.feature, "t={t}: screening diverged");
    }
}

#[test]
fn trace_sampling_thins_gap_check_instants() {
    let _g = trace_lock();
    let pb = planted(13);
    let opts = path_opts(RuleKind::GapSafe, 1e-10, 4);
    let lambdas = lambda_grid(pb.lambda_max(), opts.delta, opts.t_count);
    let count = |sample: u64| {
        trace::clear();
        trace::enable(sample);
        let _ = solve_path_with(pb.as_ref(), &lambdas, &opts, SolverKind::Cd);
        trace::disable();
        trace::drain().iter().filter(|e| e.name == "gap_check").count()
    };
    let every = count(1);
    let fourth = count(4);
    trace::clear();
    assert!(every > lambdas.len(), "tight path should gap-check often, got {every}");
    assert!(fourth < every, "sampling must thin instants: {fourth} vs {every}");
    // The first check of every solve has sequence number 0, which every
    // sampling divisor records — no solve goes dark.
    assert!(fourth >= lambdas.len(), "{fourth} solves went dark under sampling");
}

fn assert_prometheus_name(name: &str) {
    let mut chars = name.chars();
    let c0 = chars.next().expect("empty metric name");
    assert!(c0.is_ascii_alphabetic() || c0 == '_' || c0 == ':', "bad first char in {name:?}");
    for c in chars {
        assert!(c.is_ascii_alphanumeric() || c == '_' || c == ':', "bad char in {name:?}");
    }
}

#[test]
fn render_text_is_prometheus_line_format() {
    let m = Metrics::new();
    m.incr("solves total", 3); // space → underscore
    m.incr("9lives", 1); // leading digit → prefixed
    m.set("queue.depth", 4.5); // dot → underscore
    for i in 1..=200 {
        m.observe_secs("shard solve-s", i as f64 * 1e-3);
    }
    let text = m.render_text();
    let (mut samples, mut types) = (0usize, 0usize);
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line has a name");
            let kind = it.next().expect("TYPE line has a kind");
            assert!(matches!(kind, "counter" | "gauge" | "summary"), "{line}");
            assert_eq!(it.next(), None, "trailing tokens in {line:?}");
            assert_prometheus_name(name);
            types += 1;
            continue;
        }
        let mut it = line.split_whitespace();
        let name = it.next().expect("sample line has a name");
        let value = it.next().expect("sample line has a value");
        assert_eq!(it.next(), None, "trailing tokens in {line:?}");
        assert_prometheus_name(name);
        value.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        samples += 1;
    }
    assert_eq!(types, 4, "one TYPE comment per metric family:\n{text}");
    assert_eq!(samples, 2 + 1 + 8, "counter + gauge + summary series:\n{text}");
    assert!(text.contains("solves_total 3\n"));
    assert!(text.contains("_9lives 1\n"));
    assert!(text.contains("queue_depth 4.5\n"));
    assert!(text.contains("# TYPE shard_solve_s summary\n"));
    assert!(text.contains("shard_solve_s_p95 "));

    // Quantiles of 1..=200 ms sit near the exact order statistics — the
    // log-bucket histogram is 2^(1/4)-granular, so within ~19% relative.
    let p50 = m.timer_quantile("shard solve-s", 0.50).unwrap();
    let p95 = m.timer_quantile("shard solve-s", 0.95).unwrap();
    let p99 = m.timer_quantile("shard solve-s", 0.99).unwrap();
    assert!(p50 <= p95 && p95 <= p99, "quantiles out of order: {p50} {p95} {p99}");
    assert!((0.07..=0.14).contains(&p50), "p50 {p50} far from 0.100");
    assert!((0.14..=0.25).contains(&p95), "p95 {p95} far from 0.190");
    assert!((0.15..=0.26).contains(&p99), "p99 {p99} far from 0.198");
}

#[test]
fn two_worker_fleet_scrape_surfaces_per_worker_histograms() {
    // Fleet workers run real (instrumented) solves — hold the trace lock
    // so a concurrently-enabled collector never sees their events.
    let _g = trace_lock();
    let metrics = Arc::new(Metrics::new());
    let servers: Vec<WorkerServer> =
        (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = Arc::new(
        RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
            .expect("connect fleet"),
    );

    let pb = planted(14);
    let jobs: Vec<InterleavedJob> = (0..2)
        .map(|i| InterleavedJob {
            pb: AnyProblem::Dense(pb.clone()),
            lambdas: lambda_grid(pb.lambda_max(), 1.0, 4),
            opts: path_opts(RuleKind::GapSafeSeq, 1e-8, 4),
            solver: SolverKind::Cd,
            shards: 2,
            label: format!("job{i}"),
        })
        .collect();
    let out = solve_batch_interleaved(&jobs, 2, |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
    }

    // Both workers answer the scrape; their registries land under
    // worker_<i>_ prefixes and the shard totals add up exactly.
    assert_eq!(fleet.scrape(Duration::from_secs(5)), 2);
    let solved: u64 =
        (0..2).map(|i| metrics.counter(&format!("worker_{i}_worker_shards_solved"))).sum();
    assert_eq!(solved, 4, "every shard accounted to exactly one worker");
    let text = metrics.render_text();
    for i in 0..2 {
        let gauge = format!("worker_{i}_worker_in_flight 0\n");
        assert!(text.contains(&gauge), "missing {gauge:?} in:\n{text}");
    }
    // Worker 0 demonstrably solved (least-loaded dispatch tries it
    // first): its latency histogram surfaces quantiles end to end, in
    // the text exposition and the JSON dump alike.
    let p50 = metrics.timer_quantile("worker_0_worker_shard_solve_s", 0.50).unwrap();
    let p99 = metrics.timer_quantile("worker_0_worker_shard_solve_s", 0.99).unwrap();
    assert!(p50 > 0.0 && p50 <= p99, "degenerate scraped quantiles {p50} {p99}");
    assert!(text.contains("worker_0_worker_shard_solve_s_p95 "));
    assert!(metrics.to_json().dump().contains("worker_0_worker_shard_solve_s_p99"));

    // Heartbeats carry live summaries: both workers idle-alive.
    let beats = fleet.heartbeat(Duration::from_secs(5));
    assert_eq!(beats.len(), 2);
    for (addr, state) in &beats {
        let s = state.summary().unwrap_or_else(|| panic!("{addr} not idle-alive"));
        assert_eq!(s.in_flight, 0, "{addr} still mid-shard");
    }
    assert_eq!(beats.iter().map(|(_, s)| s.summary().unwrap().solves).sum::<u64>(), 4);

    // Re-scraping overwrites absolute totals — never double-counts.
    assert_eq!(fleet.scrape(Duration::from_secs(5)), 2);
    let resolved: u64 =
        (0..2).map(|i| metrics.counter(&format!("worker_{i}_worker_shards_solved"))).sum();
    assert_eq!(resolved, 4, "re-scrape must not double-count");
}
