//! Property tests for the framed wire codec (`util::wire`): every
//! message round-trips bit-exactly — including adversarial f64s
//! (NaN payloads, ±inf, signed zeros, subnormals) in handoffs, empty
//! paths, zero-row CSC datasets, multi-column (task-major)
//! responses and v6 chunked dataset ships — and every malformed input
//! (truncated frames, bad versions, bad tags, random garbage, mutated
//! frames, chunk-protocol abuse) decodes to a *typed* [`WireError`]
//! instead of panicking.
//!
//! Generators mirror the vendored-proptest style of
//! `proptest_invariants.rs` (`util::proptest::forall`, fixed per-name
//! seeds, `SGL_PROPTEST_SEED` to explore).

use sgl::screening::{ActiveSet, RuleKind};
use sgl::solver::cd::{CheckEvent, SolveOptions, SolveResult};
use sgl::solver::duality::DualSnapshot;
use sgl::solver::path::{DualHandoff, PathOptions, PathResult};
use sgl::solver::sweep::{SweepMode, SweepTuning};
use sgl::solver::SolverKind;
use sgl::util::proptest::{check, forall, Gen};
use sgl::coordinator::metrics::{MetricsSnapshot, TimerStats};
use sgl::util::wire::{
    ChunkAssembler, ChunkBegin, ChunkPart, Message, ProblemPayload, RemoteError,
    RemoteErrorKind, ShardRequest, WireDatafit, WireDataset, WireDesign, WireError,
    WorkerSummary, WIRE_VERSION,
};

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// f64 with the full pathology mix: NaNs (payload-carrying, both signs),
/// infinities, signed zeros, subnormals, extremes — the values a naive
/// text or lossy encoding would destroy.
fn edgy_f64(g: &mut Gen) -> f64 {
    match g.usize_in(0..14) {
        0 => f64::NAN,
        1 => f64::from_bits(0x7ff8_dead_beef_0001),
        2 => f64::from_bits(0xfff8_1234_5678_9abc),
        3 => f64::INFINITY,
        4 => f64::NEG_INFINITY,
        5 => 0.0,
        6 => -0.0,
        7 => f64::from_bits(1), // smallest subnormal
        8 => f64::MIN_POSITIVE / 4.0,
        9 => f64::MAX,
        10 => f64::MIN,
        11 => f64::MIN_POSITIVE,
        _ => g.normal() * 10f64.powi(g.usize_in(0..9) as i32 - 4),
    }
}

fn edgy_vec(g: &mut Gen, max_len: usize) -> Vec<f64> {
    let n = g.usize_in(0..max_len + 1);
    (0..n).map(|_| edgy_f64(g)).collect()
}

fn gen_snapshot(g: &mut Gen) -> DualSnapshot {
    DualSnapshot {
        theta: edgy_vec(g, 6),
        xt_theta: edgy_vec(g, 6),
        dual_norm_xt_rho: edgy_f64(g),
        theta_aug_sq: edgy_f64(g),
        primal: edgy_f64(g),
        dual: edgy_f64(g),
        gap: edgy_f64(g),
        radius: edgy_f64(g),
    }
}

fn gen_handoff(g: &mut Gen) -> DualHandoff {
    DualHandoff { lambda: edgy_f64(g), beta: edgy_vec(g, 8), snap: gen_snapshot(g) }
}

fn gen_solve_options(g: &mut Gen) -> SolveOptions {
    let rules = RuleKind::all();
    let sweeps = SweepMode::all();
    SolveOptions {
        tol: edgy_f64(g),
        max_epochs: g.usize_in(0..100_000),
        fce: g.usize_in(0..64),
        rule: rules[g.usize_in(0..rules.len())],
        record_history: g.bool(),
        sweep: sweeps[g.usize_in(0..sweeps.len())],
        sweep_threads: g.usize_in(0..9),
        tuning: SweepTuning {
            xt_floor: g.usize_in(1..1000),
            residual_floor: g.usize_in(1..1000),
            omega_dual_floor: g.usize_in(1..1000),
            prox_floor: g.usize_in(1..1000),
            cd_floor: g.usize_in(1..1000),
            groups_per_round: g.usize_in(1..64),
        },
    }
}

fn gen_path_options(g: &mut Gen) -> PathOptions {
    PathOptions { delta: edgy_f64(g), t_count: g.usize_in(0..200), solve: gen_solve_options(g) }
}

fn gen_solve_result(g: &mut Gen) -> SolveResult {
    let p = g.usize_in(0..7);
    let n_groups = g.usize_in(0..4);
    SolveResult {
        beta: (0..p).map(|_| edgy_f64(g)).collect(),
        gap: edgy_f64(g),
        epochs: g.usize_in(0..100_000),
        converged: g.bool(),
        elapsed_s: edgy_f64(g),
        active: ActiveSet {
            feature: (0..p).map(|_| g.bool()).collect(),
            group: (0..n_groups).map(|_| g.bool()).collect(),
        },
        history: (0..g.usize_in(0..3))
            .map(|_| CheckEvent {
                epoch: g.usize_in(0..10_000),
                gap: edgy_f64(g),
                radius: edgy_f64(g),
                active_features: g.usize_in(0..1000),
                active_groups: g.usize_in(0..100),
                elapsed_s: edgy_f64(g),
            })
            .collect(),
        gap_evals: g.usize_in(0..1000),
    }
}

/// Paths are empty with real probability (the degenerate shard case).
fn gen_path_result(g: &mut Gen) -> PathResult {
    let t = g.usize_in(0..4);
    PathResult {
        lambdas: (0..t).map(|_| edgy_f64(g)).collect(),
        results: (0..t).map(|_| gen_solve_result(g)).collect(),
        total_s: edgy_f64(g),
    }
}

/// A datafit that survives `into_problem` validation: finite non-negative
/// ridge, logistic (whose labels `gen_dataset` then constrains), or
/// multi-task with a positive column count (whose `y` length `gen_dataset`
/// then scales by `tasks`).
fn gen_valid_datafit(g: &mut Gen) -> WireDatafit {
    match g.usize_in(0..4) {
        0 => WireDatafit::Quadratic { ridge: 0.0 },
        1 => WireDatafit::Quadratic { ridge: g.f64_in(0.0..2.0) },
        2 => WireDatafit::MultiTask { tasks: g.usize_in(1..5) as u64 },
        _ => WireDatafit::Logistic,
    }
}

/// Structurally valid dataset (the kind our own encoder emits), with
/// zero-row CSC designs and both datafits mixed in.
fn gen_dataset(g: &mut Gen) -> WireDataset {
    let n_groups = g.usize_in(1..4);
    let sizes: Vec<usize> = (0..n_groups).map(|_| g.usize_in(1..4)).collect();
    let p: usize = sizes.iter().sum();
    let n = if g.usize_in(0..5) == 0 { 0 } else { g.usize_in(1..6) };
    let datafit = gen_valid_datafit(g);
    let design = if g.bool() {
        WireDesign::Dense {
            n_rows: n,
            n_cols: p,
            data: (0..n * p).map(|_| edgy_f64(g)).collect(),
        }
    } else {
        // Valid CSC: strictly increasing rows within each column.
        let mut indptr = vec![0u64];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for _ in 0..p {
            for row in 0..n {
                if g.bool() {
                    indices.push(row as u64);
                    values.push(edgy_f64(g));
                }
            }
            indptr.push(indices.len() as u64);
        }
        WireDesign::Csc { n_rows: n, n_cols: p, indptr, indices, values }
    };
    // Logistic labels must lie in [0, 1] for into_problem; the quadratic
    // and multi-task responses keep the full f64 pathology mix. Multi-task
    // `y` is task-major with `n · tasks` entries.
    let y: Vec<f64> = if datafit == WireDatafit::Logistic {
        (0..n).map(|_| [0.0, 1.0, 0.5][g.usize_in(0..3)]).collect()
    } else {
        (0..n * datafit.tasks() as usize).map(|_| edgy_f64(g)).collect()
    };
    WireDataset {
        design,
        y,
        group_sizes: sizes.iter().map(|&s| s as u64).collect(),
        // τ valid (into_problem is also exercised) but off the lattice.
        tau: 0.1 + 0.8 * g.f64_in(0.0..1.0),
        weights: (0..n_groups).map(|_| 0.5 + g.f64_in(0.0..2.0)).collect(),
        datafit,
    }
}

fn gen_worker_summary(g: &mut Gen) -> WorkerSummary {
    WorkerSummary {
        in_flight: g.rng().next_u64(),
        solves: g.rng().next_u64(),
        uptime_ticks: g.rng().next_u64(),
        epoch: g.rng().next_u64(),
        // Raw bits: NaN payloads and infinities in the gap must survive.
        gap_bits: g.rng().next_u64(),
    }
}

/// A structurally valid chunked ship, straight from the splitter the
/// coordinator uses — tiny byte budgets so multi-part ships are the
/// common case, not the exception.
fn gen_chunked_ship(g: &mut Gen) -> (ChunkBegin, Vec<ChunkPart>) {
    let ds = gen_dataset(g);
    let budget = 1 + g.usize_in(0..96);
    ds.to_chunks(budget)
}

/// Snapshots mix empty registries, edgy gauge floats, and sparse
/// histogram pairs at the index extremes.
fn gen_snapshot_msg(g: &mut Gen) -> MetricsSnapshot {
    let n_counters = g.usize_in(0..4);
    let n_gauges = g.usize_in(0..4);
    let n_timers = g.usize_in(0..3);
    MetricsSnapshot {
        counters: (0..n_counters)
            .map(|i| (format!("counter_{i}"), g.rng().next_u64()))
            .collect(),
        gauges: (0..n_gauges).map(|i| (format!("gauge_{i}"), edgy_f64(g))).collect(),
        timers: (0..n_timers)
            .map(|i| {
                let stats = TimerStats {
                    count: g.rng().next_u64(),
                    sum: edgy_f64(g),
                    min: edgy_f64(g),
                    max: edgy_f64(g),
                };
                let sparse: Vec<(u64, u64)> = (0..g.usize_in(0..4))
                    .map(|_| (g.rng().next_u64() % 200, g.rng().next_u64()))
                    .collect();
                (format!("timer_{i}"), stats, sparse)
            })
            .collect(),
    }
}

fn gen_message(g: &mut Gen) -> Message {
    match g.usize_in(0..16) {
        0 => Message::Ping { seq: g.rng().next_u64() },
        10 => Message::Register {
            addr: format!(
                "10.{}.{}.{}:{}",
                g.usize_in(0..256),
                g.usize_in(0..256),
                g.usize_in(0..256),
                g.usize_in(1..65536)
            ),
        },
        11 => Message::Registered { worker: g.rng().next_u64() },
        12 => Message::Progress { summary: gen_worker_summary(g) },
        13 => Message::ShipBegin(gen_chunked_ship(g).0),
        14 => {
            let (_, parts) = gen_chunked_ship(g);
            let i = g.usize_in(0..parts.len());
            Message::ShipChunk(parts.into_iter().nth(i).expect("at least one chunk"))
        }
        15 => Message::ShipEnd { fingerprint: g.rng().next_u64() },
        1 => Message::Pong { seq: g.rng().next_u64(), summary: gen_worker_summary(g) },
        8 => Message::StatsRequest,
        9 => Message::StatsReply(gen_snapshot_msg(g)),
        2 => Message::HasDataset { fingerprint: g.rng().next_u64() },
        3 => Message::DatasetKnown { fingerprint: g.rng().next_u64(), known: g.bool() },
        4 => Message::ShipDataset(gen_dataset(g)),
        5 => Message::SolveShard(ShardRequest {
            dataset: g.rng().next_u64(),
            // Roundtrip (not into_problem): the ridge keeps edgy bits and
            // the task count ranges over all of u64.
            datafit: match g.usize_in(0..3) {
                0 => WireDatafit::Quadratic { ridge: edgy_f64(g) },
                1 => WireDatafit::MultiTask { tasks: g.rng().next_u64() },
                _ => WireDatafit::Logistic,
            },
            lambdas: edgy_vec(g, 6),
            solver: SolverKind::all()[g.usize_in(0..3)],
            opts: gen_path_options(g),
            handoff: if g.bool() { Some(gen_handoff(g)) } else { None },
        }),
        6 => Message::ShardDone {
            result: gen_path_result(g),
            handoff: if g.bool() { Some(gen_handoff(g)) } else { None },
        },
        _ => Message::Error(RemoteError {
            kind: [
                RemoteErrorKind::UnknownDataset,
                RemoteErrorKind::SolveFailed,
                RemoteErrorKind::BadRequest,
            ][g.usize_in(0..3)],
            detail: format!("detail {} — λ≈π", g.usize_in(0..1000)),
        }),
    }
}

/// Canonical-bytes equality: the strongest message equality available in
/// the presence of NaNs (two equal messages encode identically, and the
/// encoding is injective on the fields we ship).
fn roundtrip_canonical(msg: &Message) -> Result<Message, String> {
    let frame = msg.encode();
    let (decoded, used) =
        Message::decode(&frame).map_err(|e| format!("decode failed: {e}"))?;
    if used != frame.len() {
        return Err(format!("consumed {used} of {} frame bytes", frame.len()));
    }
    let re = decoded.encode();
    if re != frame {
        return Err(format!(
            "re-encode differs: {} vs {} bytes",
            re.len(),
            frame.len()
        ));
    }
    Ok(decoded)
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

#[test]
fn every_message_roundtrips_bit_exactly() {
    forall("wire-roundtrip", 300, |g| {
        let msg = gen_message(g);
        roundtrip_canonical(&msg)?;
        Ok(())
    });
}

#[test]
fn handoff_floats_replay_bit_for_bit() {
    forall("wire-handoff-bits", 200, |g| {
        let h = gen_handoff(g);
        let msg = Message::ShardDone { result: gen_path_result(g), handoff: Some(h.clone()) };
        let Message::ShardDone { handoff: Some(back), .. } = roundtrip_canonical(&msg)?
        else {
            return Err("variant changed in transit".to_string());
        };
        check(back.lambda.to_bits() == h.lambda.to_bits(), "lambda bits")?;
        check(back.beta.len() == h.beta.len(), "beta length")?;
        for (a, b) in back.beta.iter().zip(&h.beta) {
            check(a.to_bits() == b.to_bits(), "beta bits")?;
        }
        for (a, b) in back.snap.theta.iter().zip(&h.snap.theta) {
            check(a.to_bits() == b.to_bits(), "theta bits")?;
        }
        for (a, b) in back.snap.xt_theta.iter().zip(&h.snap.xt_theta) {
            check(a.to_bits() == b.to_bits(), "xt_theta bits")?;
        }
        check(back.snap.gap.to_bits() == h.snap.gap.to_bits(), "gap bits")?;
        check(back.snap.radius.to_bits() == h.snap.radius.to_bits(), "radius bits")
    });
}

#[test]
fn empty_paths_roundtrip() {
    let empty = PathResult { lambdas: vec![], results: vec![], total_s: 0.0 };
    let msg = Message::ShardDone { result: empty, handoff: None };
    let Message::ShardDone { result, handoff } =
        roundtrip_canonical(&msg).expect("empty path roundtrips")
    else {
        panic!("variant changed")
    };
    assert!(result.lambdas.is_empty() && result.results.is_empty());
    assert!(handoff.is_none());
}

#[test]
fn truncated_frames_are_typed_errors_never_panics() {
    forall("wire-truncation", 120, |g| {
        let frame = gen_message(g).encode();
        // Probe a spread of cuts, always including the frame header.
        for k in 0..12 {
            let cut = if k < 5 { k.min(frame.len() - 1) } else { g.usize_in(0..frame.len()) };
            match Message::decode(&frame[..cut]) {
                Err(WireError::Truncated { needed, have }) => {
                    check(have == cut, "reported have")?;
                    check(needed > cut, "needed beyond the cut")?;
                }
                other => {
                    return Err(format!("cut {cut}: expected Truncated, got {other:?}"))
                }
            }
        }
        Ok(())
    });
}

#[test]
fn bad_version_and_bad_tag_are_typed_errors() {
    forall("wire-bad-header", 100, |g| {
        let mut frame = gen_message(g).encode();
        let v = (g.usize_in(7..250)) as u8; // never WIRE_VERSION (= 6)
        frame[4] = v;
        match Message::decode(&frame) {
            Err(WireError::BadVersion { got }) => check(got == v, "version echoed")?,
            other => return Err(format!("expected BadVersion, got {other:?}")),
        }
        frame[4] = WIRE_VERSION; // restore the version…
        frame[5] = 200 + (g.usize_in(0..50)) as u8; // …and break the tag
        match Message::decode(&frame) {
            Err(WireError::BadTag { .. }) => Ok(()),
            other => Err(format!("expected BadTag, got {other:?}")),
        }
    });
}

#[test]
fn garbage_and_mutations_never_panic() {
    forall("wire-fuzz", 400, |g| {
        // Pure garbage of arbitrary length.
        let len = g.usize_in(0..120);
        let garbage: Vec<u8> = (0..len).map(|_| (g.rng().next_u32() & 0xff) as u8).collect();
        let _ = Message::decode(&garbage); // must return, Err or Ok
        // A real frame with a handful of interior bytes flipped: decoding
        // must stay total (typed error or a reinterpreted-but-valid
        // message — either is fine, panicking is not).
        let mut frame = gen_message(g).encode();
        for _ in 0..4 {
            let i = g.usize_in(0..frame.len());
            frame[i] ^= (1 + g.rng().next_u32() % 255) as u8;
        }
        let _ = Message::decode(&frame);
        Ok(())
    });
}

#[test]
fn datasets_roundtrip_rebuild_and_fingerprint_by_content() {
    forall("wire-dataset", 120, |g| {
        let ds = gen_dataset(g);
        let fp = ds.fingerprint();
        let Message::ShipDataset(back) = roundtrip_canonical(&Message::ShipDataset(ds))?
        else {
            return Err("variant changed in transit".to_string());
        };
        check(back.fingerprint() == fp, "fingerprint survives the trip")?;
        // The receiver can always rebuild a problem from what our encoder
        // emits — including zero-row designs — on the matching backend
        // *and* datafit.
        let is_csc = matches!(back.design, WireDesign::Csc { .. });
        let is_logistic = back.datafit == WireDatafit::Logistic;
        let is_mt = matches!(back.datafit, WireDatafit::MultiTask { .. });
        let q_expect = back.datafit.tasks() as usize;
        let (n_expect, p_expect) = match &back.design {
            WireDesign::Dense { n_rows, n_cols, .. }
            | WireDesign::Csc { n_rows, n_cols, .. } => (*n_rows, *n_cols),
        };
        match back.into_problem() {
            Ok(ProblemPayload::Dense(pb)) => {
                check(!is_csc && !is_logistic && !is_mt, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")
            }
            Ok(ProblemPayload::Csc(pb)) => {
                check(is_csc && !is_logistic && !is_mt, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")
            }
            Ok(ProblemPayload::DenseLogistic(pb)) => {
                check(!is_csc && is_logistic, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")
            }
            Ok(ProblemPayload::CscLogistic(pb)) => {
                check(is_csc && is_logistic, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")
            }
            Ok(ProblemPayload::DenseMultiTask(pb)) => {
                check(!is_csc && is_mt, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")?;
                check(pb.tasks() == q_expect, "task count preserved")
            }
            Ok(ProblemPayload::CscMultiTask(pb)) => {
                check(is_csc && is_mt, "backend+datafit preserved")?;
                check(pb.n() == n_expect && pb.p() == p_expect, "shape preserved")?;
                check(pb.tasks() == q_expect, "task count preserved")
            }
            Err(e) => Err(format!("valid dataset rejected: {e}")),
        }
    });
}

#[test]
fn zero_row_csc_and_flipped_value_bits_change_the_fingerprint() {
    let base = WireDataset {
        design: WireDesign::Csc {
            n_rows: 0,
            n_cols: 2,
            indptr: vec![0, 0, 0],
            indices: vec![],
            values: vec![],
        },
        y: vec![],
        group_sizes: vec![2],
        tau: 0.5,
        weights: vec![2.0f64.sqrt()],
        datafit: WireDatafit::Quadratic { ridge: 0.0 },
    };
    let fp = base.fingerprint();
    roundtrip_canonical(&Message::ShipDataset(base.clone())).expect("zero-row roundtrip");
    assert!(matches!(base.clone().into_problem(), Ok(ProblemPayload::Csc(_))));
    // One mantissa bit in the weights is a different dataset.
    let mut other = base;
    other.weights[0] = f64::from_bits(other.weights[0].to_bits() ^ 1);
    assert_ne!(fp, other.fingerprint());
}

#[test]
fn invalid_datasets_fail_decoding_into_problems_with_typed_errors() {
    forall("wire-dataset-invalid", 60, |g| {
        let mut ds = gen_dataset(g);
        // Break it in one of several structural ways.
        match g.usize_in(0..7) {
            0 => ds.group_sizes = vec![],
            1 => ds.weights.push(1.0),
            2 => ds.tau = 1.5,
            3 => {
                ds.datafit = WireDatafit::Quadratic {
                    ridge: [-1.0, f64::NAN, f64::INFINITY][g.usize_in(0..3)],
                }
            }
            4 => {
                // A label outside [0, 1] under the logistic fit (checked
                // before any shape validation).
                ds.datafit = WireDatafit::Logistic;
                ds.y.push([2.0, -0.5, f64::NAN][g.usize_in(0..3)]);
            }
            5 => {
                // Zero response columns under the multi-task fit (rejected
                // before any shape validation).
                ds.datafit = WireDatafit::MultiTask { tasks: 0 };
            }
            _ => ds.y.push(0.0),
        }
        match ds.into_problem() {
            Err(WireError::Malformed(_)) => Ok(()),
            Err(other) => Err(format!("expected Malformed, got {other:?}")),
            Ok(_) => Err("structurally broken dataset was accepted".to_string()),
        }
    });
}

/// A v1 peer (pre-datafit layout) must be rejected outright: its frames
/// would otherwise decode into a misaligned problem.
#[test]
fn v1_frames_are_rejected_with_bad_version() {
    forall("wire-v1-reject", 60, |g| {
        let mut frame = gen_message(g).encode();
        assert_eq!(frame[4], WIRE_VERSION, "version byte location");
        frame[4] = 1;
        match Message::decode(&frame) {
            Err(WireError::BadVersion { got: 1 }) => Ok(()),
            other => Err(format!("expected BadVersion{{got: 1}}, got {other:?}")),
        }
    });
}

/// An unknown datafit tag inside a shipped dataset is a typed
/// [`WireError::Malformed`], never a panic or a misread. The datafit is
/// the final field `put_dataset` emits, so its tag byte sits at a fixed
/// offset from the frame's end.
#[test]
fn unknown_datafit_tags_are_typed_errors() {
    let ds = WireDataset {
        design: WireDesign::Dense { n_rows: 1, n_cols: 1, data: vec![1.0] },
        y: vec![0.5],
        group_sizes: vec![1],
        tau: 0.5,
        weights: vec![1.0],
        datafit: WireDatafit::Quadratic { ridge: 0.25 },
    };
    let mut frame = Message::ShipDataset(ds.clone()).encode();
    // Quadratic encodes as tag 0 + 8 ridge bytes at the very end.
    let tag_at = frame.len() - 9;
    assert_eq!(frame[tag_at], 0, "quadratic datafit tag byte");
    for bad in [3u8, 7, 255] {
        frame[tag_at] = bad;
        match Message::decode(&frame) {
            Err(WireError::Malformed(what)) => {
                assert!(what.contains("datafit"), "tag {bad}: {what}")
            }
            other => panic!("tag {bad}: expected Malformed, got {other:?}"),
        }
    }
    // Logistic is a bare trailing tag byte (1).
    let mut frame =
        Message::ShipDataset(WireDataset { datafit: WireDatafit::Logistic, ..ds.clone() })
            .encode();
    let last = frame.len() - 1;
    assert_eq!(frame[last], 1, "logistic datafit tag byte");
    frame[last] = 9;
    assert!(matches!(Message::decode(&frame), Err(WireError::Malformed(_))));
    // Multi-task encodes as tag 2 + 8 task-count bytes; an unknown tag in
    // its place is equally typed.
    let mt = WireDataset {
        datafit: WireDatafit::MultiTask { tasks: 2 },
        y: vec![0.5, -0.5],
        ..ds
    };
    let mut frame = Message::ShipDataset(mt).encode();
    let tag_at = frame.len() - 9;
    assert_eq!(frame[tag_at], 2, "multi-task datafit tag byte");
    frame[tag_at] = 3;
    match Message::decode(&frame) {
        Err(WireError::Malformed(what)) => assert!(what.contains("datafit"), "{what}"),
        other => panic!("expected Malformed, got {other:?}"),
    }
}

/// A v4 peer predates the multi-task datafit tag and the `n_rows · tasks`
/// response contract; its frames must be refused outright with a typed
/// [`WireError::BadVersion`] instead of misvalidating a multi-column `y`.
#[test]
fn v4_frames_are_rejected_with_bad_version() {
    forall("wire-v4-reject", 60, |g| {
        let mut frame = gen_message(g).encode();
        assert_eq!(frame[4], WIRE_VERSION, "version byte location");
        frame[4] = 4;
        match Message::decode(&frame) {
            Err(WireError::BadVersion { got: 4 }) => Ok(()),
            other => Err(format!("expected BadVersion{{got: 4}}, got {other:?}")),
        }
    });
}

/// Multi-task datasets: multi-column responses (full f64 pathology mix,
/// task-major) survive the trip bit-exactly — including the zero-row and
/// q = 1 edge cases — the datafit tag (not the column count) decides the
/// rebuilt variant, and the task count is part of the dataset identity:
/// identical bytes under a different `tasks` is a different fingerprint.
#[test]
fn multitask_datasets_roundtrip_and_fingerprint_by_task_count() {
    forall("wire-dataset-multitask", 80, |g| {
        let n = if g.usize_in(0..5) == 0 { 0 } else { g.usize_in(1..5) };
        let q = g.usize_in(1..4);
        let ds = WireDataset {
            design: WireDesign::Dense {
                n_rows: n,
                n_cols: 2,
                data: (0..n * 2).map(|_| edgy_f64(g)).collect(),
            },
            y: (0..n * q).map(|_| edgy_f64(g)).collect(),
            group_sizes: vec![2],
            tau: 0.5,
            weights: vec![2.0f64.sqrt()],
            datafit: WireDatafit::MultiTask { tasks: q as u64 },
        };
        let fp = ds.fingerprint();
        let Message::ShipDataset(back) =
            roundtrip_canonical(&Message::ShipDataset(ds.clone()))?
        else {
            return Err("variant changed in transit".to_string());
        };
        check(back.fingerprint() == fp, "fingerprint survives the trip")?;
        check(back.datafit.tasks() == q as u64, "task count survives")?;
        for (a, b) in back.y.iter().zip(&ds.y) {
            check(a.to_bits() == b.to_bits(), "response bits")?;
        }
        match back.into_problem() {
            Ok(ProblemPayload::DenseMultiTask(pb)) => {
                check(pb.n() == n && pb.p() == 2, "shape rebuilt")?;
                check(pb.tasks() == q, "task count rebuilt")?;
            }
            other => return Err(format!("expected DenseMultiTask, got {other:?}")),
        }
        // Same bytes everywhere except the task count ⇒ a different
        // dataset (the count is hashed, not inferred from `y`'s length).
        let mut other = ds;
        other.datafit = WireDatafit::MultiTask { tasks: q as u64 + 1 };
        check(other.fingerprint() != fp, "fingerprint differs by task count")
    });
}

/// A v5 peer predates chunked shipping, worker registration, and
/// progress pings; its frames must be refused outright with a typed
/// [`WireError::BadVersion`] rather than misread as v6 traffic.
#[test]
fn v5_frames_are_rejected_with_bad_version() {
    forall("wire-v5-reject", 60, |g| {
        let mut frame = gen_message(g).encode();
        assert_eq!(frame[4], WIRE_VERSION, "version byte location");
        frame[4] = 5;
        match Message::decode(&frame) {
            Err(WireError::BadVersion { got: 5 }) => Ok(()),
            other => Err(format!("expected BadVersion{{got: 5}}, got {other:?}")),
        }
    });
}

/// Chunked ships survive framing end to end: every `ShipBegin`,
/// `ShipChunk`, and `ShipEnd` frame roundtrips bit-exactly, and the
/// decoded pieces reassemble through [`ChunkAssembler`] into a dataset
/// that hashes to the declared fingerprint — dense and CSC, zero-row
/// designs and oversized singleton chunks included.
#[test]
fn chunked_ship_frames_roundtrip_and_reassemble() {
    forall("wire-chunked-roundtrip", 100, |g| {
        let ds = gen_dataset(g);
        let fp = ds.fingerprint();
        let budget = 1 + g.usize_in(0..96);
        let (begin, parts) = ds.to_chunks(budget);
        check(!parts.is_empty(), "every ship carries at least one chunk")?;
        let Message::ShipBegin(begin) = roundtrip_canonical(&Message::ShipBegin(begin))?
        else {
            return Err("begin variant changed in transit".to_string());
        };
        let mut asm =
            ChunkAssembler::new(begin).map_err(|e| format!("begin rejected: {e}"))?;
        for part in parts {
            let Message::ShipChunk(part) =
                roundtrip_canonical(&Message::ShipChunk(part))?
            else {
                return Err("chunk variant changed in transit".to_string());
            };
            asm.chunk(part).map_err(|e| format!("chunk rejected: {e}"))?;
        }
        let Message::ShipEnd { fingerprint } =
            roundtrip_canonical(&Message::ShipEnd { fingerprint: fp })?
        else {
            return Err("end variant changed in transit".to_string());
        };
        let back = asm.finish(fingerprint).map_err(|e| format!("finish rejected: {e}"))?;
        check(back.fingerprint() == fp, "assembled fingerprint matches the original")
    });
}

/// Cutting a `ShipBegin` or `ShipChunk` frame anywhere — inside the
/// length header, mid-payload, one byte short — reports a typed
/// [`WireError::Truncated`] with honest byte counts, never a panic.
#[test]
fn truncated_chunk_frames_are_typed_errors() {
    forall("wire-chunked-truncation", 80, |g| {
        let (begin, parts) = gen_chunked_ship(g);
        let i = g.usize_in(0..parts.len());
        let frame = if g.bool() {
            Message::ShipBegin(begin).encode()
        } else {
            Message::ShipChunk(parts.into_iter().nth(i).expect("chunk")).encode()
        };
        for k in 0..10 {
            let cut = if k < 4 { k.min(frame.len() - 1) } else { g.usize_in(0..frame.len()) };
            match Message::decode(&frame[..cut]) {
                Err(WireError::Truncated { needed, have }) => {
                    check(have == cut, "reported have")?;
                    check(needed > cut, "needed beyond the cut")?;
                }
                other => {
                    return Err(format!("cut {cut}: expected Truncated, got {other:?}"))
                }
            }
        }
        Ok(())
    });
}

/// Every chunk-protocol abuse a malicious or confused peer can attempt
/// lands as a typed [`WireError::Malformed`], never a panic and never a
/// silently-stored dataset: duplicate and overlapping column ranges,
/// out-of-order chunks, chunks from a different ship, an `End` whose
/// fingerprint mismatches, sealing before full coverage, and payload
/// corruption caught by the fingerprint check on `finish`.
#[test]
fn chunk_protocol_abuse_is_typed_never_a_panic() {
    forall("wire-chunked-abuse", 150, |g| {
        let (begin, parts) = gen_chunked_ship(g);
        let fp = begin.fingerprint;
        match g.usize_in(0..6) {
            0 => {
                // Duplicate: replay the first chunk after delivering it.
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                let replay = parts[0].clone();
                asm.chunk(parts[0].clone()).map_err(|e| e.to_string())?;
                match asm.chunk(replay) {
                    Err(WireError::Malformed(what)) => {
                        check(what.contains("duplicates or overlaps"), "duplicate typed")
                    }
                    other => Err(format!("duplicate chunk accepted: {other:?}")),
                }
            }
            1 => {
                // Out of order / gap: deliver the second chunk first.
                if parts.len() < 2 {
                    return Ok(());
                }
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                match asm.chunk(parts[1].clone()) {
                    Err(WireError::Malformed(what)) => {
                        check(what.contains("out of order"), "gap typed")
                    }
                    other => Err(format!("out-of-order chunk accepted: {other:?}")),
                }
            }
            2 => {
                // Overlap: stretch a later chunk back into covered ground.
                if parts.len() < 2 {
                    return Ok(());
                }
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                asm.chunk(parts[0].clone()).map_err(|e| e.to_string())?;
                let mut bad = parts[1].clone();
                bad.col_start = 0;
                match asm.chunk(bad) {
                    Err(WireError::Malformed(what)) => {
                        check(what.contains("duplicates or overlaps"), "overlap typed")
                    }
                    other => Err(format!("overlapping chunk accepted: {other:?}")),
                }
            }
            3 => {
                // A chunk interleaved from some other ship entirely.
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                let mut bad = parts[0].clone();
                bad.fingerprint ^= 1;
                match asm.chunk(bad) {
                    Err(WireError::Malformed(what)) => {
                        check(what.contains("fingerprint"), "foreign chunk typed")
                    }
                    other => Err(format!("foreign chunk accepted: {other:?}")),
                }
            }
            4 => {
                // End abuse: a mismatched fingerprint, or sealing early.
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                if g.bool() {
                    for part in parts {
                        asm.chunk(part).map_err(|e| e.to_string())?;
                    }
                    match asm.finish(fp ^ 0xdead_beef) {
                        Err(WireError::Malformed(what)) => {
                            check(what.contains("fingerprint"), "end mismatch typed")
                        }
                        other => Err(format!("mismatched end accepted: {other:?}")),
                    }
                } else {
                    match asm.finish(fp) {
                        Err(WireError::Malformed(what)) => {
                            check(what.contains("before covering"), "early end typed")
                        }
                        other => Err(format!("early end accepted: {other:?}")),
                    }
                }
            }
            _ => {
                // Corruption in transit the framing cannot see: flip one
                // bit of the *declared* content (here: τ) and deliver an
                // otherwise perfect ship — the streamed hash on `finish`
                // must refuse to store it.
                let mut begin = begin;
                begin.tau = f64::from_bits(begin.tau.to_bits() ^ 1);
                let mut asm = ChunkAssembler::new(begin).map_err(|e| e.to_string())?;
                for part in parts {
                    asm.chunk(part).map_err(|e| e.to_string())?;
                }
                match asm.finish(fp) {
                    Err(WireError::Malformed(what)) => check(
                        what.contains("does not hash to the declared fingerprint"),
                        "corruption typed",
                    ),
                    other => Err(format!("corrupted ship stored: {other:?}")),
                }
            }
        }
    });
}
