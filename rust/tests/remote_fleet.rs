//! Loopback integration tests for the distributed serving layer (L5):
//! an in-process TCP worker fleet must produce results **bit-identical**
//! to `solve_path_sharded` run locally — across backends, solvers and
//! rules, under the cross-path interleaved schedule — and must never
//! lose a shard to a killed worker (requeue onto survivors), a
//! silently-dead one (progress-deadline requeue), scripted kill/restart
//! churn (registration rejoin), or a cancelled service job (no leaked
//! slot). Chunked dataset streaming must be invisible to results.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet, WorkerOptions, WorkerServer};
use sgl::coordinator::service::{
    AnyProblem, JobStatus, ServiceConfig, SolveRequest, SolveService,
};
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::data::synthetic::{generate, generate_multitask, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::datafit::{Logistic, MultiTaskQuadratic};
use sgl::solver::path::{DualHandoff, PathOptions, PathResult};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::io::Read;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

fn spawn_fleet(n: usize, metrics: Arc<Metrics>) -> (Vec<WorkerServer>, Arc<RemoteFleet>) {
    let servers: Vec<WorkerServer> =
        (0..n).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = Arc::new(
        RemoteFleet::connect(&addrs, FleetConfig::default(), metrics).expect("connect fleet"),
    );
    (servers, fleet)
}

/// Planted-sparse instance with unit-norm `y` (absolute objective budgets).
fn planted(seed: u64) -> Arc<SglProblem> {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    Arc::new(SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2))
}

fn csc_twin(pb: &SglProblem) -> Arc<SglProblem<CscMatrix>> {
    Arc::new(SglProblem::new(
        CscMatrix::from_dense(&pb.x),
        pb.y.clone(),
        pb.groups.clone(),
        pb.tau,
    ))
}

fn opts_for(rule: RuleKind, tol: f64, delta: f64, t_count: usize) -> PathOptions {
    PathOptions {
        delta,
        t_count,
        solve: SolveOptions {
            rule,
            tol,
            max_epochs: 500_000,
            record_history: false,
            ..Default::default()
        },
    }
}

/// Local `solve_path_sharded` reference on the job's own backend.
fn local_reference(job: &InterleavedJob) -> PathResult {
    match &job.pb {
        AnyProblem::Dense(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
        AnyProblem::Csc(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
        AnyProblem::DenseLogistic(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
        AnyProblem::CscLogistic(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
        AnyProblem::DenseMultiTask(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
        AnyProblem::CscMultiTask(p) => {
            solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
        }
    }
}

fn assert_bit_identical(tag: &str, got: &PathResult, want: &PathResult) {
    assert_eq!(got.lambdas, want.lambdas, "{tag}: lambda grids");
    assert_eq!(got.results.len(), want.results.len(), "{tag}: path length");
    for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
        assert_eq!(a.beta, b.beta, "{tag} t={t}: beta must be bit-identical");
        assert_eq!(a.active.feature, b.active.feature, "{tag} t={t}: feature mask");
        assert_eq!(a.active.group, b.active.group, "{tag} t={t}: group mask");
        assert_eq!(a.epochs, b.epochs, "{tag} t={t}: epochs");
        assert_eq!(a.converged, b.converged, "{tag} t={t}: convergence");
    }
}

fn objective<D: Design>(pb: &SglProblem<D>, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
    0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

/// The tentpole equivalence: a mixed batch (dense+CSC × cd/ista/fista ×
/// every rule) interleaved over a 2-worker loopback fleet is
/// bit-identical to `solve_path_sharded` run locally, job by job.
#[test]
fn loopback_fleet_matches_local_sharded_across_backends_solvers_rules() {
    let metrics = Arc::new(Metrics::new());
    let (_servers, fleet) = spawn_fleet(2, metrics.clone());
    let dense = planted(1);
    let csc = csc_twin(&dense);

    let mut jobs: Vec<InterleavedJob> = Vec::new();
    // Every rule on the CD path, alternating backends, k=3 shards.
    for (i, rule) in RuleKind::all().into_iter().enumerate() {
        let (pb, lmax): (AnyProblem, f64) = if i % 2 == 0 {
            (AnyProblem::Dense(dense.clone()), dense.lambda_max())
        } else {
            (AnyProblem::Csc(csc.clone()), csc.lambda_max())
        };
        jobs.push(InterleavedJob {
            pb,
            lambdas: lambda_grid(lmax, 1.2, 8),
            opts: opts_for(rule, 1e-8, 1.2, 8),
            solver: SolverKind::Cd,
            shards: 3,
            label: format!("cd/{}", rule.name()),
        });
    }
    // The full-gradient solvers with the sequential rule on both
    // backends (shallower, looser path: debug-profile time).
    for solver in [SolverKind::Ista, SolverKind::Fista] {
        for backend in 0..2 {
            let (pb, lmax): (AnyProblem, f64) = if backend == 0 {
                (AnyProblem::Dense(dense.clone()), dense.lambda_max())
            } else {
                (AnyProblem::Csc(csc.clone()), csc.lambda_max())
            };
            jobs.push(InterleavedJob {
                pb,
                lambdas: lambda_grid(lmax, 0.8, 5),
                opts: opts_for(RuleKind::GapSafeSeq, 1e-7, 0.8, 5),
                solver,
                shards: 2,
                label: format!("{}/{}", solver.name(), if backend == 0 { "dense" } else { "csc" }),
            });
        }
    }

    let slots = fleet.capacity();
    assert_eq!(slots, 2);
    let fleet_exec = |job: &InterleavedJob, grid: &[f64], h: Option<&DualHandoff>| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    };
    let out = solve_batch_interleaved(&jobs, slots, fleet_exec);
    assert_eq!(out.len(), jobs.len());
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        assert_bit_identical(&job.label, got, &local_reference(job));
    }

    // Accounting: every shard solved exactly once, nothing in flight,
    // each worker shipped each dataset at most once (2 datasets total).
    let total_shards: u64 = jobs.iter().map(|j| j.shards as u64).sum();
    assert_eq!(metrics.counter("fleet_shards_solved"), total_shards);
    assert_eq!(metrics.counter("fleet_shards_requeued"), 0);
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    assert!(metrics.counter("fleet_datasets_shipped") <= 4, "ship-once per worker");
    assert!(metrics.counter("fleet_datasets_shipped") >= 2, "both datasets shipped");
    assert_eq!(fleet.in_flight(), 0);
}

/// A path whose per-shard duration is a fixed epoch budget (the gap is
/// checked only at epoch 0 and the tolerance is unreachable): remote
/// shards run long enough to kill a worker mid-shard, deterministically,
/// while staying bit-reproducible for the local comparison.
fn slow_fixed_work_request(
    pb: &Arc<SglProblem>,
    fractions: &[f64],
    shards: usize,
    label: &str,
) -> SolveRequest {
    let epochs = if cfg!(debug_assertions) { 2_500 } else { 50_000 };
    let lmax = pb.lambda_max();
    SolveRequest {
        label: label.to_string(),
        lambdas: Some(fractions.iter().map(|f| f * lmax).collect()),
        shards,
        ..SolveRequest::new(
            AnyProblem::Dense(pb.clone()),
            PathOptions {
                delta: 1.0,
                t_count: fractions.len(),
                solve: SolveOptions {
                    tol: 1e-300,
                    fce: usize::MAX,
                    max_epochs: epochs,
                    rule: RuleKind::None,
                    record_history: false,
                    ..Default::default()
                },
            },
        )
    }
}

fn wait_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_micros(500));
    }
}

/// Fault injection: drop one worker's sockets while both workers hold an
/// in-flight shard. The orphaned shard must be requeued onto the
/// survivor, every path must complete with results matching local
/// (objectives ≤ 1e-8 — in fact bit-identical, since re-solving a shard
/// from its handoff is deterministic), and the retry must show up in the
/// fleet metrics and the service's reaping counters.
#[test]
fn killed_worker_mid_shard_requeues_onto_survivor() {
    let metrics = Arc::new(Metrics::new());
    let (servers, fleet) = spawn_fleet(2, metrics.clone());
    let svc = SolveService::with_fleet(
        ServiceConfig { workers: 0, queue_depth: 16, result_capacity: 1, cache_capacity: 4 },
        metrics.clone(),
        fleet.clone(),
    );
    assert_eq!(svc.workers(), 2, "dispatch threads sized to fleet capacity");

    let pb = planted(2);
    // Two slow 4-shard paths pin both workers for the whole first shard;
    // a fast real solve rides along behind them.
    let j1 = svc.submit(slow_fixed_work_request(&pb, &[0.6, 0.5, 0.4, 0.3], 4, "slow-a")).unwrap();
    let j2 = svc.submit(slow_fixed_work_request(&pb, &[0.55, 0.45, 0.35, 0.25], 4, "slow-b")).unwrap();
    let real = SolveRequest {
        label: "real".into(),
        shards: 2,
        ..SolveRequest::new(
            AnyProblem::Dense(pb.clone()),
            opts_for(RuleKind::GapSafeSeq, 1e-8, 1.2, 8),
        )
    };
    let j3 = svc.submit(real).unwrap();

    // Both workers demonstrably mid-shard → kill one of them.
    wait_until("both workers mid-shard", Duration::from_secs(60), || fleet.in_flight() == 2);
    servers[0].kill();

    let r1 = svc.wait(j1).expect("slow-a completes on the survivor");
    let r2 = svc.wait(j2).expect("slow-b completes on the survivor");
    let r3 = svc.wait(j3).expect("real job completes on the survivor");

    // Local references (bit-identical arithmetic, shard for shard).
    let lmax = pb.lambda_max();
    let slow_opts = |t: usize| PathOptions {
        delta: 1.0,
        t_count: t,
        solve: SolveOptions {
            tol: 1e-300,
            fce: usize::MAX,
            max_epochs: if cfg!(debug_assertions) { 2_500 } else { 50_000 },
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        },
    };
    let g1: Vec<f64> = [0.6, 0.5, 0.4, 0.3].iter().map(|f| f * lmax).collect();
    let g2: Vec<f64> = [0.55, 0.45, 0.35, 0.25].iter().map(|f| f * lmax).collect();
    let w1 = solve_path_sharded(pb.as_ref(), &g1, &slow_opts(4), SolverKind::Cd, 4);
    let w2 = solve_path_sharded(pb.as_ref(), &g2, &slow_opts(4), SolverKind::Cd, 4);
    let g3 = lambda_grid(lmax, 1.2, 8);
    let w3 = solve_path_sharded(
        pb.as_ref(),
        &g3,
        &opts_for(RuleKind::GapSafeSeq, 1e-8, 1.2, 8),
        SolverKind::Cd,
        2,
    );
    assert_bit_identical("slow-a", &r1, &w1);
    assert_bit_identical("slow-b", &r2, &w2);
    assert_bit_identical("real", &r3, &w3);
    for (res, want) in [(&r1, &w1), (&r2, &w2), (&r3, &w3)] {
        for (t, (a, b)) in res.results.iter().zip(&want.results).enumerate() {
            let lam = want.lambdas[t];
            let d = (objective(pb.as_ref(), lam, &a.beta)
                - objective(pb.as_ref(), lam, &b.beta))
            .abs();
            assert!(d <= 1e-8, "t={t}: objective diverged by {d:.2e}");
        }
    }

    // The retry is visible end to end: one disconnect, at least one
    // requeued shard, every shard solved exactly once overall, and the
    // service reaped retrieved jobs past its capacity of 1.
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 1);
    assert!(metrics.counter("fleet_shards_requeued") >= 1, "orphaned shard was requeued");
    assert_eq!(metrics.counter("fleet_shards_solved"), 4 + 4 + 2);
    assert_eq!(metrics.counter("service_completed"), 3);
    assert_eq!(metrics.counter("service_failed"), 0);
    assert!(metrics.counter("service_jobs_reaped") >= 1, "reaping accounts for retrieval");
    assert_eq!(fleet.workers_alive(), 1);
    assert_eq!(fleet.in_flight(), 0);
}

/// A worker that was dead before its first exchange: the shard planned
/// for it must requeue onto the survivor — fully deterministic (the
/// least-loaded pick tries worker 0 first).
#[test]
fn dead_on_arrival_worker_requeues_deterministically() {
    let metrics = Arc::new(Metrics::new());
    let (servers, fleet) = spawn_fleet(2, metrics.clone());
    servers[0].kill();
    let pb = planted(3);
    let jobs: Vec<InterleavedJob> = (0..2)
        .map(|i| InterleavedJob {
            pb: AnyProblem::Dense(pb.clone()),
            lambdas: lambda_grid(pb.lambda_max(), 1.0, 6),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 6),
            solver: SolverKind::Cd,
            shards: 3,
            label: format!("job{i}"),
        })
        .collect();
    let out = solve_batch_interleaved(&jobs, 2, |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        assert_bit_identical(&job.label, got, &local_reference(job));
    }
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 1);
    assert!(metrics.counter("fleet_shards_requeued") >= 1);
    assert_eq!(metrics.counter("fleet_shards_solved"), 6);
    // And with *no* survivors, the failure is a typed error, not a hang.
    servers[1].kill();
    let err = fleet
        .solve_shard(
            &AnyProblem::Dense(pb.clone()),
            &lambda_grid(pb.lambda_max(), 1.0, 2),
            &opts_for(RuleKind::GapSafe, 1e-6, 1.0, 2),
            SolverKind::Cd,
            None,
        )
        .expect_err("no survivors");
    assert!(format!("{err:#}").contains("no surviving workers"), "{err:#}");
}

/// `Service::cancel` on a job whose shard is already dispatched to a
/// remote worker must not leak the worker slot: the in-flight count
/// returns to 0 once the discarded shard drains, and the slot serves the
/// next job.
#[test]
fn cancel_of_dispatched_job_returns_the_fleet_slot() {
    let metrics = Arc::new(Metrics::new());
    let (_servers, fleet) = spawn_fleet(1, metrics.clone());
    let svc = SolveService::with_fleet(
        ServiceConfig { workers: 0, queue_depth: 8, ..Default::default() },
        metrics.clone(),
        fleet.clone(),
    );
    let pb = planted(4);
    let victim = svc.submit(slow_fixed_work_request(&pb, &[0.5], 1, "victim")).unwrap();
    wait_until("the shard to be dispatched", Duration::from_secs(60), || {
        fleet.in_flight() == 1 && svc.poll(victim) == Some(JobStatus::Running)
    });
    assert!(svc.cancel(victim), "cancel must land while dispatched");
    assert_eq!(svc.poll(victim), Some(JobStatus::Cancelled));
    // The remote shard finishes and is discarded; the slot must drain.
    wait_until("the fleet slot to drain", Duration::from_secs(60), || fleet.in_flight() == 0);
    // The slot is reusable: a real job completes on it afterwards.
    let next = svc
        .submit(SolveRequest {
            label: "after-cancel".into(),
            ..SolveRequest::new(
                AnyProblem::Dense(pb.clone()),
                opts_for(RuleKind::GapSafe, 1e-6, 1.0, 4),
            )
        })
        .unwrap();
    let res = svc.wait(next).expect("slot serves the next job");
    assert!(res.all_converged());
    assert_eq!(fleet.in_flight(), 0);
    assert_eq!(metrics.counter("service_cancelled"), 1);
    // The cancelled job's only dispatched shard ran once; its
    // continuation never entered the queue.
    assert_eq!(metrics.counter("fleet_shards_solved"), 2);
    assert_eq!(fleet.workers_alive(), 1, "cancel is not a worker failure");
}

/// Classification twin of [`planted`]: the same design with labels
/// binarized at the response mean, on the CSC backend.
fn planted_logistic(seed: u64) -> Arc<SglProblem<CscMatrix, Logistic>> {
    let base = planted(seed);
    let mean = base.y.iter().sum::<f64>() / base.y.len() as f64;
    let labels: Vec<f64> = base.y.iter().map(|&v| f64::from(v > mean)).collect();
    Arc::new(SglProblem::with_datafit(
        CscMatrix::from_dense(&base.x),
        labels,
        base.groups.clone(),
        base.tau,
        base.groups.sqrt_size_weights(),
        Logistic,
    ))
}

/// Multi-response twin on the dense backend (task-major `y`).
fn planted_multitask(
    seed: u64,
    tasks: usize,
) -> Arc<SglProblem<sgl::linalg::Matrix, MultiTaskQuadratic>> {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 30,
        group_size: 4,
        gamma1: 5,
        gamma2: 2,
        seed,
        ..Default::default()
    };
    let d = generate_multitask(&cfg, tasks);
    let weights = d.dataset.groups.sqrt_size_weights();
    Arc::new(SglProblem::with_datafit(
        d.dataset.x,
        d.dataset.y,
        d.dataset.groups,
        0.2,
        weights,
        MultiTaskQuadratic::new(tasks),
    ))
}

/// Chunked dataset streaming must be invisible to results: with a chunk
/// budget far below the dataset's encoding (512 bytes against tens of
/// kilobytes — one design column per chunk), both backends still solve
/// bit-identically to local, the shipped-set commits exactly once per
/// dataset, and the worker's assembler verifies and stores every ship.
#[test]
fn tiny_chunk_budget_streams_datasets_and_stays_bit_identical() {
    let metrics = Arc::new(Metrics::new());
    let server = WorkerServer::bind("127.0.0.1:0").expect("bind worker");
    let addrs = vec![server.local_addr().to_string()];
    let fleet = Arc::new(
        RemoteFleet::connect(
            &addrs,
            FleetConfig { ship_chunk_bytes: 512, ..FleetConfig::default() },
            metrics.clone(),
        )
        .expect("connect fleet"),
    );
    let dense = planted(5);
    let csc = csc_twin(&dense);
    let jobs = vec![
        InterleavedJob {
            pb: AnyProblem::Dense(dense.clone()),
            lambdas: lambda_grid(dense.lambda_max(), 1.0, 6),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 6),
            solver: SolverKind::Cd,
            shards: 2,
            label: "dense/chunked".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.0, 6),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 6),
            solver: SolverKind::Cd,
            shards: 2,
            label: "csc/chunked".into(),
        },
    ];
    let out = solve_batch_interleaved(&jobs, 1, |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        assert_bit_identical(&job.label, got, &local_reference(job));
    }
    // Each dataset shipped exactly once (commit-on-ack), in many chunks.
    assert_eq!(metrics.counter("fleet_datasets_shipped"), 2);
    let chunks = metrics.counter("fleet_dataset_chunks_shipped");
    assert!(chunks >= 4, "512-byte budget must split both datasets: {chunks} chunks");
    // Worker-side truth: every ship arrived chunked, reassembled, and
    // passed its fingerprint check before being stored.
    fleet.scrape(Duration::from_secs(5));
    assert_eq!(metrics.counter("worker_0_worker_chunked_ships_opened"), 2);
    assert_eq!(metrics.counter("worker_0_worker_chunked_ships_completed"), 2);
    assert_eq!(metrics.counter("worker_0_worker_chunks_received"), chunks);
    assert_eq!(metrics.counter("worker_0_worker_datasets_stored"), 2);
    assert_eq!(metrics.counter("fleet_shards_requeued"), 0);
    assert_eq!(fleet.in_flight(), 0);
}

/// A fake worker that accepts fleet connections and swallows every
/// frame without ever replying — the silent-death mode (wedged kernel,
/// partitioned host) that used to hang an exchange forever.
fn silent_worker() -> SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind silent worker");
    let addr = listener.local_addr().expect("local addr");
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(mut s) = stream else { continue };
            thread::spawn(move || {
                let mut buf = [0u8; 4096];
                while matches!(s.read(&mut buf), Ok(n) if n > 0) {}
            });
        }
    });
    addr
}

/// Progress-ping liveness, both directions at once: a worker that goes
/// *silent* trips `progress_deadline` and its shard requeues onto the
/// survivor, while a *legitimately slow* solve on the survivor runs far
/// past the same deadline because its pings keep re-arming the clock —
/// no socket read deadline ever bounds solve time.
#[test]
fn silent_worker_trips_the_progress_deadline_while_pings_keep_slow_solves_alive() {
    let metrics = Arc::new(Metrics::new());
    // Worker 0 is silent-dead; worker 1 is real and pings every 25 ms.
    let silent = silent_worker();
    let server = WorkerServer::bind_with(
        "127.0.0.1:0",
        WorkerOptions { progress_interval: Duration::from_millis(25), ..Default::default() },
    )
    .expect("bind real worker");
    let addrs = vec![silent.to_string(), server.local_addr().to_string()];
    let fleet = Arc::new(
        RemoteFleet::connect(
            &addrs,
            FleetConfig { progress_deadline: Duration::from_secs(1), ..FleetConfig::default() },
            metrics.clone(),
        )
        .expect("connect fleet"),
    );
    // One fixed-work path long enough to dwarf the 1 s deadline; the
    // least-loaded pick dispatches its first shard to the silent worker.
    let pb = planted(6);
    let epochs = if cfg!(debug_assertions) { 2_500 } else { 50_000 };
    let lmax = pb.lambda_max();
    let lambdas: Vec<f64> = [0.6, 0.5, 0.4, 0.3].iter().map(|f| f * lmax).collect();
    let opts = PathOptions {
        delta: 1.0,
        t_count: 4,
        solve: SolveOptions {
            tol: 1e-300,
            fce: usize::MAX,
            max_epochs: epochs,
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        },
    };
    let got = fleet
        .solve_shard(&AnyProblem::Dense(pb.clone()), &lambdas, &opts, SolverKind::Cd, None)
        .expect("shard survives the silent worker");
    let want = solve_path_sharded(pb.as_ref(), &lambdas, &opts, SolverKind::Cd, 1);
    assert_bit_identical("silent-dead", &got, &want);
    // The silent worker was written off by the deadline (not by the OS
    // hours later), its shard requeued, and the survivor's long solve
    // demonstrably outlived the deadline on the back of its pings.
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 1);
    assert!(metrics.counter("fleet_shards_requeued") >= 1, "silent shard requeued");
    assert!(metrics.counter("fleet_progress_pings") >= 1, "survivor pinged mid-solve");
    assert_eq!(metrics.counter("fleet_shards_solved"), 1);
    assert_eq!(fleet.workers_alive(), 1);
    assert_eq!(fleet.in_flight(), 0);
}

/// The chaos-replay prove-out: a mixed quadratic + logistic + multitask
/// batch under scripted worker kill/restart churn — every killed worker
/// is replaced by a fresh one announcing itself through the
/// registration listener — must finish **bit-identical** to the local
/// engine with **zero lost jobs** and every shard solved exactly once.
#[test]
fn chaos_churn_mixed_batch_is_bit_identical_with_zero_lost_jobs() {
    let metrics = Arc::new(Metrics::new());
    let servers: Vec<WorkerServer> =
        (0..2).map(|_| WorkerServer::bind("127.0.0.1:0").expect("bind worker")).collect();
    let addrs: Vec<String> = servers.iter().map(|s| s.local_addr().to_string()).collect();
    let fleet = Arc::new(
        RemoteFleet::connect(
            &addrs,
            // A rejoin grace so even a momentarily worker-less fleet
            // waits for the next replacement instead of failing shards.
            FleetConfig { rejoin_grace: Duration::from_secs(60), ..FleetConfig::default() },
            metrics.clone(),
        )
        .expect("connect fleet"),
    );
    let reg = fleet.serve_registrations("127.0.0.1:0").expect("registration listener");

    let dense = planted(7);
    let csc = csc_twin(&dense);
    let logistic = planted_logistic(7);
    let mt = planted_multitask(7, 3);
    let epochs = if cfg!(debug_assertions) { 2_500 } else { 50_000 };
    let lmax = dense.lambda_max();
    let slow_grid: Vec<f64> = [0.6, 0.5, 0.4, 0.3].iter().map(|f| f * lmax).collect();
    let slow_opts = PathOptions {
        delta: 1.0,
        t_count: 4,
        solve: SolveOptions {
            tol: 1e-300,
            fce: usize::MAX,
            max_epochs: epochs,
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        },
    };
    let jobs = vec![
        // A fixed-epoch path that keeps the batch alive long enough for
        // several churn rounds to land mid-solve, deterministically.
        InterleavedJob {
            pb: AnyProblem::Dense(dense.clone()),
            lambdas: slow_grid,
            opts: slow_opts,
            solver: SolverKind::Cd,
            shards: 4,
            label: "quadratic/slow".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.0, 6),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 6),
            solver: SolverKind::Cd,
            shards: 3,
            label: "quadratic/csc".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscLogistic(logistic.clone()),
            lambdas: lambda_grid(logistic.lambda_max(), 1.0, 5),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 5),
            solver: SolverKind::Cd,
            shards: 2,
            label: "logistic".into(),
        },
        InterleavedJob {
            pb: AnyProblem::DenseMultiTask(mt.clone()),
            lambdas: lambda_grid(mt.lambda_max(), 1.0, 5),
            opts: opts_for(RuleKind::GapSafeSeq, 1e-8, 1.0, 5),
            solver: SolverKind::Cd,
            shards: 3,
            label: "multitask".into(),
        },
    ];

    // Scripted churn: every 80 ms kill the oldest survivor and register
    // a fresh replacement, waiting for it to join before the next round.
    let stop = Arc::new(AtomicBool::new(false));
    let churn = {
        let fleet = fleet.clone();
        let reg = reg.to_string();
        let stop = stop.clone();
        let mut pool = servers;
        thread::spawn(move || {
            for round in 0..4u64 {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                thread::sleep(Duration::from_millis(80));
                let victim = pool.remove(0);
                victim.kill();
                drop(victim);
                let fresh = WorkerServer::bind("127.0.0.1:0").expect("bind replacement");
                fresh.register(&reg);
                let deadline = Instant::now() + Duration::from_secs(30);
                while fleet.metrics().counter("fleet_workers_joined") <= round
                    && Instant::now() < deadline
                {
                    thread::sleep(Duration::from_millis(5));
                }
                pool.push(fresh);
            }
            pool // survivors stay alive until the batch is done
        })
    };

    let out = solve_batch_interleaved(&jobs, 2, |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    stop.store(true, Ordering::SeqCst);
    let _pool = churn.join().expect("churn thread");

    // Zero lost jobs: every job completed, and bit-identically so.
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} lost to churn: {e:#}", job.label));
        assert_bit_identical(&job.label, got, &local_reference(job));
    }
    // Every shard solved exactly once from the coordinator's view, the
    // churn demonstrably hit the fleet, and replacements joined by
    // announcing themselves — nothing was re-dialed by address.
    let total_shards: u64 = jobs.iter().map(|j| j.shards as u64).sum();
    assert_eq!(metrics.counter("fleet_shards_solved"), total_shards);
    assert!(metrics.counter("fleet_worker_disconnects") >= 1, "churn landed mid-batch");
    assert!(metrics.counter("fleet_workers_joined") >= 1, "replacements registered");
    assert_eq!(fleet.in_flight(), 0);
}
