//! End-to-end over *real* worker processes: spawn two `sgl worker`
//! children (the actual binary, talking over real loopback TCP), run a
//! mixed sharded batch — both backends *and* both datafits (least-squares
//! regression alongside logistic classification) — against them through
//! the fleet, and require bit-identity with the local engine. CI runs
//! this leg with `SGL_THREADS=2` to keep the runner honest about
//! parallelism.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet};
use sgl::coordinator::service::AnyProblem;
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::CscMatrix;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::datafit::Logistic;
use sgl::solver::path::PathOptions;
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

/// A spawned `sgl worker` child, killed on drop (panic-safe).
struct WorkerProcess {
    child: Child,
    addr: String,
}

impl WorkerProcess {
    fn spawn() -> WorkerProcess {
        let exe = env!("CARGO_BIN_EXE_sgl");
        let mut child = Command::new(exe)
            .args(["worker", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn sgl worker");
        // The worker announces its bound address as its first stdout
        // line: `worker listening on 127.0.0.1:PORT`.
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read announcement");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .unwrap_or_else(|| panic!("unparsable worker announcement {line:?}"))
            .to_string();
        assert!(addr.contains(':'), "unparsable worker announcement {line:?}");
        WorkerProcess { child, addr }
    }
}

impl Drop for WorkerProcess {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn two_worker_processes_serve_a_mixed_batch_bit_identically() {
    let workers = [WorkerProcess::spawn(), WorkerProcess::spawn()];
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
    let metrics = Arc::new(Metrics::new());
    let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
        .expect("connect to worker processes");
    assert_eq!(fleet.capacity(), 2);
    let alive = fleet.heartbeat(Duration::from_secs(10));
    assert!(alive.iter().all(|(_, up)| up.is_alive()), "{alive:?}");

    let cfg = SyntheticConfig {
        n: 50,
        n_groups: 20,
        group_size: 4,
        gamma1: 4,
        gamma2: 2,
        seed: 17,
        ..Default::default()
    };
    let d = generate(&cfg);
    let dense =
        Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.25));
    let csc = Arc::new(SglProblem::new(
        CscMatrix::from_dense(&dense.x),
        dense.y.clone(),
        dense.groups.clone(),
        dense.tau,
    ));
    // Classification twin: the same design with labels binarized at the
    // response mean — the batch below mixes both datafits over one fleet.
    let mean = dense.y.iter().sum::<f64>() / dense.y.len() as f64;
    let labels: Vec<f64> = dense.y.iter().map(|&v| f64::from(v > mean)).collect();
    let logistic = Arc::new(SglProblem::with_datafit(
        CscMatrix::from_dense(&dense.x),
        labels,
        dense.groups.clone(),
        dense.tau,
        dense.groups.sqrt_size_weights(),
        Logistic,
    ));

    let opts = |rule: RuleKind| PathOptions {
        delta: 1.2,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
    };
    let jobs = vec![
        InterleavedJob {
            pb: AnyProblem::Dense(dense.clone()),
            lambdas: lambda_grid(dense.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "dense/gap_safe_seq".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafe),
            solver: SolverKind::Cd,
            shards: 2,
            label: "csc/gap_safe".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "csc/gap_safe_seq".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscLogistic(logistic.clone()),
            lambdas: lambda_grid(logistic.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 2,
            label: "logistic/gap_safe_seq".into(),
        },
    ];

    let out = solve_batch_interleaved(&jobs, fleet.capacity(), |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        let want = match &job.pb {
            AnyProblem::Dense(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::Csc(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
        };
        assert_eq!(got.lambdas, want.lambdas, "{}", job.label);
        for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
            assert_eq!(a.beta, b.beta, "{} t={t}: bit-identical over real TCP", job.label);
            assert_eq!(a.active.feature, b.active.feature, "{} t={t}", job.label);
            assert_eq!(a.epochs, b.epochs, "{} t={t}", job.label);
        }
    }
    assert_eq!(metrics.counter("fleet_shards_solved"), 10);
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    assert_eq!(fleet.in_flight(), 0);
}
