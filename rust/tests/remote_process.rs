//! End-to-end over *real* worker processes: spawn two `sgl worker`
//! children (the actual binary, talking over real loopback TCP), run a
//! mixed sharded batch — both backends *and* both datafits (least-squares
//! regression alongside logistic classification) — against them through
//! the fleet, and require bit-identity with the local engine. CI runs
//! this leg with `SGL_THREADS=2` to keep the runner honest about
//! parallelism.

use sgl::coordinator::metrics::Metrics;
use sgl::coordinator::remote::{FleetConfig, RemoteFleet};
use sgl::coordinator::service::AnyProblem;
use sgl::coordinator::shard::{solve_batch_interleaved, solve_path_sharded, InterleavedJob};
use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::CscMatrix;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::datafit::Logistic;
use sgl::solver::path::PathOptions;
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::SolverKind;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// A spawned `sgl worker` child, killed on drop (panic-safe).
struct WorkerProcess {
    child: Child,
    addr: String,
}

impl WorkerProcess {
    fn spawn() -> WorkerProcess {
        Self::spawn_args(&[])
    }

    fn spawn_args(extra: &[&str]) -> WorkerProcess {
        let exe = env!("CARGO_BIN_EXE_sgl");
        let mut child = Command::new(exe)
            .args(["worker", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn sgl worker");
        // The worker announces its bound address as its first stdout
        // line: `worker listening on 127.0.0.1:PORT`.
        let stdout = child.stdout.take().expect("piped stdout");
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).expect("read announcement");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .unwrap_or_else(|| panic!("unparsable worker announcement {line:?}"))
            .to_string();
        assert!(addr.contains(':'), "unparsable worker announcement {line:?}");
        WorkerProcess { child, addr }
    }
}

impl Drop for WorkerProcess {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

#[test]
fn two_worker_processes_serve_a_mixed_batch_bit_identically() {
    let workers = [WorkerProcess::spawn(), WorkerProcess::spawn()];
    let addrs: Vec<String> = workers.iter().map(|w| w.addr.clone()).collect();
    let metrics = Arc::new(Metrics::new());
    let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), metrics.clone())
        .expect("connect to worker processes");
    assert_eq!(fleet.capacity(), 2);
    let alive = fleet.heartbeat(Duration::from_secs(10));
    assert!(alive.iter().all(|(_, up)| up.is_alive()), "{alive:?}");

    let cfg = SyntheticConfig {
        n: 50,
        n_groups: 20,
        group_size: 4,
        gamma1: 4,
        gamma2: 2,
        seed: 17,
        ..Default::default()
    };
    let d = generate(&cfg);
    let dense =
        Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.25));
    let csc = Arc::new(SglProblem::new(
        CscMatrix::from_dense(&dense.x),
        dense.y.clone(),
        dense.groups.clone(),
        dense.tau,
    ));
    // Classification twin: the same design with labels binarized at the
    // response mean — the batch below mixes both datafits over one fleet.
    let mean = dense.y.iter().sum::<f64>() / dense.y.len() as f64;
    let labels: Vec<f64> = dense.y.iter().map(|&v| f64::from(v > mean)).collect();
    let logistic = Arc::new(SglProblem::with_datafit(
        CscMatrix::from_dense(&dense.x),
        labels,
        dense.groups.clone(),
        dense.tau,
        dense.groups.sqrt_size_weights(),
        Logistic,
    ));

    let opts = |rule: RuleKind| PathOptions {
        delta: 1.2,
        t_count: 6,
        solve: SolveOptions { rule, tol: 1e-8, record_history: false, ..Default::default() },
    };
    let jobs = vec![
        InterleavedJob {
            pb: AnyProblem::Dense(dense.clone()),
            lambdas: lambda_grid(dense.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "dense/gap_safe_seq".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafe),
            solver: SolverKind::Cd,
            shards: 2,
            label: "csc/gap_safe".into(),
        },
        InterleavedJob {
            pb: AnyProblem::Csc(csc.clone()),
            lambdas: lambda_grid(csc.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 3,
            label: "csc/gap_safe_seq".into(),
        },
        InterleavedJob {
            pb: AnyProblem::CscLogistic(logistic.clone()),
            lambdas: lambda_grid(logistic.lambda_max(), 1.2, 6),
            opts: opts(RuleKind::GapSafeSeq),
            solver: SolverKind::Cd,
            shards: 2,
            label: "logistic/gap_safe_seq".into(),
        },
    ];

    let out = solve_batch_interleaved(&jobs, fleet.capacity(), |job, grid, h| {
        fleet.solve_shard(&job.pb, grid, &job.opts, job.solver, h)
    });
    for (job, got) in jobs.iter().zip(&out) {
        let got = got.as_ref().unwrap_or_else(|e| panic!("{} failed: {e:#}", job.label));
        let want = match &job.pb {
            AnyProblem::Dense(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::Csc(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscLogistic(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::DenseMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
            AnyProblem::CscMultiTask(p) => {
                solve_path_sharded(p.as_ref(), &job.lambdas, &job.opts, job.solver, job.shards)
            }
        };
        assert_eq!(got.lambdas, want.lambdas, "{}", job.label);
        for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
            assert_eq!(a.beta, b.beta, "{} t={t}: bit-identical over real TCP", job.label);
            assert_eq!(a.active.feature, b.active.feature, "{} t={t}", job.label);
            assert_eq!(a.epochs, b.epochs, "{} t={t}", job.label);
        }
    }
    assert_eq!(metrics.counter("fleet_shards_solved"), 10);
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 0);
    assert_eq!(fleet.in_flight(), 0);
}

/// Kill a real worker *process* mid-solve, then start a replacement
/// that announces itself to the coordinator with `--register`: the
/// orphaned shard must wait out the rejoin grace, land on the
/// replacement, and finish **bit-identically** to the local engine —
/// the full self-healing loop over real processes and real TCP.
#[test]
fn killed_worker_process_is_replaced_by_a_registered_restart() {
    let mut victim = WorkerProcess::spawn();
    let metrics = Arc::new(Metrics::new());
    let fleet = Arc::new(
        RemoteFleet::connect(
            &[victim.addr.clone()],
            FleetConfig { rejoin_grace: Duration::from_secs(120), ..FleetConfig::default() },
            metrics.clone(),
        )
        .expect("connect to worker process"),
    );
    let reg = fleet.serve_registrations("127.0.0.1:0").expect("registration listener");

    // A fixed-epoch path (unreachable tolerance, no screening) so the
    // solve runs long enough to be killed mid-shard yet stays exactly
    // reproducible for the local comparison.
    let cfg = SyntheticConfig {
        n: 50,
        n_groups: 20,
        group_size: 4,
        gamma1: 4,
        gamma2: 2,
        seed: 29,
        ..Default::default()
    };
    let d = generate(&cfg);
    let pb = Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.25));
    let epochs = if cfg!(debug_assertions) { 2_500 } else { 50_000 };
    let lmax = pb.lambda_max();
    let lambdas: Vec<f64> = [0.6, 0.5, 0.4, 0.3].iter().map(|f| f * lmax).collect();
    let opts = PathOptions {
        delta: 1.0,
        t_count: 4,
        solve: SolveOptions {
            tol: 1e-300,
            fce: usize::MAX,
            max_epochs: epochs,
            rule: RuleKind::None,
            record_history: false,
            ..Default::default()
        },
    };

    let solver = {
        let fleet = fleet.clone();
        let pb = pb.clone();
        let lambdas = lambdas.clone();
        let opts = opts.clone();
        thread::spawn(move || {
            fleet.solve_shard(&AnyProblem::Dense(pb), &lambdas, &opts, SolverKind::Cd, None)
        })
    };

    // Provably mid-shard, then kill the child process outright.
    let deadline = Instant::now() + Duration::from_secs(60);
    while fleet.in_flight() == 0 && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(fleet.in_flight(), 1, "shard dispatched to the victim");
    thread::sleep(Duration::from_millis(100));
    victim.child.kill().expect("kill worker process");
    let _ = victim.child.wait();

    // The replacement rejoins by announcing itself — the coordinator
    // never re-dials a configured address.
    let replacement = WorkerProcess::spawn_args(&["--register", &reg.to_string()]);
    let got = solver
        .join()
        .expect("solver thread")
        .expect("zero lost jobs: the shard finished on the replacement");
    let want = solve_path_sharded(pb.as_ref(), &lambdas, &opts, SolverKind::Cd, 1);
    assert_eq!(got.lambdas, want.lambdas);
    for (t, (a, b)) in want.results.iter().zip(&got.results).enumerate() {
        assert_eq!(a.beta, b.beta, "t={t}: bit-identical across the restart");
        assert_eq!(a.epochs, b.epochs, "t={t}: epochs");
    }
    assert_eq!(metrics.counter("fleet_worker_disconnects"), 1);
    assert!(metrics.counter("fleet_shards_requeued") >= 1, "orphaned shard requeued");
    assert_eq!(metrics.counter("fleet_workers_joined"), 1);
    assert_eq!(metrics.counter("fleet_shards_solved"), 1);
    assert_eq!(fleet.workers_alive(), 1);
    assert_eq!(fleet.in_flight(), 0);
    drop(replacement);
}
