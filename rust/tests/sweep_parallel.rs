//! Parallel-vs-serial sweep equivalence (the `sweep = "parallel"` mode of
//! `solver::sweep`):
//!
//! - CD's bulk-synchronous rounds take a different trajectory than the
//!   cyclic sweep, so the contract is *outcome* equivalence: identical
//!   terminal screening decisions and ≤ 1e-8 objective agreement, across
//!   dense/csc backends and every screening rule;
//! - ISTA/FISTA sweeps are Jacobi by construction, so their parallel mode
//!   must reproduce the serial runs **bit for bit**;
//! - safety: a parallel sweep must never screen a feature that is nonzero
//!   in a high-precision no-screening reference (Theorem 1 holds for any
//!   iterate, parallel or not).

use sgl::data::synthetic::{generate, SyntheticConfig};
use sgl::linalg::{CscMatrix, Design};
use sgl::norms::sgl::omega;
use sgl::screening::RuleKind;
use sgl::solver::cd::SolveOptions;
use sgl::solver::path::{solve_path_with, PathOptions};
use sgl::solver::problem::{lambda_grid, SglProblem};
use sgl::solver::sweep::SweepMode;
use sgl::solver::SolverKind;

/// Planted instance with unit-norm `y` (absolute objective budgets) and
/// strongly separated signal groups. Sized so the parallel kernels cross
/// their engage() floors with 2 sweep threads (p = 200, 40 groups).
fn planted(seed: u64) -> SglProblem {
    let cfg = SyntheticConfig {
        n: 60,
        n_groups: 40,
        group_size: 5,
        gamma1: 6,
        gamma2: 3,
        seed,
        ..Default::default()
    };
    let d = generate(&cfg);
    let y_norm = d.dataset.y.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
    let y: Vec<f64> = d.dataset.y.iter().map(|v| v / y_norm).collect();
    SglProblem::new(d.dataset.x, y, d.dataset.groups, 0.2)
}

fn to_csc(pb: &SglProblem) -> SglProblem<CscMatrix> {
    SglProblem::new(CscMatrix::from_dense(&pb.x), pb.y.clone(), pb.groups.clone(), pb.tau)
}

fn popts(rule: RuleKind, tol: f64, sweep: SweepMode, t_count: usize) -> PathOptions {
    PathOptions {
        delta: 1.0,
        t_count,
        solve: SolveOptions {
            rule,
            tol,
            max_epochs: 500_000,
            record_history: false,
            sweep,
            sweep_threads: 2,
            ..Default::default()
        },
    }
}

fn objective<D: Design>(pb: &SglProblem<D>, lambda: f64, beta: &[f64]) -> f64 {
    let xb = pb.x.matvec(beta);
    let r2: f64 = pb.y.iter().zip(&xb).map(|(y, v)| (y - v) * (y - v)).sum();
    0.5 * r2 + lambda * omega(beta, &pb.groups, pb.tau, &pb.weights)
}

/// CD: same terminal screening decisions, objectives within 1e-8 (tol is
/// 5e-9 on a unit-norm `y`, so each run sits within 5e-9 of the optimum).
fn assert_cd_outcome_equivalent<D: Design>(
    pb: &SglProblem<D>,
    lambdas: &[f64],
    rule: RuleKind,
    tag: &str,
) {
    let serial = solve_path_with(
        pb,
        lambdas,
        &popts(rule, 5e-9, SweepMode::Serial, lambdas.len()),
        SolverKind::Cd,
    );
    let par = solve_path_with(
        pb,
        lambdas,
        &popts(rule, 5e-9, SweepMode::Parallel, lambdas.len()),
        SolverKind::Cd,
    );
    assert!(serial.all_converged(), "{tag}: serial did not converge");
    assert!(par.all_converged(), "{tag}: parallel did not converge");
    for (i, &lambda) in lambdas.iter().enumerate() {
        let a = &serial.results[i];
        let b = &par.results[i];
        assert_eq!(a.active.feature, b.active.feature, "{tag}: feature masks differ at t={i}");
        assert_eq!(a.active.group, b.active.group, "{tag}: group masks differ at t={i}");
        let oa = objective(pb, lambda, &a.beta);
        let ob = objective(pb, lambda, &b.beta);
        assert!(
            (oa - ob).abs() <= 1e-8,
            "{tag}: objectives diverged at t={i}: {oa} vs {ob}"
        );
    }
}

#[test]
fn cd_parallel_matches_serial_across_backends_and_rules() {
    let pb = planted(1);
    let spb = to_csc(&pb);
    let lambdas = lambda_grid(pb.lambda_max(), 1.0, 4);
    for rule in RuleKind::all() {
        assert_cd_outcome_equivalent(&pb, &lambdas, rule, &format!("dense/{}", rule.name()));
        assert_cd_outcome_equivalent(&spb, &lambdas, rule, &format!("csc/{}", rule.name()));
    }
}

/// ISTA/FISTA: the parallel sweeps must be bit-identical to serial.
fn assert_full_gradient_bit_identical<D: Design>(
    pb: &SglProblem<D>,
    lambdas: &[f64],
    rule: RuleKind,
    solver: SolverKind,
    tag: &str,
) {
    let serial = solve_path_with(
        pb,
        lambdas,
        &popts(rule, 1e-7, SweepMode::Serial, lambdas.len()),
        solver,
    );
    let par = solve_path_with(
        pb,
        lambdas,
        &popts(rule, 1e-7, SweepMode::Parallel, lambdas.len()),
        solver,
    );
    assert!(serial.all_converged() && par.all_converged(), "{tag}: convergence");
    for (i, (a, b)) in serial.results.iter().zip(&par.results).enumerate() {
        assert_eq!(a.beta, b.beta, "{tag}: coefficients differ at t={i}");
        assert_eq!(a.epochs, b.epochs, "{tag}: epoch counts differ at t={i}");
        assert_eq!(a.active.feature, b.active.feature, "{tag}: masks differ at t={i}");
    }
}

#[test]
fn ista_parallel_is_bit_identical_across_backends_and_rules() {
    let pb = planted(2);
    let spb = to_csc(&pb);
    let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
    for rule in RuleKind::all() {
        let tag = format!("ista/dense/{}", rule.name());
        assert_full_gradient_bit_identical(&pb, &lambdas, rule, SolverKind::Ista, &tag);
        let tag = format!("ista/csc/{}", rule.name());
        assert_full_gradient_bit_identical(&spb, &lambdas, rule, SolverKind::Ista, &tag);
    }
}

#[test]
fn fista_parallel_is_bit_identical_across_backends_and_rules() {
    let pb = planted(3);
    let spb = to_csc(&pb);
    let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
    for rule in RuleKind::all() {
        let tag = format!("fista/dense/{}", rule.name());
        assert_full_gradient_bit_identical(&pb, &lambdas, rule, SolverKind::Fista, &tag);
        let tag = format!("fista/csc/{}", rule.name());
        assert_full_gradient_bit_identical(&spb, &lambdas, rule, SolverKind::Fista, &tag);
    }
}

#[test]
fn parallel_sweeps_never_screen_live_features() {
    let pb = planted(4);
    let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
    // High-precision no-screening reference, serial.
    let reference = solve_path_with(
        &pb,
        &lambdas,
        &popts(RuleKind::None, 1e-12, SweepMode::Serial, lambdas.len()),
        SolverKind::Cd,
    );
    assert!(reference.all_converged());
    for solver in [SolverKind::Cd, SolverKind::Fista] {
        for rule in [
            RuleKind::Static,
            RuleKind::Dynamic,
            RuleKind::Dst3,
            RuleKind::GapSafe,
            RuleKind::GapSafeSeq,
        ] {
            let path = solve_path_with(
                &pb,
                &lambdas,
                &popts(rule, 1e-8, SweepMode::Parallel, lambdas.len()),
                solver,
            );
            assert!(path.all_converged(), "{solver:?}/{rule:?}");
            for (i, res) in path.results.iter().enumerate() {
                for j in 0..pb.p() {
                    if !res.active.feature[j] {
                        assert!(
                            reference.results[i].beta[j].abs() < 1e-6,
                            "{solver:?}/{rule:?} t={i}: screened feature {j} \
                             with reference beta {}",
                            reference.results[i].beta[j]
                        );
                    }
                }
            }
        }
    }
}
