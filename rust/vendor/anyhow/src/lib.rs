//! Vendored minimal `anyhow` replacement.
//!
//! The build image has no crates.io access, so the ergonomic error type the
//! codebase was written against is provided as this local path dependency:
//! an [`Error`] carrying a message chain, the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror the subset of the real `anyhow` this project uses:
//! `{}` displays the outermost message, `{:#}` the whole `a: b: c` chain,
//! `{:?}` adds a "Caused by" listing, `?` converts any
//! `std::error::Error` via the blanket `From` impl (possible precisely
//! because [`Error`] itself does *not* implement `std::error::Error` —
//! the same coherence trick the real crate uses), and typed errors built
//! with [`Error::new`] keep their concrete value as a payload so callers
//! can recover it with [`Error::downcast_ref`] anywhere in the chain.

use std::any::Any;
use std::fmt;

/// An error: a message plus an optional cause chain, optionally carrying
/// the original typed error value for downcasting.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string(), source: None, payload: None }
    }

    /// Build from a typed error, capturing its source chain as messages
    /// and keeping the value itself for [`Error::downcast_ref`].
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut out = Error::from_std_chain(&e);
        out.payload = Some(Box::new(e));
        out
    }

    /// Message-chain skeleton of a std error (no payload attached).
    fn from_std_chain(e: &(dyn std::error::Error + 'static)) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut cur = e.source();
        while let Some(c) = cur {
            msgs.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new), payload: None });
        }
        err.expect("non-empty chain")
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)), payload: None }
    }

    /// The first payload in the chain (outermost first) that is an `E`.
    /// Context wrapping never loses the payload: `downcast_ref` walks the
    /// whole cause chain.
    pub fn downcast_ref<E: Any>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(p) = e.payload.as_ref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(p);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// Whether any error in the chain carries an `E` payload.
    pub fn is<E: Any>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the chain from the outermost message inward.
    pub fn chain<'a>(&'a self) -> impl Iterator<Item = &'a str> + 'a {
        let mut cur = Some(self);
        std::iter::from_fn(move || {
            let e = cur?;
            cur = e.source.as_deref();
            Some(e.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(first) = self.source.as_deref() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = Some(first);
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error`, capturing its source chain and the
// typed value (for downcasting). Legal only because `Error` does not
// implement `std::error::Error` itself.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/sgl-anyhow-test")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(e.message(), "reading config");
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("inner"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.message(), "missing key");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().message(), "zero not allowed");
        assert_eq!(f(-1).unwrap_err().message(), "negative: -1");
        let e = anyhow!("code {}", 7);
        assert_eq!(e.message(), "code 7");
    }

    #[test]
    fn downcast_ref_survives_context_wrapping() {
        #[derive(Debug, PartialEq)]
        struct Marker(u32);
        impl std::fmt::Display for Marker {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "marker {}", self.0)
            }
        }
        impl std::error::Error for Marker {}

        let e = Error::new(Marker(7)).context("outer").context("outermost");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(7)));
        assert!(e.is::<Marker>());
        assert!(!e.is::<std::io::Error>());
        assert_eq!(format!("{e:#}"), "outermost: outer: marker 7");
        // Message-only errors carry no payload.
        assert!(Error::msg("plain").downcast_ref::<Marker>().is_none());
    }

    #[test]
    fn question_mark_preserves_payload() {
        fn inner() -> Result<()> {
            let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
            Err(io)?;
            Ok(())
        }
        let e = inner().unwrap_err().context("while probing");
        let io = e.downcast_ref::<std::io::Error>().expect("payload kept");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
    }

    #[test]
    fn ensure_without_message() {
        fn f(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(f(true).is_ok());
        assert!(f(false).unwrap_err().message().contains("condition failed"));
    }
}
