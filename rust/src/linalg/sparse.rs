//! Compressed-sparse-column design backend.
//!
//! The natural sparse layout for this solver family: every hot operation
//! (correlation sweeps `X_jᵀρ`, residual updates `ρ ± δX_j`, the
//! Theorem-1 screening tests) reads whole feature columns, and CSC makes
//! a column one contiguous `(row-indices, values)` pair. Per-epoch solver
//! cost then scales with the number of *stored* entries (`nnz`) instead
//! of `n·p` — on a ~1%-density bag-of-words-style design that is a ~100×
//! smaller sweep.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use super::dense::Matrix;
use super::design::Design;
use super::simd;

/// Sparse `n_rows × n_cols` matrix of `f64` in compressed-sparse-column
/// form. Within a column entries are stored in increasing row order
/// (constructors enforce the order they receive; the solver kernels never
/// rely on it, but deterministic order keeps backend comparisons exact).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    /// Column pointers, length `n_cols + 1`.
    indptr: Vec<usize>,
    /// Row index of each stored entry, length `nnz`.
    indices: Vec<usize>,
    /// Value of each stored entry, length `nnz`.
    values: Vec<f64>,
    /// Memoized `col_axpy_rows` window bounds (derived data; excluded from
    /// equality, cloned fresh).
    windows: RowWindowCache,
}

/// Sentinel: bounds not yet computed for this column.
const WINDOW_UNSET: u64 = u64::MAX;

/// Lazily-filled memo of the binary-search results `col_axpy_rows` needs.
///
/// The parallel residual sweep partitions rows into one fixed window per
/// worker and then calls `col_axpy_rows` with the *same* `(row0, row1)` for
/// every active column, every epoch — re-running two `partition_point`
/// searches per call on identical inputs. This cache keys on the window and
/// memoizes each column's `(lo, hi)` entry range the first time it is
/// asked, packed into one `AtomicU64` (`lo << 32 | hi`). Fills are raceless
/// by idempotence: concurrent workers compute identical values, so a
/// duplicate store is harmless.
///
/// It is pure derived data, so it compares equal to any other cache and a
/// `Clone` of the matrix starts empty. Bounded: at most [`MAX_WINDOWS`]
/// distinct windows are memoized (a fleet re-solving under many different
/// worker counts); requests past the cap just fall back to the binary
/// search. Columns of matrices with ≥ `u32::MAX` stored entries are never
/// cached (they would not fit the packing).
///
/// [`MAX_WINDOWS`]: RowWindowCache::MAX_WINDOWS
struct RowWindowCache {
    windows: RwLock<Vec<WindowBounds>>,
}

struct WindowBounds {
    row0: usize,
    row1: usize,
    /// Per-column packed `(lo << 32) | hi`, [`WINDOW_UNSET`] until filled.
    bounds: Arc<Vec<AtomicU64>>,
}

impl RowWindowCache {
    const MAX_WINDOWS: usize = 64;

    fn new() -> Self {
        RowWindowCache { windows: RwLock::new(Vec::new()) }
    }

    /// The bounds table for a window, creating it if there is room.
    fn table(&self, row0: usize, row1: usize, n_cols: usize) -> Option<Arc<Vec<AtomicU64>>> {
        {
            let read = self.windows.read().unwrap();
            if let Some(w) = read.iter().find(|w| w.row0 == row0 && w.row1 == row1) {
                return Some(Arc::clone(&w.bounds));
            }
            if read.len() >= Self::MAX_WINDOWS {
                return None;
            }
        }
        let mut write = self.windows.write().unwrap();
        if let Some(w) = write.iter().find(|w| w.row0 == row0 && w.row1 == row1) {
            return Some(Arc::clone(&w.bounds));
        }
        if write.len() >= Self::MAX_WINDOWS {
            return None;
        }
        let bounds: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_cols).map(|_| AtomicU64::new(WINDOW_UNSET)).collect());
        write.push(WindowBounds { row0, row1, bounds: Arc::clone(&bounds) });
        Some(bounds)
    }
}

impl Clone for RowWindowCache {
    fn clone(&self) -> Self {
        RowWindowCache::new() // derived data: rebuilt on demand
    }
}

impl Default for RowWindowCache {
    fn default() -> Self {
        RowWindowCache::new()
    }
}

impl PartialEq for RowWindowCache {
    fn eq(&self, _: &Self) -> bool {
        true // never part of matrix identity
    }
}

impl std::fmt::Debug for RowWindowCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("RowWindowCache")
    }
}

impl CscMatrix {
    /// Build from per-column `(row, value)` lists. Explicit zeros are
    /// dropped; rows must be strictly increasing within a column.
    pub fn from_columns(n_rows: usize, columns: &[Vec<(usize, f64)>]) -> Self {
        let n_cols = columns.len();
        let mut indptr = Vec::with_capacity(n_cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for col in columns {
            let mut prev: Option<usize> = None;
            for &(i, v) in col {
                assert!(i < n_rows, "row index {i} out of bounds (n_rows {n_rows})");
                if let Some(p) = prev {
                    assert!(i > p, "rows must be strictly increasing within a column");
                }
                prev = Some(i);
                if v != 0.0 {
                    indices.push(i);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CscMatrix { n_rows, n_cols, indptr, indices, values, windows: RowWindowCache::new() }
    }

    /// Build from raw CSC arrays (`indptr.len() == n_cols + 1`).
    pub fn from_raw(
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), n_cols + 1, "indptr length mismatch");
        assert_eq!(indices.len(), values.len(), "indices/values length mismatch");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr tail mismatch");
        for w in indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be non-decreasing");
        }
        for &i in &indices {
            assert!(i < n_rows, "row index {i} out of bounds (n_rows {n_rows})");
        }
        CscMatrix { n_rows, n_cols, indptr, indices, values, windows: RowWindowCache::new() }
    }

    /// Compress a dense matrix, dropping exact zeros.
    pub fn from_dense(m: &Matrix) -> Self {
        let n_rows = m.n_rows();
        let n_cols = m.n_cols();
        let mut indptr = Vec::with_capacity(n_cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for j in 0..n_cols {
            for (i, &v) in m.col(j).iter().enumerate() {
                if v != 0.0 {
                    indices.push(i);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CscMatrix { n_rows, n_cols, indptr, indices, values, windows: RowWindowCache::new() }
    }

    /// Expand back to a dense column-major matrix.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.n_rows, self.n_cols);
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            let col = m.col_mut(j);
            for (&i, &v) in rows.iter().zip(vals) {
                col[i] = v;
            }
        }
        m
    }

    /// Raw column pointers (`len == n_cols + 1`) — the triplet form the
    /// wire codec ([`crate::util::wire`]) ships across machines.
    #[inline]
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    /// Raw row index of every stored entry (`len == nnz`).
    #[inline]
    pub fn row_indices(&self) -> &[usize] {
        &self.indices
    }

    /// Raw value of every stored entry (`len == nnz`).
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// The stored entries of column `j` as `(row-indices, values)`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        debug_assert!(j < self.n_cols);
        let (a, b) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[a..b], &self.values[a..b])
    }

    /// Entry range of column `j` (given as its row slice) covering rows
    /// `row0..row1`, memoized through the window cache when possible.
    fn window_entry_range(&self, j: usize, row0: usize, row1: usize, rows: &[usize]) -> (usize, usize) {
        if self.values.len() < u32::MAX as usize {
            if let Some(table) = self.windows.table(row0, row1, self.n_cols) {
                let packed = table[j].load(Ordering::Relaxed);
                if packed != WINDOW_UNSET {
                    return ((packed >> 32) as usize, (packed & 0xffff_ffff) as usize);
                }
                let lo = rows.partition_point(|&i| i < row0);
                let hi = lo + rows[lo..].partition_point(|&i| i < row1);
                table[j].store(((lo as u64) << 32) | hi as u64, Ordering::Relaxed);
                return (lo, hi);
            }
        }
        let lo = rows.partition_point(|&i| i < row0);
        let hi = lo + rows[lo..].partition_point(|&i| i < row1);
        (lo, hi)
    }
}

/// `out[rows[k] - base] += alpha * vals[k]`, 4-way unrolled. Row indices are
/// strictly increasing within a column, so the targets never alias and the
/// unroll is bit-identical to the sequential scatter.
#[inline]
fn scatter_axpy(rows: &[usize], vals: &[f64], alpha: f64, base: usize, out: &mut [f64]) {
    let n = vals.len();
    let chunks = n / 4 * 4;
    let mut k = 0;
    while k < chunks {
        out[rows[k] - base] += alpha * vals[k];
        out[rows[k + 1] - base] += alpha * vals[k + 1];
        out[rows[k + 2] - base] += alpha * vals[k + 2];
        out[rows[k + 3] - base] += alpha * vals[k + 3];
        k += 4;
    }
    while k < n {
        out[rows[k] - base] += alpha * vals[k];
        k += 1;
    }
}

impl Design for CscMatrix {
    #[inline]
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    #[inline]
    fn n_cols(&self) -> usize {
        self.n_cols
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.n_rows);
        let (rows, vals) = self.col(j);
        // Policy-dispatched: the scalar branch is this backend's original
        // sequential gather, the SIMD branch runs 4 independent accumulator
        // chains (gather-free over the contiguous value slice).
        simd::sparse_dot(rows, vals, v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.n_rows);
        if alpha == 0.0 {
            return;
        }
        let (rows, vals) = self.col(j);
        scatter_axpy(rows, vals, alpha, 0, out);
    }

    fn col_axpy_rows(&self, j: usize, alpha: f64, row0: usize, row1: usize, out: &mut [f64]) {
        debug_assert!(row0 <= row1 && row1 <= self.n_rows);
        debug_assert_eq!(out.len(), row1 - row0);
        if alpha == 0.0 {
            return;
        }
        // Row indices are sorted within a column, so the window is an entry
        // range found by binary search — memoized per (window, column),
        // since sweeps replay identical windows every epoch.
        let (rows, vals) = self.col(j);
        let (lo, hi) = if row0 == 0 && row1 == self.n_rows {
            (0, rows.len()) // full column: no search needed
        } else {
            self.window_entry_range(j, row0, row1, rows)
        };
        scatter_axpy(&rows[lo..hi], &vals[lo..hi], alpha, row0, out);
    }

    #[inline]
    fn col_norm(&self, j: usize) -> f64 {
        let (_, vals) = self.col(j);
        if simd::use_simd() {
            simd::sq_norm_with(vals, true).sqrt()
        } else {
            // The pre-SIMD sequential fold, kept verbatim for bit identity.
            vals.iter().map(|v| v * v).sum::<f64>().sqrt()
        }
    }

    fn select_cols(&self, cols: &[usize]) -> Self {
        let mut indptr = Vec::with_capacity(cols.len() + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for &j in cols {
            let (rows, vals) = self.col(j);
            indices.extend_from_slice(rows);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        }
        CscMatrix {
            n_rows: self.n_rows,
            n_cols: cols.len(),
            indptr,
            indices,
            values,
            windows: RowWindowCache::new(),
        }
    }

    fn select_rows(&self, rows: &[usize]) -> Self {
        // Scatter each column into a dense scratch, then gather in the
        // requested row order: handles duplicated and unsorted `rows`
        // exactly like the dense backend, and keeps the emitted row
        // indices increasing within every column.
        for &i in rows {
            assert!(i < self.n_rows, "row index {i} out of bounds");
        }
        let mut scratch = vec![0.0; self.n_rows];
        let mut present = vec![false; self.n_rows];
        let mut indptr = Vec::with_capacity(self.n_cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for j in 0..self.n_cols {
            let (r, v) = self.col(j);
            for (&i, &x) in r.iter().zip(v) {
                scratch[i] = x;
                present[i] = true;
            }
            for (k, &i) in rows.iter().enumerate() {
                if present[i] {
                    indices.push(k);
                    values.push(scratch[i]);
                }
            }
            for &i in r {
                present[i] = false;
            }
            indptr.push(indices.len());
        }
        CscMatrix {
            n_rows: rows.len(),
            n_cols: self.n_cols,
            indptr,
            indices,
            values,
            windows: RowWindowCache::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random sparse matrix with its dense mirror.
    fn random_pair(n: usize, p: usize, density: f64, seed: u64) -> (CscMatrix, Matrix) {
        let mut rng = Pcg::seeded(seed);
        let mut cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(p);
        for _ in 0..p {
            let mut col = Vec::new();
            for i in 0..n {
                if rng.uniform() < density {
                    col.push((i, rng.normal()));
                }
            }
            cols.push(col);
        }
        let s = CscMatrix::from_columns(n, &cols);
        let d = s.to_dense();
        (s, d)
    }

    #[test]
    fn roundtrip_through_dense() {
        let (s, d) = random_pair(15, 20, 0.2, 1);
        assert_eq!(CscMatrix::from_dense(&d), s);
        assert_eq!(s.n_rows(), 15);
        assert_eq!(s.n_cols(), 20);
        assert!(s.density() < 0.5);
    }

    #[test]
    fn matvec_and_tmatvec_match_dense() {
        let (s, d) = random_pair(12, 18, 0.3, 2);
        let mut rng = Pcg::seeded(99);
        let v: Vec<f64> = (0..18).map(|_| rng.normal()).collect();
        let u: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let ys = s.matvec(&v);
        let yd = d.matvec(&v);
        for (a, b) in ys.iter().zip(&yd) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
        let zs = s.tmatvec(&u);
        let zd = d.tmatvec(&u);
        for (a, b) in zs.iter().zip(&zd) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn col_kernels_match_dense() {
        let (s, d) = random_pair(10, 8, 0.4, 3);
        let mut rng = Pcg::seeded(7);
        let v: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        for j in 0..8 {
            let sd = s.col_dot(j, &v);
            let dd = crate::linalg::ops::dot(d.col(j), &v);
            assert!((sd - dd).abs() < 1e-12, "col {j}");
            assert!((s.col_norm(j) - crate::linalg::ops::l2_norm(d.col(j))).abs() < 1e-12);
            let mut a = v.clone();
            let mut b = v.clone();
            s.col_axpy(j, 0.5, &mut a);
            crate::linalg::ops::axpy(0.5, d.col(j), &mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn col_axpy_rows_matches_full_axpy_on_every_window() {
        let (s, d) = random_pair(11, 6, 0.35, 8);
        for j in 0..6 {
            let mut full = vec![0.0; 11];
            s.col_axpy(j, -1.25, &mut full);
            for (row0, row1) in [(0, 11), (0, 4), (4, 9), (9, 11), (5, 5)] {
                // Sparse, dense override, and the trait default must all
                // agree with the windowed slice of the full axpy.
                let mut sp = vec![0.0; row1 - row0];
                s.col_axpy_rows(j, -1.25, row0, row1, &mut sp);
                let mut dn = vec![0.0; row1 - row0];
                d.col_axpy_rows(j, -1.25, row0, row1, &mut dn);
                let mut gen = vec![0.0; row1 - row0];
                generic_axpy_rows(&s, j, -1.25, row0, row1, &mut gen);
                for k in 0..(row1 - row0) {
                    assert_eq!(sp[k], full[row0 + k], "csc j={j} rows {row0}..{row1}");
                    assert_eq!(dn[k], full[row0 + k], "dense j={j} rows {row0}..{row1}");
                    assert_eq!(gen[k], full[row0 + k], "default j={j} rows {row0}..{row1}");
                }
            }
        }
    }

    /// Route through the trait's *default* `col_axpy_rows` (both backends
    /// override it, so the default needs an explicit harness).
    fn generic_axpy_rows<D: Design>(
        x: &D,
        j: usize,
        alpha: f64,
        row0: usize,
        row1: usize,
        out: &mut [f64],
    ) {
        struct Shim<D: Design>(D);
        impl<D: Design> Design for Shim<D> {
            fn n_rows(&self) -> usize {
                self.0.n_rows()
            }
            fn n_cols(&self) -> usize {
                self.0.n_cols()
            }
            fn nnz(&self) -> usize {
                self.0.nnz()
            }
            fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
                self.0.col_dot(j, v)
            }
            fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
                self.0.col_axpy(j, alpha, out)
            }
            fn col_norm(&self, j: usize) -> f64 {
                self.0.col_norm(j)
            }
            fn select_cols(&self, cols: &[usize]) -> Self {
                Shim(self.0.select_cols(cols))
            }
            fn select_rows(&self, rows: &[usize]) -> Self {
                Shim(self.0.select_rows(rows))
            }
        }
        impl<D: Design> Clone for Shim<D> {
            fn clone(&self) -> Self {
                Shim(self.0.clone())
            }
        }
        impl<D: Design> std::fmt::Debug for Shim<D> {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "Shim({:?})", self.0)
            }
        }
        Shim(x.clone()).col_axpy_rows(j, alpha, row0, row1, out)
    }

    #[test]
    fn window_cache_memoizes_and_stays_correct() {
        let (s, _) = random_pair(30, 5, 0.4, 11);
        // Repeat passes so later iterations hit the memoized bounds.
        for pass in 0..3 {
            for (row0, row1) in [(0, 30), (0, 10), (10, 20), (20, 30), (7, 23), (9, 9)] {
                for j in 0..5 {
                    let mut windowed = vec![0.0; row1 - row0];
                    s.col_axpy_rows(j, 1.5, row0, row1, &mut windowed);
                    let mut full = vec![0.0; 30];
                    s.col_axpy(j, 1.5, &mut full);
                    assert_eq!(
                        &windowed[..],
                        &full[row0..row1],
                        "pass {pass} j={j} rows {row0}..{row1}"
                    );
                }
            }
        }
        // A clone starts with a fresh cache and identical results.
        let c = s.clone();
        let (mut a, mut b) = (vec![0.0; 13], vec![0.0; 13]);
        s.col_axpy_rows(2, -0.5, 7, 20, &mut a);
        c.col_axpy_rows(2, -0.5, 7, 20, &mut b);
        assert_eq!(a, b);
        assert_eq!(s, c);
    }

    #[test]
    fn window_cache_cap_falls_back_to_search() {
        let (s, _) = random_pair(200, 3, 0.5, 12);
        // Burn through more distinct windows than the cache holds; the ones
        // past the cap bypass the memo and must stay exact.
        for w in 0..(RowWindowCache::MAX_WINDOWS + 8) {
            let (row0, row1) = (w, w + 100);
            for j in 0..3 {
                let mut windowed = vec![0.0; 100];
                s.col_axpy_rows(j, 2.0, row0, row1, &mut windowed);
                let mut full = vec![0.0; 200];
                s.col_axpy(j, 2.0, &mut full);
                assert_eq!(&windowed[..], &full[row0..row1], "window {row0}..{row1} col {j}");
            }
        }
        assert_eq!(s.windows.windows.read().unwrap().len(), RowWindowCache::MAX_WINDOWS);
    }

    #[test]
    fn select_cols_packs_in_order() {
        let (s, d) = random_pair(9, 10, 0.3, 4);
        let pick = [7usize, 2, 9];
        let ss = s.select_cols(&pick);
        assert_eq!(ss.n_cols(), 3);
        for (k, &j) in pick.iter().enumerate() {
            let (ri, vi) = ss.col(k);
            let (rj, vj) = s.col(j);
            assert_eq!(ri, rj);
            assert_eq!(vi, vj);
            let dense_col = d.col(j);
            let mut rebuilt = vec![0.0; 9];
            for (&i, &v) in ri.iter().zip(vi) {
                rebuilt[i] = v;
            }
            assert_eq!(&rebuilt[..], dense_col);
        }
    }

    #[test]
    fn select_rows_matches_dense() {
        let (s, d) = random_pair(11, 6, 0.35, 5);
        let rows = [0usize, 3, 4, 10];
        let ss = s.select_rows(&rows);
        let dd = d.select_rows(&rows);
        assert_eq!(ss.to_dense(), dd);
        assert_eq!(ss.n_rows(), 4);
    }

    #[test]
    fn select_rows_handles_duplicates_and_unsorted_order() {
        // Bootstrap-style row lists must behave exactly like the dense
        // backend: duplicates duplicate, order is the requested order.
        let (s, d) = random_pair(9, 5, 0.4, 8);
        let rows = [5usize, 2, 5, 0];
        let ss = s.select_rows(&rows);
        let dd = d.select_rows(&rows);
        assert_eq!(ss.to_dense(), dd);
        // Emitted row indices stay increasing within every column.
        for j in 0..ss.n_cols() {
            let (r, _) = ss.col(j);
            for w in r.windows(2) {
                assert!(w[0] < w[1], "col {j}: rows not increasing: {r:?}");
            }
        }
    }

    #[test]
    fn block_spectral_norm_close_to_dense() {
        let (s, d) = random_pair(20, 12, 0.3, 6);
        for (a, b) in [(0usize, 4usize), (4, 8), (0, 12), (5, 6)] {
            let ns = s.block_spectral_norm(a, b);
            let nd = crate::linalg::spectral::spectral_norm(&d, a, b, 1e-12, 1000);
            assert!((ns - nd).abs() < 1e-8 * nd.max(1.0), "block {a}..{b}: {ns} vs {nd}");
        }
    }

    #[test]
    fn empty_columns_are_fine() {
        let s = CscMatrix::from_columns(4, &[vec![], vec![(1, 2.0)], vec![]]);
        assert_eq!(s.nnz(), 1);
        assert_eq!(s.col_norm(0), 0.0);
        assert_eq!(s.col_norm(1), 2.0);
        assert_eq!(s.matvec(&[1.0, 1.0, 1.0]), vec![0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_row_rejected() {
        CscMatrix::from_columns(3, &[vec![(3, 1.0)]]);
    }
}
