//! Spectral norms via power iteration.
//!
//! The ISTA-BC solver (paper §6) needs the block Lipschitz constants
//! `L_g = ‖X_g‖₂²` (squared spectral norm of each group sub-matrix). We
//! compute them by power iteration on `X_gᵀX_g`, which converges fast for
//! the small group widths used here (`n_g` ≈ 7–10).

use super::dense::Matrix;
use super::ops::{l2_norm, scale};
use crate::util::rng::Pcg;

/// Largest singular value of the column block `X[:, j0..j1]`.
///
/// Power iteration on `v ← X_gᵀ(X_g v)` with deterministic seeding;
/// `tol` is the relative change stopping criterion on the Rayleigh quotient.
pub fn spectral_norm(x: &Matrix, j0: usize, j1: usize, tol: f64, max_iter: usize) -> f64 {
    let d = j1 - j0;
    assert!(d > 0, "empty block");
    let n = x.n_rows();
    if d == 1 {
        return l2_norm(x.col(j0));
    }
    let mut rng = Pcg::new(0x5EC7_0000 + j0 as u64, j1 as u64);
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let nv = l2_norm(&v);
    if nv == 0.0 {
        return 0.0;
    }
    scale(1.0 / nv, &mut v);
    let mut u = vec![0.0; n];
    let mut w = vec![0.0; d];
    let mut prev = 0.0;
    for _ in 0..max_iter {
        // u = X_g v
        u.fill(0.0);
        for (k, j) in (j0..j1).enumerate() {
            let col = x.col(j);
            let vk = v[k];
            if vk != 0.0 {
                for i in 0..n {
                    u[i] += col[i] * vk;
                }
            }
        }
        // w = X_gᵀ u
        x.tmatvec_block(j0, j1, &u, &mut w);
        let lam = l2_norm(&w); // = ‖X_gᵀX_g v‖ ≈ σ²
        if lam == 0.0 {
            return 0.0;
        }
        for (vk, wk) in v.iter_mut().zip(&w) {
            *vk = wk / lam;
        }
        if (lam - prev).abs() <= tol * lam.max(1e-300) {
            return lam.sqrt();
        }
        prev = lam;
    }
    prev.max(0.0).sqrt()
}

/// Power iteration for the top eigenvalue of a symmetric operator given as
/// a closure `apply(v) -> Av`. Used in tests and for whole-matrix norms.
pub fn power_iteration(
    dim: usize,
    mut apply: impl FnMut(&[f64]) -> Vec<f64>,
    tol: f64,
    max_iter: usize,
    seed: u64,
) -> f64 {
    let mut rng = Pcg::seeded(seed);
    let mut v: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
    let nv = l2_norm(&v);
    if nv == 0.0 || dim == 0 {
        return 0.0;
    }
    scale(1.0 / nv, &mut v);
    let mut prev = 0.0;
    for _ in 0..max_iter {
        let w = apply(&v);
        let lam = l2_norm(&w);
        if lam == 0.0 {
            return 0.0;
        }
        v = w;
        scale(1.0 / lam, &mut v);
        if (lam - prev).abs() <= tol * lam.max(1e-300) {
            return lam;
        }
        prev = lam;
    }
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_column_is_col_norm() {
        let x = Matrix::from_row_major(&[3.0, 0.0, 4.0, 0.0], 2, 2);
        let s = spectral_norm(&x, 0, 1, 1e-12, 100);
        assert!((s - 5.0).abs() < 1e-10);
    }

    #[test]
    fn diagonal_matrix_spectral_norm() {
        // X = diag(1, 2, 3): spectral norm of the full block is 3.
        let mut x = Matrix::zeros(3, 3);
        for i in 0..3 {
            x.set(i, i, (i + 1) as f64);
        }
        let s = spectral_norm(&x, 0, 3, 1e-14, 500);
        assert!((s - 3.0).abs() < 1e-8, "s={s}");
    }

    #[test]
    fn orthogonal_columns() {
        // Orthogonal columns with norms 2 and 5: sigma_max = 5.
        let x = Matrix::from_row_major(&[2.0, 0.0, 0.0, 5.0], 2, 2);
        let s = spectral_norm(&x, 0, 2, 1e-14, 500);
        assert!((s - 5.0).abs() < 1e-8);
    }

    #[test]
    fn rank_one_block() {
        // Both columns equal: sigma = sqrt(2) * ||col||.
        let x = Matrix::from_row_major(&[1.0, 1.0, 1.0, 1.0], 2, 2);
        let s = spectral_norm(&x, 0, 2, 1e-14, 500);
        assert!((s - 2.0).abs() < 1e-8, "s={s}");
    }

    #[test]
    fn zero_block() {
        let x = Matrix::zeros(4, 3);
        assert_eq!(spectral_norm(&x, 0, 3, 1e-10, 50), 0.0);
    }

    #[test]
    fn generic_power_iteration_matches_block() {
        let x = Matrix::from_row_major(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let via_block = spectral_norm(&x, 0, 3, 1e-14, 1000);
        let via_generic = power_iteration(
            3,
            |v| {
                let u = x.matvec(v);
                x.tmatvec(&u)
            },
            1e-14,
            1000,
            7,
        )
        .sqrt();
        assert!((via_block - via_generic).abs() < 1e-6);
    }
}
