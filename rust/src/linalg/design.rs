//! The `Design` abstraction: what a solver needs from the matrix `X`.
//!
//! The screening rules pay off most when the design is huge and mostly
//! irrelevant — exactly the regime of sparse designs (bag-of-words,
//! one-hot genomics). To serve both worlds the solver stack is generic
//! over this trait, with two backends:
//!
//! - [`crate::linalg::Matrix`] — the column-major dense matrix the crate
//!   started with (per-epoch cost `O(n·p_active)`);
//! - [`crate::linalg::CscMatrix`] — compressed sparse columns whose sweeps
//!   only touch stored entries (per-epoch cost `O(nnz_active)`).
//!
//! The trait is deliberately *column-oriented*: coordinate descent, the
//! correlation products `Xᵀρ`, the residual updates, and the Theorem-1
//! tests all consume whole feature columns, never rows. Everything a
//! backend must provide reduces to `col_dot` / `col_axpy` plus column
//! selection for the active-set compaction in
//! [`crate::solver::active_set`].

use std::sync::atomic::{AtomicUsize, Ordering};

use super::ops::{l2_norm, scale};
use crate::util::rng::Pcg;

/// How many times the allocating trait-default `col_axpy_rows` ran in this
/// process. Both shipped backends override it with a windowed kernel, so on
/// dense/CSC solve paths this must stay flat — `tests/kernel_equivalence.rs`
/// asserts exactly that. Exposed (hidden) so tests can observe it; only
/// deliberately minimal backends (like the test shim in `linalg::sparse`)
/// should ever bump it.
#[doc(hidden)]
pub static GENERIC_AXPY_ROWS_CALLS: AtomicUsize = AtomicUsize::new(0);

/// Snapshot of [`GENERIC_AXPY_ROWS_CALLS`].
#[doc(hidden)]
pub fn generic_axpy_rows_calls() -> usize {
    GENERIC_AXPY_ROWS_CALLS.load(Ordering::Relaxed)
}

/// A design matrix backend. All default methods are expressed in terms of
/// `col_dot` / `col_axpy`, so a minimal backend only implements the
/// column kernels plus the two structural selections; backends override
/// the defaults where a faster specialized path exists.
pub trait Design: Clone + Send + Sync + std::fmt::Debug {
    fn n_rows(&self) -> usize;

    fn n_cols(&self) -> usize;

    /// Number of explicitly stored entries (dense: `n_rows·n_cols`).
    fn nnz(&self) -> usize;

    /// `X_jᵀ v` (`v.len() == n_rows`).
    fn col_dot(&self, j: usize, v: &[f64]) -> f64;

    /// `out += alpha · X_j` (`out.len() == n_rows`).
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]);

    /// `out += alpha · X_j[row0..row1]` — the row-windowed axpy behind the
    /// row-partitioned parallel residual kernels
    /// ([`crate::solver::sweep`]): each worker owns a disjoint row range
    /// of `ρ` and accumulates every column's contribution to it, which
    /// keeps the per-row addition order identical to the serial sweep
    /// (bit-identical results). `out.len() == row1 - row0`.
    ///
    /// The default routes through a full-height scratch column — correct
    /// for any backend but allocating; both shipped backends override it
    /// with a windowed kernel.
    fn col_axpy_rows(&self, j: usize, alpha: f64, row0: usize, row1: usize, out: &mut [f64]) {
        debug_assert!(row0 <= row1 && row1 <= self.n_rows());
        debug_assert_eq!(out.len(), row1 - row0);
        GENERIC_AXPY_ROWS_CALLS.fetch_add(1, Ordering::Relaxed);
        if alpha == 0.0 {
            return;
        }
        let mut full = vec![0.0; self.n_rows()];
        self.col_axpy(j, alpha, &mut full);
        for (o, v) in out.iter_mut().zip(&full[row0..row1]) {
            *o += v;
        }
    }

    /// Euclidean norm of column `j`.
    fn col_norm(&self, j: usize) -> f64;

    /// A new design holding exactly the columns `cols` (in that order) —
    /// the backend-generic form of active-set compaction: a packed dense
    /// scratch for the dense backend, a pruned CSC for the sparse one.
    fn select_cols(&self, cols: &[usize]) -> Self;

    /// A new design holding exactly the rows `rows` (train/test splits).
    fn select_rows(&self, rows: &[usize]) -> Self;

    /// Fraction of entries stored.
    fn density(&self) -> f64 {
        let total = self.n_rows() * self.n_cols();
        if total == 0 {
            0.0
        } else {
            self.nnz() as f64 / total as f64
        }
    }

    /// Euclidean norm of every column.
    fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols()).map(|j| self.col_norm(j)).collect()
    }

    /// `y = X v`, into a caller-provided buffer. Skips zero coefficients
    /// entirely (sparse `β`), like the historical dense kernel.
    fn matvec_into(&self, v: &[f64], y: &mut [f64]) {
        assert_eq!(v.len(), self.n_cols());
        assert_eq!(y.len(), self.n_rows());
        y.fill(0.0);
        for (j, &vj) in v.iter().enumerate() {
            if vj != 0.0 {
                self.col_axpy(j, vj, y);
            }
        }
    }

    /// `z = Xᵀ u`, into a caller-provided buffer.
    fn tmatvec_into(&self, u: &[f64], z: &mut [f64]) {
        assert_eq!(u.len(), self.n_rows());
        assert_eq!(z.len(), self.n_cols());
        for (j, zj) in z.iter_mut().enumerate() {
            *zj = self.col_dot(j, u);
        }
    }

    /// `out = X[:, j0..j1]ᵀ u` — the block correlation behind the strong
    /// rules' KKT checks and the group-level tests. `out.len() == j1 - j0`.
    fn tmatvec_block(&self, j0: usize, j1: usize, u: &[f64], out: &mut [f64]) {
        debug_assert!(j0 <= j1 && j1 <= self.n_cols());
        debug_assert_eq!(out.len(), j1 - j0);
        for (k, j) in (j0..j1).enumerate() {
            out[k] = self.col_dot(j, u);
        }
    }

    /// `X v` (allocating convenience).
    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows()];
        self.matvec_into(v, &mut y);
        y
    }

    /// `Xᵀ u` (allocating convenience).
    fn tmatvec(&self, u: &[f64]) -> Vec<f64> {
        let mut z = vec![0.0; self.n_cols()];
        self.tmatvec_into(u, &mut z);
        z
    }

    /// Largest singular value of the column block `X[:, j0..j1]` — the
    /// per-group spectral bound `‖X_g‖₂` behind the Lipschitz constants
    /// `L_g` and the group-level screening test.
    fn block_spectral_norm(&self, j0: usize, j1: usize) -> f64 {
        block_spectral_norm_generic(self, j0, j1, 1e-12, 1000)
    }
}

/// Power iteration for `‖X[:, j0..j1]‖₂` over any [`Design`], mirroring
/// the dense `linalg::spectral::spectral_norm` step for step (same
/// deterministic seeding, same update, same stopping rule) so dense and
/// sparse instantiations of the same data agree to rounding error.
pub fn block_spectral_norm_generic<D: Design + ?Sized>(
    x: &D,
    j0: usize,
    j1: usize,
    tol: f64,
    max_iter: usize,
) -> f64 {
    let d = j1 - j0;
    assert!(d > 0, "empty block");
    let n = x.n_rows();
    if d == 1 {
        return x.col_norm(j0);
    }
    let mut rng = Pcg::new(0x5EC7_0000 + j0 as u64, j1 as u64);
    let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let nv = l2_norm(&v);
    if nv == 0.0 {
        return 0.0;
    }
    scale(1.0 / nv, &mut v);
    let mut u = vec![0.0; n];
    let mut prev = 0.0;
    for _ in 0..max_iter {
        // u = X_g v
        u.fill(0.0);
        for (k, j) in (j0..j1).enumerate() {
            if v[k] != 0.0 {
                x.col_axpy(j, v[k], &mut u);
            }
        }
        // w = X_gᵀ u, written back into v after normalization.
        let mut lam_sq = 0.0;
        let mut w = vec![0.0; d];
        for (k, j) in (j0..j1).enumerate() {
            let wk = x.col_dot(j, &u);
            w[k] = wk;
            lam_sq += wk * wk;
        }
        let lam = lam_sq.sqrt(); // = ‖X_gᵀX_g v‖ ≈ σ²
        if lam == 0.0 {
            return 0.0;
        }
        for (vk, wk) in v.iter_mut().zip(&w) {
            *vk = wk / lam;
        }
        if (lam - prev).abs() <= tol * lam.max(1e-300) {
            return lam.sqrt();
        }
        prev = lam;
    }
    prev.max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn generic_spectral_matches_dense_kernel() {
        let x = Matrix::from_row_major(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let dense = crate::linalg::spectral::spectral_norm(&x, 0, 3, 1e-14, 1000);
        let generic = block_spectral_norm_generic(&x, 0, 3, 1e-14, 1000);
        assert!((dense - generic).abs() < 1e-10, "{dense} vs {generic}");
    }

    #[test]
    fn default_matvec_agrees_with_dense() {
        let x = Matrix::from_row_major(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let v = [1.0, 0.0, -2.0];
        // Route through the trait defaults explicitly.
        let mut y = vec![0.0; 2];
        Design::matvec_into(&x, &v, &mut y);
        assert_eq!(y, x.matvec(&v));
        let u = [0.5, -1.5];
        let mut z = vec![0.0; 3];
        Design::tmatvec_into(&x, &u, &mut z);
        assert_eq!(z, x.tmatvec(&u));
    }

    #[test]
    fn density_of_dense_is_one() {
        let x = Matrix::zeros(4, 3);
        assert_eq!(Design::nnz(&x), 12);
        assert!((x.density() - 1.0).abs() < 1e-15);
    }
}
