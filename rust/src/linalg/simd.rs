//! Portable explicit-SIMD kernels with a bit-reproducible scalar fallback.
//!
//! Stable Rust has no `std::simd`, so the "SIMD" here is lane structs
//! (`F64x8`) over fixed-size arrays: the accumulator loops are written so
//! the autovectorizer reliably emits packed `mulpd/addpd` (the same trick as
//! `ops::dot`'s 4-way unroll, widened to 8 lanes with an explicit horizontal
//! reduce). No nightly features, no intrinsics, no `f64::mul_add` (baseline
//! x86-64 has no FMA, so `mul_add` would fall back to a slow libm call).
//!
//! # Kernel policy contract
//!
//! Which implementation runs is a process-global [`KernelPolicy`]:
//!
//! - **`scalar`** — every reduction takes the exact pre-SIMD code path
//!   (`ops::dot`'s unroll, the sequential sparse gather, the sequential
//!   iterator folds in `duality`/`norms`). Results are **bit-identical** to
//!   the solver before this layer existed, and all bit-identity tests
//!   (sharding, wire, parallel sweeps) hold under it.
//! - **`simd`** — reductions reassociate into 8 accumulator lanes reduced
//!   pairwise, and dense reductions are additionally computed blockwise in
//!   [`PANEL_ROWS`]-sized panels (so the cache-blocked `tmatvec` in
//!   `linalg::dense` is bit-identical to a per-column [`dot`] under the same
//!   policy). Versus `scalar` the guarantee is **≤ 1e-12 relative
//!   agreement** per kernel (see `tests/kernel_equivalence.rs`), not bit
//!   identity.
//! - **`auto`** — defers to the `SGL_KERNELS` env var (`scalar` / `simd`),
//!   else picks `simd`.
//!
//! Elementwise kernels ([`axpy`], [`axpy_rows`], [`sub_into`]) do not
//! reassociate anything, so they are bit-identical under every policy and
//! have a single implementation.
//!
//! The policy is per *process*, mirroring `SGL_THREADS`: a distributed
//! fleet may mix workers running different policies, so wire/fleet results
//! are computed under whatever policy each worker runs — cross-policy
//! comparisons assert objective agreement, not bit-identity.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::ops;

/// Which kernel implementations the process uses.
///
/// See the [module docs](self) for the full contract. In short: `Scalar` is
/// bit-identical to the pre-SIMD solver, `Simd` agrees to ≤ 1e-12 relative
/// per kernel, `Auto` resolves via `SGL_KERNELS` (default `Simd`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelPolicy {
    /// Defer to `SGL_KERNELS` (`scalar`/`simd`); default to SIMD.
    #[default]
    Auto,
    /// Bit-reproducible scalar kernels (the pre-SIMD code paths, verbatim).
    Scalar,
    /// Lane-unrolled kernels; ≤ 1e-12 relative agreement with `Scalar`.
    Simd,
}

impl KernelPolicy {
    pub fn name(self) -> &'static str {
        match self {
            KernelPolicy::Auto => "auto",
            KernelPolicy::Scalar => "scalar",
            KernelPolicy::Simd => "simd",
        }
    }

    pub fn from_name(name: &str) -> Option<KernelPolicy> {
        match name {
            "auto" => Some(KernelPolicy::Auto),
            "scalar" => Some(KernelPolicy::Scalar),
            "simd" => Some(KernelPolicy::Simd),
            _ => None,
        }
    }

    pub fn all() -> &'static [KernelPolicy] {
        &[KernelPolicy::Auto, KernelPolicy::Scalar, KernelPolicy::Simd]
    }
}

/// Process-global policy (0 = auto, 1 = scalar, 2 = simd).
static POLICY: AtomicU8 = AtomicU8::new(0);

/// Set the process-global kernel policy (CLI `--kernels`, `[solver] kernels`).
pub fn set_policy(p: KernelPolicy) {
    let v = match p {
        KernelPolicy::Auto => 0,
        KernelPolicy::Scalar => 1,
        KernelPolicy::Simd => 2,
    };
    POLICY.store(v, Ordering::Relaxed);
}

/// The policy as set (possibly `Auto`; see [`effective`] for the resolution).
pub fn policy() -> KernelPolicy {
    match POLICY.load(Ordering::Relaxed) {
        1 => KernelPolicy::Scalar,
        2 => KernelPolicy::Simd,
        _ => KernelPolicy::Auto,
    }
}

/// Parse an `SGL_KERNELS` value; malformed values are ignored (None).
fn parse_env(raw: &str) -> Option<KernelPolicy> {
    match KernelPolicy::from_name(raw.trim()) {
        Some(KernelPolicy::Auto) | None => None,
        p => p,
    }
}

fn env_policy() -> Option<KernelPolicy> {
    static ENV: OnceLock<Option<KernelPolicy>> = OnceLock::new();
    *ENV.get_or_init(|| std::env::var("SGL_KERNELS").ok().and_then(|v| parse_env(&v)))
}

/// The policy actually executing: `Auto` resolved via `SGL_KERNELS`, else
/// SIMD. Never returns `Auto`.
pub fn effective() -> KernelPolicy {
    match policy() {
        KernelPolicy::Auto => env_policy().unwrap_or(KernelPolicy::Simd),
        p => p,
    }
}

/// Whether the lane-unrolled kernels are active.
#[inline]
pub fn use_simd() -> bool {
    effective() == KernelPolicy::Simd
}

/// Accumulator lane count of the widest kernel. Portable lane structs always
/// carry 8 lanes; how many map to hardware registers is the compiler's call.
pub const LANES: usize = 8;

/// Lane width exposed for benches/tests gating on "≥ 2 lanes available".
#[inline]
pub fn lanes() -> usize {
    LANES
}

/// Row-panel size for cache-blocked dense reductions (2048 f64 = 16 KiB, an
/// L1-resident panel). SIMD [`dot`] is *defined* blockwise at this size so
/// the blocked `tmatvec` in `linalg::dense` and a straight per-column `dot`
/// produce bit-identical sums.
pub const PANEL_ROWS: usize = 2048;

/// 8-lane f64 accumulator.
#[derive(Clone, Copy)]
struct F64x8([f64; 8]);

impl F64x8 {
    const ZERO: F64x8 = F64x8([0.0; 8]);

    #[inline(always)]
    fn load(chunk: &[f64]) -> F64x8 {
        let mut v = [0.0; 8];
        v.copy_from_slice(chunk);
        F64x8(v)
    }

    /// `self += a * b`, lanewise.
    #[inline(always)]
    fn mul_acc(&mut self, a: F64x8, b: F64x8) {
        for l in 0..8 {
            self.0[l] += a.0[l] * b.0[l];
        }
    }

    /// `self += a * a`, lanewise.
    #[inline(always)]
    fn sq_acc(&mut self, a: F64x8) {
        for l in 0..8 {
            self.0[l] += a.0[l] * a.0[l];
        }
    }

    /// `self = max(self, |a|)`, lanewise.
    #[inline(always)]
    fn abs_max(&mut self, a: F64x8) {
        for l in 0..8 {
            self.0[l] = self.0[l].max(a.0[l].abs());
        }
    }

    /// Pairwise horizontal sum: `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`.
    #[inline(always)]
    fn hsum(self) -> f64 {
        let v = self.0;
        ((v[0] + v[1]) + (v[2] + v[3])) + ((v[4] + v[5]) + (v[6] + v[7]))
    }

    #[inline(always)]
    fn hmax(self) -> f64 {
        self.0.iter().fold(0.0f64, |m, &x| m.max(x))
    }
}

/// SIMD dot over one panel (callers split at [`PANEL_ROWS`]).
#[inline]
fn dot_panel(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = F64x8::ZERO;
    let mut ia = a.chunks_exact(8);
    let mut ib = b.chunks_exact(8);
    for (ca, cb) in (&mut ia).zip(&mut ib) {
        acc.mul_acc(F64x8::load(ca), F64x8::load(cb));
    }
    let mut s = acc.hsum();
    for (x, y) in ia.remainder().iter().zip(ib.remainder()) {
        s += x * y;
    }
    s
}

#[inline]
fn sq_norm_panel(x: &[f64]) -> f64 {
    let mut acc = F64x8::ZERO;
    let mut it = x.chunks_exact(8);
    for c in &mut it {
        acc.sq_acc(F64x8::load(c));
    }
    let mut s = acc.hsum();
    for v in it.remainder() {
        s += v * v;
    }
    s
}

/// Dot product under an explicit lane choice (`simd = false` is
/// `ops::dot`, bit-for-bit). The SIMD branch sums [`PANEL_ROWS`]-block
/// partials left to right; see [`PANEL_ROWS`] for why.
#[inline]
pub fn dot_with(a: &[f64], b: &[f64], simd: bool) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if !simd {
        return ops::dot(a, b);
    }
    if a.len() <= PANEL_ROWS {
        return dot_panel(a, b);
    }
    // First panel by assignment, not `0.0 + …`, so a blocked caller that
    // assigns panel 0 then `+=` the rest reproduces this bit-for-bit (even
    // for signed-zero partials).
    let mut s = dot_panel(&a[..PANEL_ROWS], &b[..PANEL_ROWS]);
    let mut i = PANEL_ROWS;
    while i < a.len() {
        let hi = (i + PANEL_ROWS).min(a.len());
        s += dot_panel(&a[i..hi], &b[i..hi]);
        i = hi;
    }
    s
}

/// Policy-dispatched dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_with(a, b, use_simd())
}

/// Squared Euclidean norm under an explicit lane choice (`simd = false` is
/// `ops::l2_norm_sq`, bit-for-bit).
#[inline]
pub fn sq_norm_with(x: &[f64], simd: bool) -> f64 {
    if !simd {
        return ops::l2_norm_sq(x);
    }
    if x.len() <= PANEL_ROWS {
        return sq_norm_panel(x);
    }
    let mut s = sq_norm_panel(&x[..PANEL_ROWS]);
    let mut i = PANEL_ROWS;
    while i < x.len() {
        let hi = (i + PANEL_ROWS).min(x.len());
        s += sq_norm_panel(&x[i..hi]);
        i = hi;
    }
    s
}

/// Policy-dispatched squared Euclidean norm.
#[inline]
pub fn sq_norm(x: &[f64]) -> f64 {
    sq_norm_with(x, use_simd())
}

/// Policy-dispatched Euclidean norm.
#[inline]
pub fn l2_norm(x: &[f64]) -> f64 {
    sq_norm(x).sqrt()
}

/// Max-abs (`ℓ∞`) reduction under an explicit lane choice. `max`/`abs` are
/// exact and order-independent for non-NaN input, so both branches agree
/// bit-for-bit — the SIMD branch just trades the serial dependency chain for
/// 8 independent lanes.
#[inline]
pub fn max_abs_with(x: &[f64], simd: bool) -> f64 {
    if !simd {
        return ops::inf_norm(x);
    }
    let mut acc = F64x8::ZERO;
    let mut it = x.chunks_exact(8);
    for c in &mut it {
        acc.abs_max(F64x8::load(c));
    }
    let mut m = acc.hmax();
    for v in it.remainder() {
        m = m.max(v.abs());
    }
    m
}

/// Policy-dispatched max-abs reduction.
#[inline]
pub fn max_abs(x: &[f64]) -> f64 {
    max_abs_with(x, use_simd())
}

/// Sparse gather-dot `Σ x[rows[i]] * vals[i]` under an explicit lane choice.
/// The scalar branch is the CSC backend's original sequential gather; the
/// SIMD branch runs four independent accumulator chains (the gather itself
/// cannot vectorize on baseline x86-64, but the chains hide load latency).
#[inline]
pub fn sparse_dot_with(rows: &[usize], vals: &[f64], x: &[f64], simd: bool) -> f64 {
    debug_assert_eq!(rows.len(), vals.len());
    if !simd {
        let mut s = 0.0;
        for (&i, &v) in rows.iter().zip(vals) {
            s += x[i] * v;
        }
        return s;
    }
    let n = vals.len();
    let chunks = n / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        s0 += x[rows[i]] * vals[i];
        s1 += x[rows[i + 1]] * vals[i + 1];
        s2 += x[rows[i + 2]] * vals[i + 2];
        s3 += x[rows[i + 3]] * vals[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += x[rows[i]] * vals[i];
        i += 1;
    }
    s
}

/// Policy-dispatched sparse gather-dot.
#[inline]
pub fn sparse_dot(rows: &[usize], vals: &[f64], x: &[f64]) -> f64 {
    sparse_dot_with(rows, vals, x, use_simd())
}

/// Σ (t_i − y_i/λ)² — the dual-point distance reduction from
/// `solver::duality`, fused (no scratch residual vector). Scalar branch is
/// the original sequential iterator fold, bit-for-bit.
#[inline]
pub fn dist_sq_scaled_with(y: &[f64], theta: &[f64], lambda: f64, simd: bool) -> f64 {
    debug_assert_eq!(y.len(), theta.len());
    if !simd {
        return theta
            .iter()
            .zip(y)
            .map(|(ti, yi)| {
                let d = ti - yi / lambda;
                d * d
            })
            .sum();
    }
    let n = y.len();
    let chunks = n / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        let d0 = theta[i] - y[i] / lambda;
        let d1 = theta[i + 1] - y[i + 1] / lambda;
        let d2 = theta[i + 2] - y[i + 2] / lambda;
        let d3 = theta[i + 3] - y[i + 3] / lambda;
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        let d = theta[i] - y[i] / lambda;
        s += d * d;
        i += 1;
    }
    s
}

/// Policy-dispatched fused dual-distance reduction.
#[inline]
pub fn dist_sq_scaled(y: &[f64], theta: &[f64], lambda: f64) -> f64 {
    dist_sq_scaled_with(y, theta, lambda, use_simd())
}

// ---------------------------------------------------------------------------
// Elementwise kernels: no reassociation, bit-identical under every policy.
// ---------------------------------------------------------------------------

/// `y += alpha * x`, unrolled. Elementwise, so bit-identical to `ops::axpy`
/// under every policy; kept as one implementation.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    let mut iy = y.chunks_exact_mut(8);
    let mut ix = x.chunks_exact(8);
    for (cy, cx) in (&mut iy).zip(&mut ix) {
        for l in 0..8 {
            cy[l] += alpha * cx[l];
        }
    }
    for (yi, xi) in iy.into_remainder().iter_mut().zip(ix.remainder()) {
        *yi += alpha * xi;
    }
}

/// `out += alpha * x[row0..row1]` — the row-window axpy every backend's
/// `col_axpy_rows` bottoms out in. Elementwise; bit-identical everywhere.
#[inline]
pub fn axpy_rows(alpha: f64, x: &[f64], row0: usize, row1: usize, out: &mut [f64]) {
    axpy(alpha, &x[row0..row1], out);
}

/// `out[i] = a[i] - b[i]` — fused residual update (`r = y − Xβ` given the
/// prediction). Elementwise; bit-identical everywhere.
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1e-300)
    }

    fn vec_a(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i * 2654435761 % 1000) as f64 - 500.0) / 331.0).collect()
    }

    fn vec_b(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i * 40503 % 997) as f64 - 498.0) / 173.0).collect()
    }

    #[test]
    fn policy_names_round_trip() {
        for &p in KernelPolicy::all() {
            assert_eq!(KernelPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(KernelPolicy::from_name("avx512"), None);
        assert_eq!(KernelPolicy::default(), KernelPolicy::Auto);
    }

    #[test]
    fn env_parse_ignores_malformed() {
        assert_eq!(parse_env(" simd "), Some(KernelPolicy::Simd));
        assert_eq!(parse_env("scalar"), Some(KernelPolicy::Scalar));
        assert_eq!(parse_env("auto"), None);
        assert_eq!(parse_env("fast"), None);
        assert_eq!(parse_env(""), None);
    }

    #[test]
    fn scalar_branch_is_ops_dot_bitwise() {
        for n in [0, 1, 3, 7, 8, 9, 63, 100] {
            let a = vec_a(n);
            let b = vec_b(n);
            assert_eq!(dot_with(&a, &b, false).to_bits(), ops::dot(&a, &b).to_bits());
            assert_eq!(sq_norm_with(&a, false).to_bits(), ops::l2_norm_sq(&a).to_bits());
            assert_eq!(max_abs_with(&a, false).to_bits(), ops::inf_norm(&a).to_bits());
        }
    }

    #[test]
    fn simd_dot_agrees_with_scalar() {
        for n in [0, 1, 2, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1000, 2047, 2048, 2049, 5000] {
            let a = vec_a(n);
            let b = vec_b(n);
            let s = dot_with(&a, &b, false);
            let v = dot_with(&a, &b, true);
            assert!(rel(v, s) < 1e-12 || (s == 0.0 && v.abs() < 1e-12), "n={n}: {v} vs {s}");
        }
    }

    #[test]
    fn simd_dot_is_blockwise_consistent() {
        // A long dot must equal the left-to-right sum of panel dots: this is
        // what makes cache-blocked tmatvec bit-identical to per-column dot.
        let n = 3 * PANEL_ROWS + 123;
        let a = vec_a(n);
        let b = vec_b(n);
        let whole = dot_with(&a, &b, true);
        let mut sum = dot_with(&a[..PANEL_ROWS], &b[..PANEL_ROWS], true);
        let mut i = PANEL_ROWS;
        while i < n {
            let hi = (i + PANEL_ROWS).min(n);
            sum += dot_with(&a[i..hi], &b[i..hi], true);
            i = hi;
        }
        assert_eq!(whole.to_bits(), sum.to_bits());
    }

    #[test]
    fn simd_reductions_agree() {
        for n in [0, 1, 5, 8, 13, 100, 4097] {
            let a = vec_a(n);
            let s = sq_norm_with(&a, false);
            assert!(rel(sq_norm_with(&a, true), s) < 1e-12 || s == 0.0);
            // max/abs are exact: bit-identical across branches.
            assert_eq!(max_abs_with(&a, true).to_bits(), max_abs_with(&a, false).to_bits());
        }
    }

    #[test]
    fn sparse_dot_branches_agree() {
        let x = vec_a(50);
        let rows: Vec<usize> = (0..23).map(|i| (i * 7) % 50).collect();
        let vals = vec_b(23);
        let s = sparse_dot_with(&rows, &vals, &x, false);
        let v = sparse_dot_with(&rows, &vals, &x, true);
        assert!(rel(v, s) < 1e-12);
        assert_eq!(sparse_dot_with(&[], &[], &x, true), 0.0);
    }

    #[test]
    fn dist_sq_scaled_branches_agree() {
        for n in [0, 1, 3, 4, 5, 97] {
            let y = vec_a(n);
            let t = vec_b(n);
            let s = dist_sq_scaled_with(&y, &t, 0.37, false);
            let v = dist_sq_scaled_with(&y, &t, 0.37, true);
            assert!(rel(v, s) < 1e-12 || s == 0.0);
        }
    }

    #[test]
    fn axpy_matches_ops_bitwise() {
        for n in [0, 1, 7, 8, 9, 40] {
            let x = vec_a(n);
            let mut y1 = vec_b(n);
            let mut y2 = y1.clone();
            axpy(0.731, &x, &mut y1);
            ops::axpy(0.731, &x, &mut y2);
            assert_eq!(y1, y2);
            axpy(0.0, &x, &mut y1);
            assert_eq!(y1, y2);
        }
    }

    #[test]
    fn axpy_rows_is_windowed_axpy() {
        let x = vec_a(20);
        let mut out = vec![0.0; 6];
        axpy_rows(2.0, &x, 4, 10, &mut out);
        let expect: Vec<f64> = x[4..10].iter().map(|v| 2.0 * v).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn sub_into_subtracts() {
        let a = [5.0, 1.0, -2.0];
        let b = [1.0, 1.0, 1.5];
        let mut out = [0.0; 3];
        sub_into(&a, &b, &mut out);
        assert_eq!(out, [4.0, 0.0, -3.5]);
    }

    #[test]
    fn subnormal_and_signed_zero_inputs() {
        let tiny = f64::MIN_POSITIVE / 8.0;
        let a = [tiny, -tiny, 0.0, -0.0, tiny, tiny, -tiny, 0.0, tiny];
        let b = [tiny, tiny, -0.0, 0.0, -tiny, tiny, tiny, 1.0, tiny];
        let s = dot_with(&a, &b, false);
        let v = dot_with(&a, &b, true);
        assert!((v - s).abs() <= s.abs() * 1e-12 + f64::MIN_POSITIVE);
        assert_eq!(max_abs_with(&a, true), tiny);
    }

    #[test]
    fn lanes_reported() {
        assert_eq!(lanes(), LANES);
        assert!(lanes() >= 2);
    }
}
