//! Linear-algebra substrate.
//!
//! The Sparse-Group Lasso solvers need column-oriented design matrices
//! (feature columns are accessed constantly), matrix-vector products,
//! vector norms, and power iteration for block spectral norms `‖X_g‖₂`.
//! All of it lives here, implemented from scratch for this offline
//! environment, behind the [`Design`] backend abstraction:
//!
//! - [`Matrix`] — column-major dense storage (the original backend);
//! - [`CscMatrix`] — compressed sparse columns, whose sweeps only touch
//!   stored entries (`O(nnz)` per epoch instead of `O(n·p)`).

pub mod dense;
pub mod design;
pub mod ops;
pub mod simd;
pub mod sparse;
pub mod spectral;

pub use dense::Matrix;
pub use design::{block_spectral_norm_generic, Design};
pub use ops::{axpy, dot, inf_norm, l1_norm, l2_norm, l2_norm_sq, scale, sub};
pub use simd::KernelPolicy;
pub use sparse::CscMatrix;
pub use spectral::{power_iteration, spectral_norm};
