//! Dense linear algebra substrate.
//!
//! The Sparse-Group Lasso solver needs column-major dense matrices (feature
//! columns are accessed constantly), matrix-vector products, vector norms,
//! power iteration for block spectral norms `‖X_g‖₂`, and a Cholesky-based
//! multivariate normal sampler for the synthetic designs. All of it lives
//! here, implemented from scratch for this offline environment.

pub mod dense;
pub mod ops;
pub mod spectral;

pub use dense::Matrix;
pub use ops::{axpy, dot, inf_norm, l1_norm, l2_norm, l2_norm_sq, scale, sub};
pub use spectral::{power_iteration, spectral_norm};
