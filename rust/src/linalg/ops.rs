//! Vector kernels shared by the solver hot loops.

/// Dot product with 4-way unrolling (the compiler auto-vectorizes this
/// pattern reliably; see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Elementwise `a - b` into a new vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Euclidean norm.
#[inline]
pub fn l2_norm(x: &[f64]) -> f64 {
    l2_norm_sq(x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn l2_norm_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// `ℓ₁` norm.
#[inline]
pub fn l1_norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// `ℓ∞` norm.
#[inline]
pub fn inf_norm(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Euclidean distance between two vectors.
#[inline]
pub fn l2_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_updates() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
        axpy(0.0, &x, &mut y);
        assert_eq!(y, vec![10.5, 21.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert_eq!(l2_norm(&x), 5.0);
        assert_eq!(l1_norm(&x), 7.0);
        assert_eq!(inf_norm(&x), 4.0);
        assert_eq!(l2_norm_sq(&x), 25.0);
    }

    #[test]
    fn sub_and_dist() {
        assert_eq!(sub(&[3.0, 1.0], &[1.0, 1.0]), vec![2.0, 0.0]);
        assert_eq!(l2_dist(&[0.0, 0.0], &[3.0, 4.0]), 5.0);
    }

    #[test]
    fn scale_in_place() {
        let mut x = vec![1.0, -2.0];
        scale(-2.0, &mut x);
        assert_eq!(x, vec![-2.0, 4.0]);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(l2_norm(&[]), 0.0);
        assert_eq!(inf_norm(&[]), 0.0);
    }
}
