//! Column-major dense matrix.
//!
//! Column-major storage is the natural layout for coordinate-descent
//! solvers: the inner loop repeatedly reads whole feature columns `X_j` and
//! group sub-matrices `X_g` (contiguous column ranges).

use super::ops::dot;
use super::simd;

/// Column-major `n_rows x n_cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    n_rows: usize,
    n_cols: usize,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        Matrix { data: vec![0.0; n_rows * n_cols], n_rows, n_cols }
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(data: Vec<f64>, n_rows: usize, n_cols: usize) -> Self {
        assert_eq!(data.len(), n_rows * n_cols, "buffer size mismatch");
        Matrix { data, n_rows, n_cols }
    }

    /// Build from a row-major buffer (transposing into column-major).
    pub fn from_row_major(data: &[f64], n_rows: usize, n_cols: usize) -> Self {
        assert_eq!(data.len(), n_rows * n_cols, "buffer size mismatch");
        let mut m = Matrix::zeros(n_rows, n_cols);
        for i in 0..n_rows {
            for j in 0..n_cols {
                m.set(i, j, data[i * n_cols + j]);
            }
        }
        m
    }

    /// Build column by column from a closure.
    pub fn from_fn(n_rows: usize, n_cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(n_rows, n_cols);
        for j in 0..n_cols {
            for i in 0..n_rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    #[inline]
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.n_rows && j < self.n_cols);
        self.data[j * self.n_rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n_rows && j < self.n_cols);
        self.data[j * self.n_rows + i] = v;
    }

    /// Contiguous view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.n_cols);
        &self.data[j * self.n_rows..(j + 1) * self.n_rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.n_cols);
        &mut self.data[j * self.n_rows..(j + 1) * self.n_rows]
    }

    /// Contiguous view of columns `j0..j1` (e.g. a group block `X_g`).
    #[inline]
    pub fn cols(&self, j0: usize, j1: usize) -> &[f64] {
        debug_assert!(j0 <= j1 && j1 <= self.n_cols);
        &self.data[j0 * self.n_rows..j1 * self.n_rows]
    }

    /// Full column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Row-major copy of the data (for the XLA runtime, which takes
    /// row-major literals).
    pub fn to_row_major(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n_rows * self.n_cols];
        for j in 0..self.n_cols {
            let col = self.col(j);
            for i in 0..self.n_rows {
                out[i * self.n_cols + j] = col[i];
            }
        }
        out
    }

    /// `y = A v` (dense GEMV). `v.len() == n_cols`, result length `n_rows`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        self.matvec_into(v, &mut y);
        y
    }

    /// `y = A v`, writing into a caller-provided buffer (hot path: avoids
    /// allocation).
    pub fn matvec_into(&self, v: &[f64], y: &mut [f64]) {
        assert_eq!(v.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        y.fill(0.0);
        for j in 0..self.n_cols {
            let vj = v[j];
            if vj == 0.0 {
                continue; // sparse beta: skip zero coefficients entirely
            }
            // Elementwise, so the unrolled axpy is bit-identical to the old
            // per-element loop under every kernel policy.
            simd::axpy(vj, self.col(j), y);
        }
    }

    /// `z = Aᵀ u`. `u.len() == n_rows`, result length `n_cols`.
    pub fn tmatvec(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.n_rows);
        let mut z = vec![0.0; self.n_cols];
        self.tmatvec_into(u, &mut z);
        z
    }

    /// `z = Aᵀ u`, into a caller-provided buffer.
    ///
    /// Under the SIMD kernel policy this streams cache-blocked column
    /// panels: a [`simd::PANEL_ROWS`]-row slab of `u` stays L1-resident
    /// while every column's matching slab is reduced against it, instead of
    /// each column walking the full (cache-cold for large `n`) vector.
    /// Because [`simd::dot_with`] is *defined* blockwise at the same panel
    /// size (first panel assigned, the rest accumulated left to right), the
    /// blocked result is bit-identical to per-column [`simd::dot`] — which
    /// keeps serial and parallel `xt` sweeps exactly equal under either
    /// policy.
    pub fn tmatvec_into(&self, u: &[f64], z: &mut [f64]) {
        assert_eq!(u.len(), self.n_rows);
        assert_eq!(z.len(), self.n_cols);
        if !simd::use_simd() {
            for (j, zj) in z.iter_mut().enumerate() {
                *zj = dot(self.col(j), u);
            }
            return;
        }
        let n = self.n_rows;
        let first = simd::PANEL_ROWS.min(n);
        for (j, zj) in z.iter_mut().enumerate() {
            *zj = simd::dot_with(&self.col(j)[..first], &u[..first], true);
        }
        let mut r0 = first;
        while r0 < n {
            let r1 = (r0 + simd::PANEL_ROWS).min(n);
            let up = &u[r0..r1];
            for (j, zj) in z.iter_mut().enumerate() {
                *zj += simd::dot_with(&self.col(j)[r0..r1], up, true);
            }
            r0 = r1;
        }
    }

    /// `Xᵀu` restricted to columns `j0..j1` (a group block).
    pub fn tmatvec_block(&self, j0: usize, j1: usize, u: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), j1 - j0);
        for (k, j) in (j0..j1).enumerate() {
            out[k] = simd::dot(self.col(j), u);
        }
    }

    /// Euclidean norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols).map(|j| simd::l2_norm(self.col(j))).collect()
    }

    /// Frobenius norm of the column block `j0..j1`.
    pub fn block_frobenius(&self, j0: usize, j1: usize) -> f64 {
        simd::l2_norm(self.cols(j0, j1))
    }

    /// Vertical stack: `[self; other]` (used by the elastic-net
    /// reformulation `X̃ = [X; sqrt(λ₂) I]`).
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n_cols, other.n_cols);
        let n = self.n_rows + other.n_rows;
        let mut m = Matrix::zeros(n, self.n_cols);
        for j in 0..self.n_cols {
            let dst = m.col_mut(j);
            dst[..self.n_rows].copy_from_slice(self.col(j));
            dst[self.n_rows..].copy_from_slice(other.col(j));
        }
        m
    }

    /// Select a subset of columns, packed contiguously in the given order
    /// (active-set compaction for the dense backend).
    pub fn select_cols(&self, cols: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(self.n_rows, cols.len());
        for (k, &j) in cols.iter().enumerate() {
            m.col_mut(k).copy_from_slice(self.col(j));
        }
        m
    }

    /// Select a subset of rows (used for train/test splits).
    pub fn select_rows(&self, rows: &[usize]) -> Matrix {
        let mut m = Matrix::zeros(rows.len(), self.n_cols);
        for j in 0..self.n_cols {
            let src = self.col(j);
            let dst = m.col_mut(j);
            for (k, &i) in rows.iter().enumerate() {
                dst[k] = src[i];
            }
        }
        m
    }

    /// Identity scaled by `s`.
    pub fn scaled_identity(n: usize, s: f64) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, s);
        }
        m
    }
}

impl super::design::Design for Matrix {
    #[inline]
    fn n_rows(&self) -> usize {
        Matrix::n_rows(self)
    }

    #[inline]
    fn n_cols(&self) -> usize {
        Matrix::n_cols(self)
    }

    #[inline]
    fn nnz(&self) -> usize {
        self.data.len()
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        simd::dot(self.col(j), v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        simd::axpy(alpha, self.col(j), out);
    }

    #[inline]
    fn col_axpy_rows(&self, j: usize, alpha: f64, row0: usize, row1: usize, out: &mut [f64]) {
        simd::axpy_rows(alpha, self.col(j), row0, row1, out);
    }

    #[inline]
    fn col_norm(&self, j: usize) -> f64 {
        simd::l2_norm(self.col(j))
    }

    fn col_norms(&self) -> Vec<f64> {
        Matrix::col_norms(self)
    }

    fn matvec_into(&self, v: &[f64], y: &mut [f64]) {
        Matrix::matvec_into(self, v, y)
    }

    fn tmatvec_into(&self, u: &[f64], z: &mut [f64]) {
        Matrix::tmatvec_into(self, u, z)
    }

    fn tmatvec_block(&self, j0: usize, j1: usize, u: &[f64], out: &mut [f64]) {
        Matrix::tmatvec_block(self, j0, j1, u, out)
    }

    fn select_cols(&self, cols: &[usize]) -> Matrix {
        Matrix::select_cols(self, cols)
    }

    fn select_rows(&self, rows: &[usize]) -> Matrix {
        Matrix::select_rows(self, rows)
    }

    /// Dense override: the specialized power iteration in
    /// [`super::spectral`] (bit-identical arithmetic to the generic path,
    /// but streams the contiguous block directly).
    fn block_spectral_norm(&self, j0: usize, j1: usize) -> f64 {
        super::spectral::spectral_norm(self, j0, j1, 1e-12, 1000)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        // [[1, 2, 3],
        //  [4, 5, 6]]
        Matrix::from_row_major(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3)
    }

    #[test]
    fn indexing_and_columns() {
        let m = sample();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.cols(1, 3), &[2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn row_major_round_trip() {
        let m = sample();
        assert_eq!(m.to_row_major(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let m = sample();
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(m.tmatvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matvec_skips_zeros() {
        let m = sample();
        // same result with and without the sparsity fast path
        let dense = m.matvec(&[0.5, 0.25, 0.125]);
        let sparse = m.matvec(&[0.5, 0.0, 0.125]);
        assert!(dense[0] != sparse[0]);
        assert_eq!(sparse, vec![0.5 + 3.0 * 0.125, 2.0 + 6.0 * 0.125]);
    }

    #[test]
    fn block_tmatvec() {
        let m = sample();
        let mut out = vec![0.0; 2];
        m.tmatvec_block(1, 3, &[1.0, 1.0], &mut out);
        assert_eq!(out, vec![7.0, 9.0]);
    }

    #[test]
    fn col_norms_correct() {
        let m = sample();
        let norms = m.col_norms();
        assert!((norms[0] - (17.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn vstack_shapes() {
        let m = sample();
        let id = Matrix::scaled_identity(3, 2.0);
        let s = m.vstack(&id);
        assert_eq!(s.n_rows(), 5);
        assert_eq!(s.get(0, 0), 1.0);
        assert_eq!(s.get(2, 0), 2.0);
        assert_eq!(s.get(3, 1), 2.0);
        assert_eq!(s.get(4, 2), 2.0);
        assert_eq!(s.get(4, 0), 0.0);
    }

    #[test]
    fn select_rows_subset() {
        let m = sample();
        let s = m.select_rows(&[1]);
        assert_eq!(s.n_rows(), 1);
        assert_eq!(s.col(2), &[6.0]);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        Matrix::from_col_major(vec![1.0, 2.0, 3.0], 2, 2);
    }
}
