//! PJRT CPU client wrapper plus f64 literal helpers.

use crate::linalg::Matrix;
use anyhow::{Context, Result};

/// Owns the PJRT client. One per process; artifacts borrow it to compile.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    pub(crate) fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// 1-D f64 literal.
pub fn lit_vec(v: &[f64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// 2-D f64 literal from a row-major buffer.
pub fn lit_mat_row_major(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// 2-D f64 literal from a [`Matrix`] (converts to row-major).
pub fn lit_matrix(m: &Matrix) -> Result<xla::Literal> {
    lit_mat_row_major(&m.to_row_major(), m.n_rows(), m.n_cols())
}

/// Scalar f64 literal.
pub fn lit_scalar(v: f64) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a Vec<f64> from a literal (any shape; row-major order).
pub fn to_vec_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f64>()?)
}

/// Extract a scalar f64.
pub fn to_scalar_f64(lit: &xla::Literal) -> Result<f64> {
    Ok(lit.get_first_element::<f64>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips() {
        let v = vec![1.0, -2.5, 3.0];
        let lit = lit_vec(&v);
        assert_eq!(to_vec_f64(&lit).unwrap(), v);
        assert_eq!(to_scalar_f64(&lit_scalar(4.5)).unwrap(), 4.5);
    }

    #[test]
    fn matrix_literal_shape() {
        let m = Matrix::from_row_major(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3);
        let lit = lit_matrix(&m).unwrap();
        assert_eq!(to_vec_f64(&lit).unwrap(), m.to_row_major());
    }

    #[test]
    fn client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.device_count() >= 1);
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }
}
