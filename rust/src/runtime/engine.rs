//! XLA-backed SGL solver: drives the two AOT artifacts
//! (`ista_epoch.hlo.txt`, `screen.hlo.txt`) in the masked-ISTA scheme.
//!
//! The division of labour mirrors the paper's Algorithm 2 at artifact
//! granularity: the **epoch artifact** runs `n_inner` proximal-gradient
//! steps over the masked active set; the **screen artifact** computes the
//! dual-scaled feasible point (Eq. 15), the duality gap, the GAP safe
//! radius (Thm. 2) and the Theorem-1 tests, returning updated masks. Rust
//! owns the outer loop, convergence policy, and all state; Python never
//! runs here.
//!
//! The PJRT execution path needs the `xla` bindings crate, which offline
//! build images do not carry; it is compiled only under the `xla` feature.
//! Without the feature, [`XlaEngine`]/[`XlaSession`] keep the exact same
//! API but every entry point returns an explanatory error, so callers
//! (CLI `xla` subcommand, `examples/xla_pipeline.rs`, `bench_runtime`)
//! build and degrade gracefully. [`ArtifactMeta`] is pure TOML and is
//! always available.

use crate::config::toml::TomlDoc;
use crate::solver::problem::SglProblem;
use anyhow::{Context, Result};
use std::path::Path;

#[cfg(feature = "xla")]
use super::artifact::Artifact;
#[cfg(feature = "xla")]
use super::client::{lit_matrix, lit_scalar, lit_vec, to_scalar_f64, to_vec_f64, Runtime};
#[cfg(feature = "xla")]
use crate::solver::ista::global_lipschitz;
#[cfg(feature = "xla")]
use anyhow::ensure;

/// Shape metadata baked into a set of artifacts (written by `aot.py`).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub n: usize,
    pub p: usize,
    pub n_groups: usize,
    pub group_size: usize,
    /// Inner proximal-gradient steps per epoch-artifact call.
    pub n_inner: usize,
}

impl ArtifactMeta {
    /// Parse `meta.toml` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.toml"))
            .with_context(|| format!("reading {}/meta.toml", dir.display()))?;
        let doc = TomlDoc::parse(&text)?;
        let get = |k: &str| -> Result<usize> {
            doc.get_int("shape", k)
                .map(|v| v as usize)
                .with_context(|| format!("meta.toml missing shape.{k}"))
        };
        Ok(ArtifactMeta {
            n: get("n")?,
            p: get("p")?,
            n_groups: get("n_groups")?,
            group_size: get("group_size")?,
            n_inner: get("n_inner")?,
        })
    }
}

/// Result of an engine solve.
#[derive(Clone, Debug)]
pub struct EngineSolveResult {
    pub beta: Vec<f64>,
    pub gap: f64,
    pub converged: bool,
    /// Outer rounds executed (each = 1 screen + 1 epoch artifact call).
    pub rounds: usize,
    pub active_features: usize,
    pub active_groups: usize,
}

/// Compiled artifact pair + metadata.
#[cfg(feature = "xla")]
pub struct XlaEngine {
    pub rt: Runtime,
    pub meta: ArtifactMeta,
    ista: Artifact,
    screen: Artifact,
}

#[cfg(feature = "xla")]
impl XlaEngine {
    /// Load and compile the artifacts in `dir` (default `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let rt = Runtime::cpu()?;
        let meta = ArtifactMeta::load(dir)?;
        let ista = Artifact::load(&rt, &dir.join("ista_epoch.hlo.txt"))?;
        let screen = Artifact::load(&rt, &dir.join("screen.hlo.txt"))?;
        Ok(XlaEngine { rt, meta, ista, screen })
    }

    /// Bind a problem to the engine (checks shapes, uploads constants).
    pub fn session<'e>(&'e self, pb: &SglProblem) -> Result<XlaSession<'e>> {
        let m = &self.meta;
        ensure!(pb.n() == m.n, "problem n={} but artifact n={}", pb.n(), m.n);
        ensure!(pb.p() == m.p, "problem p={} but artifact p={}", pb.p(), m.p);
        ensure!(
            pb.groups.is_uniform() == Some(m.group_size),
            "artifacts require uniform groups of {}",
            m.group_size
        );
        ensure!(pb.n_groups() == m.n_groups, "group count mismatch");
        let x_lit = lit_matrix(&pb.x)?;
        let y_lit = lit_vec(&pb.y);
        let w_lit = lit_vec(&pb.weights);
        let xjn_lit = lit_vec(&pb.col_norms);
        let xgn_lit = lit_vec(&pb.group_spectral_norms);
        let inv_l = 1.0 / global_lipschitz(pb).max(1e-300);
        let y_norm_sq = crate::linalg::ops::l2_norm_sq(&pb.y);
        Ok(XlaSession {
            engine: self,
            x_lit,
            y_lit,
            w_lit,
            xjn_lit,
            xgn_lit,
            inv_l,
            tau: pb.tau,
            y_norm_sq,
        })
    }
}

/// Per-problem state: constant literals uploaded once.
#[cfg(feature = "xla")]
pub struct XlaSession<'e> {
    engine: &'e XlaEngine,
    x_lit: xla::Literal,
    y_lit: xla::Literal,
    w_lit: xla::Literal,
    xjn_lit: xla::Literal,
    xgn_lit: xla::Literal,
    inv_l: f64,
    tau: f64,
    y_norm_sq: f64,
}

#[cfg(feature = "xla")]
impl<'e> XlaSession<'e> {
    /// Run the masked-ISTA solve at one `λ`. `tol` is relative to `‖y‖²`
    /// (same convention as `solver::cd::SolveOptions::tol`).
    pub fn solve(
        &self,
        lambda: f64,
        tol: f64,
        max_rounds: usize,
        beta0: Option<&[f64]>,
        screening: bool,
    ) -> Result<EngineSolveResult> {
        let m = &self.engine.meta;
        let tol_abs = tol * self.y_norm_sq.max(f64::MIN_POSITIVE);
        let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; m.p]);
        ensure!(beta.len() == m.p, "beta0 length mismatch");
        let mut feat_mask = vec![1.0_f64; m.p];
        let mut group_mask = vec![1.0_f64; m.n_groups];
        let lam_lit = lit_scalar(lambda);
        let tau_lit = lit_scalar(self.tau);
        let invl_lit = lit_scalar(self.inv_l);
        let mut gap = f64::INFINITY;
        let mut rounds = 0usize;
        let mut converged = false;

        for round in 0..max_rounds {
            rounds = round + 1;
            // ---- screen + gap
            let outs = self.engine.screen.execute(&[
                self.x_lit.clone(),
                self.y_lit.clone(),
                lit_vec(&beta),
                lit_vec(&feat_mask),
                lit_vec(&group_mask),
                self.w_lit.clone(),
                self.xjn_lit.clone(),
                self.xgn_lit.clone(),
                lam_lit.clone(),
                tau_lit.clone(),
            ])?;
            ensure!(outs.len() == 4, "screen artifact must return 4 outputs");
            gap = to_scalar_f64(&outs[0])?;
            let _radius = to_scalar_f64(&outs[1])?;
            if screening {
                feat_mask = to_vec_f64(&outs[2])?;
                group_mask = to_vec_f64(&outs[3])?;
                // Enforce mask-consistency on beta (screened coords -> 0).
                for (b, &fm) in beta.iter_mut().zip(&feat_mask) {
                    if fm == 0.0 {
                        *b = 0.0;
                    }
                }
            }
            if gap <= tol_abs {
                converged = true;
                break;
            }
            // ---- one epoch artifact call (n_inner prox-gradient steps)
            let outs = self.engine.ista.execute(&[
                self.x_lit.clone(),
                self.y_lit.clone(),
                lit_vec(&beta),
                lit_vec(&feat_mask),
                self.w_lit.clone(),
                lam_lit.clone(),
                tau_lit.clone(),
                invl_lit.clone(),
            ])?;
            ensure!(outs.len() == 1, "ista artifact must return 1 output");
            beta = to_vec_f64(&outs[0])?;
        }

        Ok(EngineSolveResult {
            gap,
            converged,
            rounds,
            active_features: feat_mask.iter().filter(|&&v| v != 0.0).count(),
            active_groups: group_mask.iter().filter(|&&v| v != 0.0).count(),
            beta,
        })
    }
}

// ---------------------------------------------------------------------------
// Featureless stub: identical surface, every entry point errors.
// ---------------------------------------------------------------------------

/// Placeholder for the PJRT client when the `xla` feature is off.
#[cfg(not(feature = "xla"))]
pub struct StubRuntime;

#[cfg(not(feature = "xla"))]
impl StubRuntime {
    pub fn platform(&self) -> String {
        "unavailable (crate built without the `xla` feature)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }
}

/// Engine stub compiled when the `xla` feature is off. [`XlaEngine::load`]
/// always fails with an actionable message, so this struct is never
/// actually constructed — it exists to keep every caller compiling.
#[cfg(not(feature = "xla"))]
pub struct XlaEngine {
    pub rt: StubRuntime,
    pub meta: ArtifactMeta,
}

#[cfg(not(feature = "xla"))]
impl XlaEngine {
    pub fn load(dir: &Path) -> Result<Self> {
        // Surface meta.toml problems first (same failure order as the real
        // engine), then report the missing backend.
        let _meta = ArtifactMeta::load(dir)?;
        anyhow::bail!(
            "PJRT runtime unavailable: this build has no `xla` feature. \
             Rebuild with `cargo build --features xla` in an environment that \
             vendors the xla bindings, or use the native solver instead \
             (`sgl solve` / `sgl path`)"
        )
    }

    pub fn session<'e>(&'e self, _pb: &SglProblem) -> Result<XlaSession<'e>> {
        anyhow::bail!("PJRT runtime unavailable: built without the `xla` feature")
    }
}

/// Session stub compiled when the `xla` feature is off.
#[cfg(not(feature = "xla"))]
pub struct XlaSession<'e> {
    _engine: std::marker::PhantomData<&'e XlaEngine>,
}

#[cfg(not(feature = "xla"))]
impl<'e> XlaSession<'e> {
    pub fn solve(
        &self,
        _lambda: f64,
        _tol: f64,
        _max_rounds: usize,
        _beta0: Option<&[f64]>,
        _screening: bool,
    ) -> Result<EngineSolveResult> {
        anyhow::bail!("PJRT runtime unavailable: built without the `xla` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let dir = std::env::temp_dir().join(format!("sgl-meta-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.toml"),
            "[shape]\nn = 100\np = 1000\nn_groups = 100\ngroup_size = 10\nn_inner = 10\n",
        )
        .unwrap();
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(
            m,
            ArtifactMeta { n: 100, p: 1000, n_groups: 100, group_size: 10, n_inner: 10 }
        );
    }

    #[test]
    fn missing_meta_is_error() {
        assert!(ArtifactMeta::load(Path::new("/nonexistent")).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let dir = std::env::temp_dir().join(format!("sgl-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.toml"),
            "[shape]\nn = 10\np = 20\nn_groups = 4\ngroup_size = 5\nn_inner = 2\n",
        )
        .unwrap();
        let err = XlaEngine::load(&dir).unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
    }
}
