//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust solve path.
//!
//! Interchange is **HLO text** (`artifacts/*.hlo.txt`): jax ≥ 0.5 emits
//! `HloModuleProto`s with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md).

#[cfg(feature = "xla")]
pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
pub mod engine;

#[cfg(feature = "xla")]
pub use artifact::Artifact;
#[cfg(feature = "xla")]
pub use client::Runtime;
