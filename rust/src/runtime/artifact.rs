//! Load-and-execute wrapper for one AOT artifact (`*.hlo.txt`).

use super::client::Runtime;
use anyhow::{Context, Result};
use std::path::Path;

/// A compiled XLA executable loaded from an HLO text file.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Load HLO text and compile it on the runtime's client.
    pub fn load(rt: &Runtime, path: &Path) -> Result<Artifact> {
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| "artifact".to_string());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = rt
            .client()
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", path.display()))?;
        Ok(Artifact { name, exe })
    }

    /// Execute with the given input literals. The artifacts are lowered
    /// with `return_tuple=True`, so the single output literal is a tuple;
    /// this unpacks it into its elements.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client::{lit_vec, to_vec_f64};

    /// Build a tiny HLO module by hand (via XlaBuilder -> proto text is not
    /// exposed, so instead test against a generated artifact when present).
    #[test]
    fn loads_generated_artifact_if_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/smoke.hlo.txt");
        if !path.exists() {
            eprintln!("skipping: {} not built (run `make artifacts`)", path.display());
            return;
        }
        let rt = Runtime::cpu().unwrap();
        let art = Artifact::load(&rt, &path).unwrap();
        // smoke artifact: f(x) = (2*x + 1,) for x of shape (4,)
        let out = art.execute(&[lit_vec(&[1.0, 2.0, 3.0, 4.0])]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(to_vec_f64(&out[0]).unwrap(), vec![3.0, 5.0, 7.0, 9.0]);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(Artifact::load(&rt, Path::new("/nonexistent/foo.hlo.txt")).is_err());
    }
}
