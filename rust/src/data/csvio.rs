//! Minimal CSV I/O for experiment outputs (figure series) and dataset
//! round-trips.

use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Write a table: header + rows, comma-separated.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        if row.len() != header.len() {
            bail!("row width {} != header width {}", row.len(), header.len());
        }
        let line: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Write a matrix (row-major) with no header.
pub fn write_matrix_csv(path: &Path, m: &Matrix) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::fs::File::create(path)?;
    for i in 0..m.n_rows() {
        let row: Vec<String> = (0..m.n_cols()).map(|j| format!("{}", m.get(i, j))).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a headerless numeric CSV into a matrix.
pub fn read_matrix_csv(path: &Path) -> Result<Matrix> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let row: Result<Vec<f64>, _> =
            line.split(',').map(|tok| tok.trim().parse::<f64>()).collect();
        let row = row.with_context(|| format!("line {}", lineno + 1))?;
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                bail!("ragged CSV at line {}", lineno + 1);
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let n_rows = rows.len();
    let n_cols = rows[0].len();
    let flat: Vec<f64> = rows.into_iter().flatten().collect();
    Ok(Matrix::from_row_major(&flat, n_rows, n_cols))
}

/// Write a single numeric vector, one value per line.
pub fn write_vector(path: &Path, v: &[f64]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f = std::fs::File::create(path)?;
    for x in v {
        writeln!(f, "{x}")?;
    }
    Ok(())
}

/// Read a single numeric vector.
pub fn read_vector(path: &Path) -> Result<Vec<f64>> {
    let text = std::fs::read_to_string(path)?;
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .map(|l| l.parse::<f64>().map_err(|e| anyhow::anyhow!("{e}: {l:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("sgl-csv-test-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn matrix_round_trip() {
        let path = tmpdir().join("m.csv");
        let m = Matrix::from_row_major(&[1.0, 2.5, -3.0, 4.0], 2, 2);
        write_matrix_csv(&path, &m).unwrap();
        let back = read_matrix_csv(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn vector_round_trip() {
        let path = tmpdir().join("v.csv");
        let v = vec![1.0, -2.0, 3.5];
        write_vector(&path, &v).unwrap();
        assert_eq!(read_vector(&path).unwrap(), v);
    }

    #[test]
    fn table_header_checked() {
        let path = tmpdir().join("t.csv");
        let err = write_csv(&path, &["a", "b"], &[vec![1.0]]);
        assert!(err.is_err());
        write_csv(&path, &["a", "b"], &[vec![1.0, 2.0]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
    }

    #[test]
    fn ragged_csv_rejected() {
        let path = tmpdir().join("ragged.csv");
        std::fs::write(&path, "1,2\n3\n").unwrap();
        assert!(read_matrix_csv(&path).is_err());
    }
}
