//! Sparse synthetic designs for the CSC backend.
//!
//! The journal extension ("Gap Safe screening rules for sparsity enforcing
//! penalties", Ndiaye et al. 2017) benchmarks on bag-of-words and one-hot
//! genomics designs where only ~0.1–5% of entries are nonzero. This
//! generator mirrors the §7.1 planted-model protocol (γ₁ active groups,
//! γ₂ active coordinates each, `y = Xβ + σε`) but draws each design entry
//! as `Bernoulli(density) · N(0, 1)`, building the CSC structure directly
//! — the dense mirror is never materialized unless a test asks for it via
//! [`crate::linalg::CscMatrix::to_dense`].

use crate::linalg::{CscMatrix, Design};
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;

/// Configuration for the sparse synthetic benchmark.
#[derive(Clone, Debug)]
pub struct SparseSyntheticConfig {
    pub n: usize,
    pub n_groups: usize,
    pub group_size: usize,
    /// Probability that any design entry is stored (≈ final density).
    pub density: f64,
    /// Number of active groups `γ₁`.
    pub gamma1: usize,
    /// Active coordinates per active group `γ₂`.
    pub gamma2: usize,
    /// Noise scale (paper: 0.01).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SparseSyntheticConfig {
    fn default() -> Self {
        SparseSyntheticConfig {
            n: 100,
            n_groups: 1000,
            group_size: 10,
            density: 0.01,
            gamma1: 10,
            gamma2: 4,
            noise: 0.01,
            seed: 42,
        }
    }
}

impl SparseSyntheticConfig {
    pub fn p(&self) -> usize {
        self.n_groups * self.group_size
    }

    /// A scaled-down variant for unit/integration tests.
    pub fn small(seed: u64) -> Self {
        SparseSyntheticConfig {
            n: 60,
            n_groups: 30,
            group_size: 5,
            density: 0.1,
            gamma1: 4,
            gamma2: 3,
            seed,
            ..Default::default()
        }
    }
}

/// Generated sparse dataset plus its planted ground truth.
#[derive(Clone, Debug)]
pub struct SparseSyntheticData {
    pub x: CscMatrix,
    pub y: Vec<f64>,
    pub groups: Groups,
    pub beta_true: Vec<f64>,
    pub active_groups_true: Vec<usize>,
}

/// Generate the sparse planted-model dataset.
pub fn generate(cfg: &SparseSyntheticConfig) -> SparseSyntheticData {
    assert!(cfg.gamma1 <= cfg.n_groups, "gamma1 > number of groups");
    assert!(cfg.gamma2 <= cfg.group_size, "gamma2 > group size");
    assert!((0.0..=1.0).contains(&cfg.density), "density must be in [0,1]");
    let p = cfg.p();
    let mut rng = Pcg::new(cfg.seed, 0x5BA5);

    // Column-by-column Bernoulli(density) support with N(0,1) values,
    // accumulated straight into CSC arrays.
    let mut indptr = Vec::with_capacity(p + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);
    for _ in 0..p {
        for i in 0..cfg.n {
            if rng.uniform() < cfg.density {
                indices.push(i);
                values.push(rng.normal());
            }
        }
        indptr.push(indices.len());
    }
    let x = CscMatrix::from_raw(cfg.n, p, indptr, indices, values);

    // Planted group-sparse coefficients (same protocol as the dense §7.1
    // generator).
    let groups = Groups::uniform(cfg.n_groups, cfg.group_size);
    let active_groups = rng.sample_indices(cfg.n_groups, cfg.gamma1);
    let mut beta_true = vec![0.0; p];
    for &g in &active_groups {
        let (a, _) = groups.bounds(g);
        let coords = rng.sample_indices(cfg.group_size, cfg.gamma2);
        for &k in &coords {
            let u = rng.uniform_in(0.5, 10.0);
            beta_true[a + k] = rng.sign() * u;
        }
    }

    // y = X beta + noise * eps.
    let mut y = x.matvec(&beta_true);
    for v in y.iter_mut() {
        *v += cfg.noise * rng.normal();
    }

    SparseSyntheticData { x, y, groups, beta_true, active_groups_true: active_groups }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_density() {
        let cfg = SparseSyntheticConfig {
            n: 50,
            n_groups: 20,
            group_size: 5,
            density: 0.1,
            gamma1: 3,
            gamma2: 2,
            ..Default::default()
        };
        let d = generate(&cfg);
        assert_eq!(d.x.n_rows(), 50);
        assert_eq!(d.x.n_cols(), 100);
        assert_eq!(d.y.len(), 50);
        // Density concentrates near the target (5000 Bernoulli draws).
        let dens = d.x.density();
        assert!((dens - 0.1).abs() < 0.03, "density {dens}");
        let nnz_beta = d.beta_true.iter().filter(|&&b| b != 0.0).count();
        assert_eq!(nnz_beta, 6);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SparseSyntheticConfig::small(5));
        let b = generate(&SparseSyntheticConfig::small(5));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&SparseSyntheticConfig::small(6));
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn response_matches_dense_reconstruction() {
        let d = generate(&SparseSyntheticConfig::small(7));
        let dense = d.x.to_dense();
        let xb = dense.matvec(&d.beta_true);
        // y = Xb + noise: residual should be pure noise scale.
        for (yi, xi) in d.y.iter().zip(&xb) {
            assert!((yi - xi).abs() < 0.2, "{yi} vs {xi}");
        }
    }
}
