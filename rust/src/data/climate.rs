//! Simulated NCEP/NCAR-Reanalysis-like climate dataset (paper §7.1, real
//! data experiment).
//!
//! **Substitution note (DESIGN.md §3).** The paper uses monthly means of 7
//! physical variables on a 2.5°×2.5° global grid (144×73 points, n = 814
//! months, p = 73 577 after concatenation), with *Air Temperature near
//! Dakar* as the target. That archive is not available offline, so this
//! module synthesizes a field with the statistics the screening experiments
//! actually exercise:
//!
//! 1. **grouped features** — each grid point is a group of 7 variables;
//! 2. **strong spatial correlation** — variables are mixtures of a few
//!    global smooth modes (low-order spherical harmonics analogue) plus
//!    local AR noise, so nearby grid points are highly correlated;
//! 3. **seasonality + trend** — added to every series and removed by the
//!    same preprocessing the paper applies (regressing out harmonics and a
//!    linear trend);
//! 4. **localized predictive structure** — the target is a noisy linear
//!    functional of the variables in a neighbourhood of a "Dakar" cell, so
//!    the oracle support is spatially concentrated (what Fig. 4 displays).

use super::Dataset;
use crate::linalg::Matrix;
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;

/// Number of physical variables per grid point (paper: 7 — air temperature,
/// precipitable water, relative humidity, pressure, sea-level pressure,
/// horizontal and vertical wind speed).
pub const N_VARS: usize = 7;

/// Simulated-climate configuration.
#[derive(Clone, Debug)]
pub struct ClimateConfig {
    /// Longitude grid points (paper: 144).
    pub grid_lon: usize,
    /// Latitude grid points (paper: 73).
    pub grid_lat: usize,
    /// Months of data (paper: 814).
    pub n_months: usize,
    /// Number of global smooth modes driving spatial correlation.
    pub n_modes: usize,
    /// Radius (in grid cells) of the predictive neighbourhood around the
    /// target cell.
    pub influence_radius: f64,
    /// Observation noise on the target.
    pub noise: f64,
    pub seed: u64,
}

impl Default for ClimateConfig {
    fn default() -> Self {
        // Default: a 37x18 grid => 666 groups, p = 4662. Same group
        // structure and correlation statistics as the paper's 144x73 grid
        // at ~1/10 the feature count (documented in DESIGN.md §3).
        ClimateConfig {
            grid_lon: 37,
            grid_lat: 18,
            n_months: 814,
            n_modes: 12,
            influence_radius: 2.5,
            noise: 0.5,
            seed: 7,
        }
    }
}

impl ClimateConfig {
    pub fn small(seed: u64) -> Self {
        ClimateConfig {
            grid_lon: 12,
            grid_lat: 6,
            n_months: 120,
            n_modes: 6,
            influence_radius: 1.0,
            seed,
            ..Default::default()
        }
    }

    pub fn n_locations(&self) -> usize {
        self.grid_lon * self.grid_lat
    }

    pub fn p(&self) -> usize {
        self.n_locations() * N_VARS
    }
}

/// Generated climate data plus ground-truth bookkeeping for Fig. 4.
#[derive(Clone, Debug)]
pub struct ClimateData {
    pub dataset: Dataset,
    pub cfg: ClimateConfig,
    /// Grid coordinates (lon, lat) of every group, in group order.
    pub locations: Vec<(usize, usize)>,
    /// Index of the target ("Dakar") cell's group.
    pub target_group: usize,
    /// True predictive weight per group (decays with distance).
    pub true_group_influence: Vec<f64>,
}

/// Generate the simulated dataset. Columns are ordered
/// location-major/variable-minor so each group (= location) is a contiguous
/// block of 7 columns, matching `Groups::uniform(n_locations, 7)`.
pub fn generate(cfg: &ClimateConfig) -> ClimateData {
    let n_loc = cfg.n_locations();
    let n = cfg.n_months;
    let p = cfg.p();
    let mut rng = Pcg::new(cfg.seed, 0xC11A);

    // Global smooth modes: each mode is a Gaussian bump with random center
    // and width (unit-RMS normalized) and an AR(1) temporal amplitude.
    // Bumps — unlike periodic harmonics — give spatial correlation that
    // genuinely *decays* with distance, as reanalysis fields do.
    let mut mode_patterns: Vec<Vec<f64>> = Vec::with_capacity(cfg.n_modes);
    for _m in 0..cfg.n_modes {
        let cx = rng.uniform_in(0.0, cfg.grid_lon as f64);
        let cy = rng.uniform_in(0.0, cfg.grid_lat as f64);
        let sigma = rng.uniform_in(0.12, 0.30) * cfg.grid_lon.max(cfg.grid_lat) as f64;
        let sign = rng.sign();
        let mut pat = vec![0.0; n_loc];
        let mut ss = 0.0;
        for lon in 0..cfg.grid_lon {
            for lat in 0..cfg.grid_lat {
                let d2 = (lon as f64 - cx).powi(2) + (lat as f64 - cy).powi(2);
                let v = sign * (-d2 / (2.0 * sigma * sigma)).exp();
                pat[lat * cfg.grid_lon + lon] = v;
                ss += v * v;
            }
        }
        let rms = (ss / n_loc as f64).sqrt().max(1e-12);
        for v in pat.iter_mut() {
            *v /= rms;
        }
        mode_patterns.push(pat);
    }
    let ar = 0.6; // temporal AR(1) coefficient of mode amplitudes
    let mut amplitudes = vec![vec![0.0; cfg.n_modes]; n];
    for m in 0..cfg.n_modes {
        let mut prev = rng.normal();
        for t in 0..n {
            prev = ar * prev + (1.0 - ar * ar).sqrt() * rng.normal();
            amplitudes[t][m] = prev;
        }
    }

    // Per-variable mixing of the modes + local noise + seasonality + trend.
    let mut var_loading = vec![vec![0.0; cfg.n_modes]; N_VARS];
    for v in 0..N_VARS {
        for m in 0..cfg.n_modes {
            var_loading[v][m] = rng.normal() * 0.8;
        }
    }
    // Seasonality is spatially coherent: a per-variable base phase with a
    // small per-location perturbation (the annual cycle does not flip sign
    // between neighbouring grid cells).
    let season_base_phase: Vec<f64> =
        (0..N_VARS).map(|_| rng.uniform_in(0.0, std::f64::consts::TAU)).collect();
    let mut x = Matrix::zeros(n, p);
    for loc in 0..n_loc {
        for v in 0..N_VARS {
            let j = loc * N_VARS + v;
            let season_amp = rng.uniform_in(0.3, 1.2);
            let season_phase = season_base_phase[v] + 0.15 * rng.normal();
            let trend = rng.uniform_in(-0.002, 0.002);
            let col = x.col_mut(j);
            for (t, c) in col.iter_mut().enumerate().take(n) {
                let mut s = 0.0;
                for m in 0..cfg.n_modes {
                    s += var_loading[v][m] * mode_patterns[m][loc] * amplitudes[t][m];
                }
                let season = season_amp
                    * (std::f64::consts::TAU * t as f64 / 12.0 + season_phase).sin();
                *c = s + season + trend * t as f64 + 0.4 * rng.normal();
            }
        }
    }

    // Target cell ("Dakar"): mid-latitude cell on the west side.
    let target_lon = cfg.grid_lon / 5;
    let target_lat = cfg.grid_lat / 2;
    let target_group = target_lat * cfg.grid_lon + target_lon;

    // True influence: exponential decay with distance from the target cell,
    // acting mostly on variable 0 (air temperature) with smaller loads on
    // the others.
    let mut true_group_influence = vec![0.0; n_loc];
    let mut y = vec![0.0; n];
    let mut var_weights = [0.0; N_VARS];
    for (v, w) in var_weights.iter_mut().enumerate() {
        *w = if v == 0 { 1.0 } else { 0.25 * rng.normal() };
    }
    for loc in 0..n_loc {
        let lon = loc % cfg.grid_lon;
        let lat = loc / cfg.grid_lon;
        let dist = (((lon as f64 - target_lon as f64).powi(2)
            + (lat as f64 - target_lat as f64).powi(2)) as f64)
            .sqrt();
        let influence = (-dist / cfg.influence_radius).exp();
        if influence < 0.05 {
            continue; // negligible: keeps oracle support local
        }
        true_group_influence[loc] = influence;
        for v in 0..N_VARS {
            let j = loc * N_VARS + v;
            let col = x.col(j);
            let w = influence * var_weights[v];
            for t in 0..n {
                y[t] += w * col[t];
            }
        }
    }
    for v in y.iter_mut() {
        *v += cfg.noise * rng.normal();
    }

    let groups = Groups::uniform(n_loc, N_VARS);
    let locations: Vec<(usize, usize)> =
        (0..n_loc).map(|loc| (loc % cfg.grid_lon, loc / cfg.grid_lon)).collect();
    ClimateData {
        dataset: Dataset {
            name: format!("sim-climate({}x{}, n={})", cfg.grid_lon, cfg.grid_lat, n),
            x,
            y,
            groups,
        },
        cfg: cfg.clone(),
        locations,
        target_group,
        true_group_influence,
    }
}

/// The paper's preprocessing: remove seasonality (annual harmonics) and a
/// linear trend from every series, then standardize.
pub fn preprocess(data: &mut ClimateData) {
    let n = data.dataset.n();
    // Covariates: intercept, t, sin/cos of the annual cycle (+ first
    // harmonic).
    let z = Matrix::from_fn(n, 6, |t, k| {
        let tf = t as f64;
        let ang = std::f64::consts::TAU * tf / 12.0;
        match k {
            0 => 1.0,
            1 => tf / n as f64,
            2 => ang.sin(),
            3 => ang.cos(),
            4 => (2.0 * ang).sin(),
            _ => (2.0 * ang).cos(),
        }
    });
    data.dataset.remove_covariates(&z);
    data.dataset.standardize();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let cfg = ClimateConfig::small(1);
        let d = generate(&cfg);
        assert_eq!(d.dataset.n(), 120);
        assert_eq!(d.dataset.p(), 12 * 6 * 7);
        assert_eq!(d.dataset.groups.n_groups(), 72);
        assert_eq!(d.dataset.groups.is_uniform(), Some(7));
        assert_eq!(d.locations.len(), 72);
    }

    #[test]
    fn influence_is_local_and_peaks_at_target() {
        let d = generate(&ClimateConfig::small(2));
        let max_i = d
            .true_group_influence
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_i, d.target_group);
        let n_influential =
            d.true_group_influence.iter().filter(|&&v| v > 0.0).count();
        assert!(n_influential < d.locations.len() / 2, "support must be local");
        assert!(n_influential >= 1);
    }

    #[test]
    fn nearby_locations_are_correlated() {
        let cfg = ClimateConfig::small(3);
        let mut d = generate(&cfg);
        // Compare *deseasonalized* fields (the shared annual cycle would
        // otherwise correlate every pair of cells equally).
        preprocess(&mut d);
        // Same variable (0) at adjacent locations should correlate much
        // more than at far locations.
        let corr = |a: &[f64], b: &[f64]| {
            let n = a.len() as f64;
            let ma = a.iter().sum::<f64>() / n;
            let mb = b.iter().sum::<f64>() / n;
            let (mut num, mut va, mut vb) = (0.0, 0.0, 0.0);
            for (x, y) in a.iter().zip(b) {
                num += (x - ma) * (y - mb);
                va += (x - ma) * (x - ma);
                vb += (y - mb) * (y - mb);
            }
            num / (va.sqrt() * vb.sqrt())
        };
        let mut near = Vec::new();
        let mut far = Vec::new();
        for lat in 0..cfg.grid_lat {
            let base = lat * cfg.grid_lon;
            near.push(corr(
                d.dataset.x.col(base * N_VARS),
                d.dataset.x.col((base + 1) * N_VARS),
            ));
            far.push(corr(
                d.dataset.x.col(base * N_VARS),
                d.dataset.x.col((base + cfg.grid_lon / 2) * N_VARS),
            ));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        // Adjacent cells share almost the same smooth-mode values: strongly
        // positively correlated; half-grid-away cells are not.
        assert!(mean(&near) > 0.25, "near corr too weak: {:.3}", mean(&near));
        assert!(
            mean(&near) > mean(&far) + 0.1,
            "near {:.3} vs far {:.3}",
            mean(&near),
            mean(&far)
        );
    }

    #[test]
    fn preprocess_removes_seasonality() {
        let cfg = ClimateConfig::small(4);
        let mut d = generate(&cfg);
        preprocess(&mut d);
        // After preprocessing, columns are centered unit-norm and the
        // annual harmonic is projected out.
        let n = d.dataset.n();
        let season: Vec<f64> =
            (0..n).map(|t| (std::f64::consts::TAU * t as f64 / 12.0).sin()).collect();
        for j in (0..d.dataset.p()).step_by(97) {
            let col = d.dataset.x.col(j);
            let c = crate::linalg::ops::dot(col, &season);
            assert!(c.abs() < 1e-8, "col {j} retains seasonality: {c}");
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(&ClimateConfig::small(9));
        let b = generate(&ClimateConfig::small(9));
        assert_eq!(a.dataset.y, b.dataset.y);
    }
}
