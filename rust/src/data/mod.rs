//! Dataset substrate: generation (synthetic §7.1 and simulated-climate),
//! standardization, CSV I/O, and sparse loaders (libsvm/svmlight text
//! straight into CSC — no dense detour).

pub mod climate;
pub mod csvio;
pub mod libsvm;
pub mod sparse;
pub mod synthetic;

use crate::linalg::{CscMatrix, Matrix};
use crate::solver::groups::Groups;

/// A regression dataset with group structure.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Matrix,
    pub y: Vec<f64>,
    pub groups: Groups,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }

    pub fn p(&self) -> usize {
        self.x.n_cols()
    }

    /// Center y and center + unit-norm-scale every column of X (columns
    /// with zero variance are left at zero). Standard preprocessing for
    /// penalized regression: makes `‖X_j‖ = 1` so feature-level screening
    /// tests are scale-free.
    pub fn standardize(&mut self) {
        let n = self.n();
        if n == 0 {
            return;
        }
        let y_mean = self.y.iter().sum::<f64>() / n as f64;
        for v in self.y.iter_mut() {
            *v -= y_mean;
        }
        for j in 0..self.p() {
            let col = self.x.col_mut(j);
            let mean = col.iter().sum::<f64>() / n as f64;
            for v in col.iter_mut() {
                *v -= mean;
            }
            let norm = col.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm > 0.0 {
                for v in col.iter_mut() {
                    *v /= norm;
                }
            }
        }
    }

    /// Regress out a set of deterministic covariates (columns of `z`) from
    /// both `X` and `y` — used by the climate pipeline to remove
    /// seasonality and trend, as the paper's preprocessing does.
    pub fn remove_covariates(&mut self, z: &Matrix) {
        assert_eq!(z.n_rows(), self.n());
        // Orthonormalize z by modified Gram-Schmidt.
        let mut basis: Vec<Vec<f64>> = Vec::new();
        for k in 0..z.n_cols() {
            let mut v = z.col(k).to_vec();
            for b in &basis {
                let c = crate::linalg::ops::dot(&v, b);
                for (vi, bi) in v.iter_mut().zip(b) {
                    *vi -= c * bi;
                }
            }
            let nv = crate::linalg::ops::l2_norm(&v);
            if nv > 1e-12 {
                for vi in v.iter_mut() {
                    *vi /= nv;
                }
                basis.push(v);
            }
        }
        let project_out = |target: &mut [f64]| {
            for b in &basis {
                let c = crate::linalg::ops::dot(target, b);
                for (ti, bi) in target.iter_mut().zip(b) {
                    *ti -= c * bi;
                }
            }
        };
        project_out(&mut self.y);
        for j in 0..self.p() {
            project_out(self.x.col_mut(j));
        }
    }
}

/// The sparse twin of [`Dataset`]: a dataset whose design never
/// materializes densely. Loaders build the CSC structure directly
/// ([`libsvm`], [`sparse`]), so a 1%-density bag-of-words matrix costs
/// `O(nnz)` memory end to end; the CLI dispatches on which of the two
/// the loader produced.
#[derive(Clone, Debug)]
pub struct SparseDataset {
    pub name: String,
    pub x: CscMatrix,
    pub y: Vec<f64>,
    pub groups: Groups,
}

impl SparseDataset {
    pub fn n(&self) -> usize {
        crate::linalg::Design::n_rows(&self.x)
    }

    pub fn p(&self) -> usize {
        crate::linalg::Design::n_cols(&self.x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_row_major(&[1.0, 10.0, 2.0, 20.0, 3.0, 60.0], 3, 2);
        Dataset {
            name: "toy".into(),
            x,
            y: vec![1.0, 2.0, 3.0],
            groups: Groups::uniform(1, 2),
        }
    }

    #[test]
    fn standardize_centers_and_scales() {
        let mut d = toy();
        d.standardize();
        assert!(d.y.iter().sum::<f64>().abs() < 1e-12);
        for j in 0..d.p() {
            let col = d.x.col(j);
            assert!(col.iter().sum::<f64>().abs() < 1e-12);
            let norm: f64 = col.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn standardize_handles_constant_column() {
        let x = Matrix::from_row_major(&[5.0, 1.0, 5.0, 2.0], 2, 2);
        let mut d = Dataset {
            name: "c".into(),
            x,
            y: vec![0.0, 1.0],
            groups: Groups::uniform(2, 1),
        };
        d.standardize();
        assert!(d.x.col(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn remove_covariates_orthogonalizes() {
        let mut d = toy();
        // Remove an intercept and a linear trend.
        let z = Matrix::from_fn(3, 2, |i, j| if j == 0 { 1.0 } else { i as f64 });
        d.remove_covariates(&z);
        // y = [1,2,3] is exactly intercept+trend: must vanish.
        assert!(d.y.iter().all(|v| v.abs() < 1e-10), "{:?}", d.y);
        // X columns are now orthogonal to the trend space.
        for j in 0..d.p() {
            let col = d.x.col(j);
            let s: f64 = col.iter().sum();
            assert!(s.abs() < 1e-10);
        }
    }
}
