//! The paper's synthetic benchmark generator (§7.1):
//!
//! `y = Xβ + 0.01ε`, `ε ~ N(0, Id_n)`; `X ∈ R^{n×p}` multivariate normal
//! with `corr(X_i, X_j) = ρ^{|i−j|}`; `p` broken into groups of equal size;
//! `γ₁` groups active; within each, `γ₂` coordinates set to
//! `sign(ξ)·U`, `U ~ Unif[0.5, 10]`, `ξ ~ Unif[−1, 1]`.
//!
//! The AR(1) correlation structure is sampled exactly by the recursion
//! `X_{·,0} = ε₀`, `X_{·,j} = ρ X_{·,j−1} + sqrt(1−ρ²) ε_j`, which gives a
//! stationary unit-variance process with `corr = ρ^{|i−j|}` — no `p × p`
//! Cholesky factor needed.

use super::Dataset;
use crate::linalg::Matrix;
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;

/// Configuration mirroring §7.1 (defaults: the Fig. 2 setting).
#[derive(Clone, Debug)]
pub struct SyntheticConfig {
    pub n: usize,
    pub n_groups: usize,
    pub group_size: usize,
    /// AR(1) feature correlation `ρ`.
    pub rho: f64,
    /// Number of active groups `γ₁`.
    pub gamma1: usize,
    /// Active coordinates per active group `γ₂`.
    pub gamma2: usize,
    /// Noise scale (paper: 0.01).
    pub noise: f64,
    pub seed: u64,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        // Paper: n=100, p=10000 in 1000 groups of 10, rho=0.5,
        // gamma1=10, gamma2=4.
        SyntheticConfig {
            n: 100,
            n_groups: 1000,
            group_size: 10,
            rho: 0.5,
            gamma1: 10,
            gamma2: 4,
            noise: 0.01,
            seed: 42,
        }
    }
}

impl SyntheticConfig {
    /// A scaled-down variant for unit/integration tests and the XLA
    /// artifact's default shape (n=100, p=1000).
    pub fn small(seed: u64) -> Self {
        SyntheticConfig {
            n: 100,
            n_groups: 100,
            group_size: 10,
            gamma1: 5,
            gamma2: 4,
            seed,
            ..Default::default()
        }
    }

    pub fn p(&self) -> usize {
        self.n_groups * self.group_size
    }
}

/// Generated dataset plus its planted ground truth.
#[derive(Clone, Debug)]
pub struct SyntheticData {
    pub dataset: Dataset,
    pub beta_true: Vec<f64>,
    pub active_groups_true: Vec<usize>,
}

/// Generate the §7.1 dataset.
pub fn generate(cfg: &SyntheticConfig) -> SyntheticData {
    assert!(cfg.gamma1 <= cfg.n_groups, "gamma1 > number of groups");
    assert!(cfg.gamma2 <= cfg.group_size, "gamma2 > group size");
    assert!((0.0..1.0).contains(&cfg.rho), "rho must be in [0,1)");
    let p = cfg.p();
    let mut rng = Pcg::new(cfg.seed, 0xDA7A);

    // AR(1) design, column by column.
    let mut x = Matrix::zeros(cfg.n, p);
    let innov_scale = (1.0 - cfg.rho * cfg.rho).sqrt();
    for i in 0..cfg.n {
        let mut prev = rng.normal();
        x.set(i, 0, prev);
        for j in 1..p {
            let v = cfg.rho * prev + innov_scale * rng.normal();
            x.set(i, j, v);
            prev = v;
        }
    }

    // Planted group-sparse coefficients.
    let groups = Groups::uniform(cfg.n_groups, cfg.group_size);
    let active_groups = rng.sample_indices(cfg.n_groups, cfg.gamma1);
    let mut beta_true = vec![0.0; p];
    for &g in &active_groups {
        let (a, _) = groups.bounds(g);
        let coords = rng.sample_indices(cfg.group_size, cfg.gamma2);
        for &k in &coords {
            let u = rng.uniform_in(0.5, 10.0);
            beta_true[a + k] = rng.sign() * u;
        }
    }

    // y = X beta + noise * eps.
    let mut y = x.matvec(&beta_true);
    for v in y.iter_mut() {
        *v += cfg.noise * rng.normal();
    }

    SyntheticData {
        dataset: Dataset { name: format!("synthetic(n={},p={})", cfg.n, p), x, y, groups },
        beta_true,
        active_groups_true: active_groups,
    }
}

/// Generated multi-response dataset plus its planted ground truth.
///
/// `dataset.y` holds `n · tasks` entries **task-major** (task `t` owns
/// `y[t·n .. (t+1)·n]`), matching the solver's multi-task state layout;
/// `beta_true` is **feature-major** `p · tasks` (feature `j`'s row is
/// `beta_true[j·q .. (j+1)·q]`), matching the coefficient layout.
#[derive(Clone, Debug)]
pub struct MultiTaskSyntheticData {
    pub dataset: Dataset,
    pub tasks: usize,
    pub beta_true: Vec<f64>,
    /// Planted active groups per task.
    pub active_groups_true: Vec<Vec<usize>>,
}

/// Generate the §7.1 design with `tasks` independent planted responses:
/// one shared `X`, per-task group-sparse coefficients drawn from the same
/// distribution on separate deterministic streams, `y_t = X β_t + noise·ε`.
///
/// Task 0 is produced by [`generate`] itself, so at `tasks = 1` the
/// dataset (`X`, `y`, groups) is bit-identical to the scalar generator's —
/// the loader-level leg of the q = 1 equivalence guarantee.
pub fn generate_multitask(cfg: &SyntheticConfig, tasks: usize) -> MultiTaskSyntheticData {
    assert!(tasks >= 1, "need at least one response column");
    let base = generate(cfg);
    let p = cfg.p();
    let groups = base.dataset.groups.clone();
    let x = base.dataset.x;
    let mut y = base.dataset.y;
    y.reserve_exact(cfg.n * (tasks - 1));
    let mut beta_true = vec![0.0; p * tasks];
    for (j, &b) in base.beta_true.iter().enumerate() {
        beta_true[j * tasks] = b;
    }
    let mut active_groups_true = vec![base.active_groups_true];

    for t in 1..tasks {
        // A fresh stream per task: same planting distribution, different
        // draws — and independent of the design stream, so widening q
        // never perturbs X or the earlier tasks.
        let mut rng = Pcg::new(cfg.seed, 0xDA7A_0000 + t as u64);
        let active_groups = rng.sample_indices(cfg.n_groups, cfg.gamma1);
        let mut beta_t = vec![0.0; p];
        for &g in &active_groups {
            let (a, _) = groups.bounds(g);
            let coords = rng.sample_indices(cfg.group_size, cfg.gamma2);
            for &k in &coords {
                let u = rng.uniform_in(0.5, 10.0);
                beta_t[a + k] = rng.sign() * u;
            }
        }
        let mut y_t = x.matvec(&beta_t);
        for v in y_t.iter_mut() {
            *v += cfg.noise * rng.normal();
        }
        y.extend_from_slice(&y_t);
        for (j, &b) in beta_t.iter().enumerate() {
            beta_true[j * tasks + t] = b;
        }
        active_groups_true.push(active_groups);
    }

    MultiTaskSyntheticData {
        dataset: Dataset {
            name: format!("synthetic-mt(n={},p={},q={tasks})", cfg.n, p),
            x,
            y,
            groups,
        },
        tasks,
        beta_true,
        active_groups_true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_config() {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 8,
            group_size: 5,
            gamma1: 3,
            gamma2: 2,
            ..Default::default()
        };
        let d = generate(&cfg);
        assert_eq!(d.dataset.n(), 30);
        assert_eq!(d.dataset.p(), 40);
        assert_eq!(d.dataset.groups.n_groups(), 8);
        assert_eq!(d.active_groups_true.len(), 3);
        // exactly gamma1*gamma2 nonzeros
        let nnz = d.beta_true.iter().filter(|&&b| b != 0.0).count();
        assert_eq!(nnz, 6);
    }

    #[test]
    fn multitask_q1_is_bitwise_the_scalar_dataset() {
        let cfg = SyntheticConfig::small(7);
        let scalar = generate(&cfg);
        let mt = generate_multitask(&cfg, 1);
        assert_eq!(mt.tasks, 1);
        assert_eq!(mt.dataset.x.as_slice(), scalar.dataset.x.as_slice());
        assert_eq!(mt.dataset.y, scalar.dataset.y);
        assert_eq!(mt.beta_true, scalar.beta_true);
        assert_eq!(mt.active_groups_true[0], scalar.active_groups_true);
    }

    #[test]
    fn multitask_widens_without_perturbing_earlier_tasks() {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 8,
            group_size: 5,
            gamma1: 3,
            gamma2: 2,
            seed: 4,
            ..Default::default()
        };
        let scalar = generate(&cfg);
        let q = 3;
        let mt = generate_multitask(&cfg, q);
        let (n, p) = (cfg.n, cfg.p());
        assert_eq!(mt.dataset.y.len(), n * q);
        assert_eq!(mt.beta_true.len(), p * q);
        // Task 0 is the scalar dataset verbatim (X shared, y prefix).
        assert_eq!(mt.dataset.x.as_slice(), scalar.dataset.x.as_slice());
        assert_eq!(&mt.dataset.y[..n], &scalar.dataset.y[..]);
        for j in 0..p {
            assert_eq!(mt.beta_true[j * q], scalar.beta_true[j]);
        }
        // Every task plants gamma1 * gamma2 nonzeros, and the tasks
        // differ (independent streams).
        for t in 0..q {
            let nnz = (0..p).filter(|&j| mt.beta_true[j * q + t] != 0.0).count();
            assert_eq!(nnz, cfg.gamma1 * cfg.gamma2, "task {t}");
        }
        assert_ne!(&mt.dataset.y[..n], &mt.dataset.y[n..2 * n]);
        // Deterministic given the seed.
        let again = generate_multitask(&cfg, q);
        assert_eq!(again.dataset.y, mt.dataset.y);
        assert_eq!(again.beta_true, mt.beta_true);
    }

    #[test]
    fn planted_magnitudes_in_range() {
        let d = generate(&SyntheticConfig::small(3));
        for &b in d.beta_true.iter().filter(|&&b| b != 0.0) {
            assert!((0.5..=10.0).contains(&b.abs()));
        }
    }

    #[test]
    fn ar1_correlation_structure() {
        // Adjacent-column empirical correlation ~ rho; distance-5 ~ rho^5.
        let cfg = SyntheticConfig {
            n: 4000,
            n_groups: 4,
            group_size: 5,
            rho: 0.5,
            gamma1: 1,
            gamma2: 1,
            seed: 9,
            ..Default::default()
        };
        let d = generate(&cfg);
        let corr = |a: &[f64], b: &[f64]| {
            let n = a.len() as f64;
            let ma = a.iter().sum::<f64>() / n;
            let mb = b.iter().sum::<f64>() / n;
            let mut num = 0.0;
            let mut va = 0.0;
            let mut vb = 0.0;
            for (x, y) in a.iter().zip(b) {
                num += (x - ma) * (y - mb);
                va += (x - ma) * (x - ma);
                vb += (y - mb) * (y - mb);
            }
            num / (va.sqrt() * vb.sqrt())
        };
        let c1 = corr(d.dataset.x.col(3), d.dataset.x.col(4));
        assert!((c1 - 0.5).abs() < 0.05, "lag-1 corr {c1}");
        let c5 = corr(d.dataset.x.col(3), d.dataset.x.col(8));
        assert!((c5 - 0.5f64.powi(5)).abs() < 0.07, "lag-5 corr {c5}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&SyntheticConfig::small(5));
        let b = generate(&SyntheticConfig::small(5));
        assert_eq!(a.dataset.x.as_slice(), b.dataset.x.as_slice());
        assert_eq!(a.dataset.y, b.dataset.y);
        let c = generate(&SyntheticConfig::small(6));
        assert_ne!(a.dataset.y, c.dataset.y);
    }

    #[test]
    fn unit_marginal_variance() {
        let cfg = SyntheticConfig {
            n: 5000,
            n_groups: 2,
            group_size: 5,
            rho: 0.7,
            gamma1: 1,
            gamma2: 1,
            seed: 11,
            ..Default::default()
        };
        let d = generate(&cfg);
        for j in [0, 4, 9] {
            let col = d.dataset.x.col(j);
            let var = col.iter().map(|v| v * v).sum::<f64>() / col.len() as f64;
            assert!((var - 1.0).abs() < 0.08, "col {j} var {var}");
        }
    }
}
