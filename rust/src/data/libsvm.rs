//! libsvm/svmlight text loader — parses `label idx:val idx:val …` lines
//! straight into [`CscMatrix`] arrays, never materializing a dense
//! design. The ROADMAP's sparse-loader item: real bag-of-words datasets
//! reach the CLI and the solve service at `O(nnz)` memory — files are
//! streamed line by line through a buffered reader (the whole text is
//! never resident), so peak memory is the parsed entries, not the file.
//!
//! Format notes:
//! - one sample per line: a numeric label followed by `index:value`
//!   pairs with strictly increasing indices (the libsvm convention;
//!   violations are parse errors, never silent misreads);
//! - `#` starts a comment (whole-line or trailing); blank lines are
//!   skipped; `qid:…` ranking tags are ignored;
//! - indices are 1-based (standard); any explicit index `0` switches the
//!   whole file to 0-based;
//! - explicit zero values are dropped from the stored structure;
//! - the feature count is padded up to a multiple of `group_size` with
//!   all-zero tail columns so a uniform [`Groups`] partition always fits
//!   (zero columns have zero norms and are never selected).

use super::SparseDataset;
use crate::linalg::CscMatrix;
use crate::solver::groups::Groups;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::BufRead;
use std::path::Path;

/// Read a libsvm/svmlight file into a CSC-backed dataset with uniform
/// groups of `group_size` features. The file is streamed through a
/// buffered line reader — peak memory is `O(nnz)` (the parsed entries),
/// never the file size.
pub fn read_libsvm(path: &Path, group_size: usize) -> Result<SparseDataset> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("reading libsvm file {}", path.display()))?;
    let lines = std::io::BufReader::new(file).lines();
    let mut d = parse_libsvm_lines(lines, group_size)
        .with_context(|| format!("parsing {}", path.display()))?;
    d.name = format!("libsvm({})", path.display());
    Ok(d)
}

/// Parse libsvm/svmlight text already in memory. See the module docs for
/// format rules.
pub fn parse_libsvm(text: &str, group_size: usize) -> Result<SparseDataset> {
    parse_libsvm_lines(text.lines().map(Ok::<&str, std::io::Error>), group_size)
}

/// Streaming parser core: consumes lines one at a time (from
/// [`BufRead::lines`] or an in-memory split), reporting I/O and parse
/// errors with their 1-based line number.
pub fn parse_libsvm_lines<I, L>(lines: I, group_size: usize) -> Result<SparseDataset>
where
    I: IntoIterator<Item = std::io::Result<L>>,
    L: AsRef<str>,
{
    ensure!(group_size >= 1, "group size must be >= 1");
    let mut y: Vec<f64> = Vec::new();
    // Per-sample raw (index, value) entries, indices as written.
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_index = 0usize;
    let mut any_feature = false;
    let mut saw_zero = false;
    for (lineno, raw) in lines.into_iter().enumerate() {
        let raw = raw.with_context(|| format!("reading line {}", lineno + 1))?;
        let line = raw.as_ref().split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let label_tok = toks.next().expect("non-empty line has a first token");
        let label: f64 = label_tok
            .parse()
            .map_err(|_| anyhow!("line {}: bad label {label_tok:?}", lineno + 1))?;
        let mut feats: Vec<(usize, f64)> = Vec::new();
        let mut prev: Option<usize> = None;
        for tok in toks {
            if tok.starts_with("qid:") {
                continue; // ranking tag: irrelevant to regression
            }
            let Some((i, v)) = tok.split_once(':') else {
                bail!("line {}: expected index:value, got {tok:?}", lineno + 1);
            };
            let idx: usize = i
                .parse()
                .map_err(|_| anyhow!("line {}: bad feature index {i:?}", lineno + 1))?;
            let val: f64 = v
                .parse()
                .map_err(|_| anyhow!("line {}: bad feature value {v:?}", lineno + 1))?;
            if let Some(p) = prev {
                ensure!(
                    idx > p,
                    "line {}: feature indices must be strictly increasing ({p} then {idx})",
                    lineno + 1
                );
            }
            prev = Some(idx);
            any_feature = true;
            saw_zero |= idx == 0;
            max_index = max_index.max(idx);
            if val != 0.0 {
                feats.push((idx, val));
            }
        }
        y.push(label);
        rows.push(feats);
    }
    ensure!(!y.is_empty(), "no samples found");
    ensure!(any_feature, "no feature entries found");

    // 1-based unless the file proves otherwise with an explicit index 0.
    let offset = usize::from(!saw_zero);
    let n_feats = max_index + 1 - offset;
    ensure!(n_feats >= 1, "no feature columns found");
    // Pad p to a multiple of the group size with all-zero tail columns.
    let p = n_feats.div_ceil(group_size) * group_size;
    let n = y.len();

    // Counting sort into CSC: per-column counts, prefix-sum, then fill in
    // sample order — so row indices are strictly increasing within every
    // column (each sample contributes at most one entry per column).
    let mut counts = vec![0usize; p];
    for r in &rows {
        for &(idx, _) in r {
            counts[idx - offset] += 1;
        }
    }
    let mut indptr = vec![0usize; p + 1];
    for j in 0..p {
        indptr[j + 1] = indptr[j] + counts[j];
    }
    let nnz = indptr[p];
    let mut indices = vec![0usize; nnz];
    let mut values = vec![0.0f64; nnz];
    let mut cursor = indptr.clone();
    for (i, r) in rows.iter().enumerate() {
        for &(idx, v) in r {
            let j = idx - offset;
            indices[cursor[j]] = i;
            values[cursor[j]] = v;
            cursor[j] += 1;
        }
    }
    let x = CscMatrix::from_raw(n, p, indptr, indices, values);
    let groups = Groups::uniform(p / group_size, group_size);
    Ok(SparseDataset { name: "libsvm".into(), x, y, groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Design;

    #[test]
    fn parses_one_based_text_and_pads_to_group_size() {
        let text = "1 1:0.5 3:2.0\n-1 2:1.0\n";
        let d = parse_libsvm(text, 2).unwrap();
        assert_eq!(d.y, vec![1.0, -1.0]);
        // 3 features padded to 4 columns = 2 groups of 2.
        assert_eq!(d.x.n_rows(), 2);
        assert_eq!(d.x.n_cols(), 4);
        assert_eq!(d.groups.n_groups(), 2);
        assert_eq!(d.x.nnz(), 3);
        let dense = d.x.to_dense();
        assert_eq!(dense.get(0, 0), 0.5);
        assert_eq!(dense.get(0, 2), 2.0);
        assert_eq!(dense.get(1, 1), 1.0);
        assert_eq!(dense.get(0, 3), 0.0);
        assert_eq!(dense.get(1, 3), 0.0);
    }

    #[test]
    fn zero_index_switches_to_zero_based() {
        let d = parse_libsvm("0.5 0:1.0 2:3.0\n1.5 1:2.0\n", 3).unwrap();
        assert_eq!(d.x.n_cols(), 3);
        let dense = d.x.to_dense();
        assert_eq!(dense.get(0, 0), 1.0);
        assert_eq!(dense.get(0, 2), 3.0);
        assert_eq!(dense.get(1, 1), 2.0);
    }

    #[test]
    fn comments_blanks_qid_and_explicit_zeros() {
        let text = "# header comment\n\n2.0 qid:7 1:1.0 2:0.0 3:4.0  # trailing\n";
        let d = parse_libsvm(text, 1).unwrap();
        assert_eq!(d.y, vec![2.0]);
        assert_eq!(d.x.n_cols(), 3);
        // The explicit zero at 2 is dropped from storage.
        assert_eq!(d.x.nnz(), 2);
    }

    #[test]
    fn csc_columns_are_row_sorted() {
        let text = "1 1:1.0 2:2.0\n2 1:3.0\n3 2:4.0 3:5.0\n";
        let d = parse_libsvm(text, 1).unwrap();
        for j in 0..d.x.n_cols() {
            let (rows, _) = d.x.col(j);
            for w in rows.windows(2) {
                assert!(w[0] < w[1], "col {j}: {rows:?}");
            }
        }
        // Column 0 holds samples 0 and 1; column 1 samples 0 and 2.
        assert_eq!(d.x.col(0).0, &[0, 1]);
        assert_eq!(d.x.col(1).0, &[0, 2]);
        assert_eq!(d.x.col(2).0, &[2]);
    }

    #[test]
    fn loaded_problem_solves_end_to_end() {
        // A tiny regression y ≈ x_1 - x_2 with sparse one-based rows.
        let text = "1.0 1:1.0\n-1.0 2:1.0\n0.0 1:1.0 2:1.0\n2.0 1:2.0\n";
        let d = parse_libsvm(text, 1).unwrap();
        let pb = crate::solver::problem::SglProblem::new(d.x, d.y, d.groups, 0.5);
        let res = crate::solver::cd::solve(
            &pb,
            0.1 * pb.lambda_max(),
            None,
            &crate::solver::cd::SolveOptions::default(),
        );
        assert!(res.converged);
    }

    #[test]
    fn malformed_inputs_are_errors() {
        assert!(parse_libsvm("", 1).is_err(), "empty file");
        assert!(parse_libsvm("# only comments\n", 1).is_err());
        assert!(parse_libsvm("abc 1:1.0\n", 1).is_err(), "bad label");
        assert!(parse_libsvm("1 5\n", 1).is_err(), "missing colon");
        assert!(parse_libsvm("1 x:1.0\n", 1).is_err(), "bad index");
        assert!(parse_libsvm("1 1:zz\n", 1).is_err(), "bad value");
        assert!(parse_libsvm("1 3:1.0 2:1.0\n", 1).is_err(), "decreasing indices");
        assert!(parse_libsvm("1 2:1.0 2:3.0\n", 1).is_err(), "duplicate index");
        assert!(parse_libsvm("1\n2\n", 1).is_err(), "labels but no features");
        assert!(parse_libsvm("1 1:1.0\n", 0).is_err(), "zero group size");
    }

    #[test]
    fn streaming_parser_reports_line_numbers_and_io_errors() {
        // An I/O failure mid-stream carries its 1-based line number.
        let lines: Vec<std::io::Result<String>> = vec![
            Ok("1 1:1.0".into()),
            Err(std::io::Error::other("disk gone")),
        ];
        let err = parse_libsvm_lines(lines, 1).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("line 2"), "{chain}");
        assert!(chain.contains("disk gone"), "{chain}");
        // Parse errors keep their line numbers through the streaming core.
        let err = parse_libsvm("1 1:1.0\n2 zz\n", 1).unwrap_err();
        assert!(format!("{err}").contains("line 2"));
        // The streaming and in-memory parsers agree.
        let text = "1 1:0.5 3:2.0\n-1 2:1.0\n";
        let a = parse_libsvm(text, 2).unwrap();
        let b = parse_libsvm_lines(
            text.lines().map(|l| Ok::<String, std::io::Error>(l.to_string())),
            2,
        )
        .unwrap();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn read_libsvm_reports_path_in_errors_and_name() {
        let dir = std::env::temp_dir();
        let path = dir.join("sgl_libsvm_test_input.txt");
        std::fs::write(&path, "1 1:1.0 2:-2.0\n-1 2:0.5\n").unwrap();
        let d = read_libsvm(&path, 2).unwrap();
        assert!(d.name.contains("sgl_libsvm_test_input.txt"));
        assert_eq!(d.n(), 2);
        assert_eq!(d.p(), 2);
        std::fs::remove_file(&path).ok();
        let missing = dir.join("sgl_libsvm_does_not_exist.txt");
        let err = read_libsvm(&missing, 1).unwrap_err();
        assert!(format!("{err:#}").contains("sgl_libsvm_does_not_exist"));
    }
}
