//! The Sparse-Group Lasso norm `Ω_{τ,w}` (paper Eq. 10), its dual norm
//! (Eq. 20/23 via the ε-norm), and the dual-ball characterization (Eq. 21).

use super::epsilon::{epsilon_dual_norm, lambda};
use super::prox::soft_threshold_vec;
use crate::linalg::ops::{l1_norm, l2_norm};
use crate::linalg::simd;
use crate::solver::groups::Groups;

/// `ε_g = (1−τ) w_g / (τ + (1−τ) w_g)` — paper Eq. (18).
#[inline]
pub fn epsilon_g(tau: f64, w_g: f64) -> f64 {
    let denom = tau + (1.0 - tau) * w_g;
    debug_assert!(denom > 0.0, "tau=0 with w_g=0 is excluded (not a norm)");
    (1.0 - tau) * w_g / denom
}

/// The SGL norm `Ω_{τ,w}(β) = τ‖β‖₁ + (1−τ) Σ_g w_g ‖β_g‖` (Eq. 10).
pub fn omega(beta: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    debug_assert_eq!(beta.len(), groups.p());
    debug_assert_eq!(w.len(), groups.n_groups());
    let mut group_part = 0.0;
    for (g, a, b) in groups.iter() {
        // Policy-dispatched: the scalar branch is the original unrolled dot.
        group_part += w[g] * simd::l2_norm(&beta[a..b]);
    }
    tau * l1_norm(beta) + (1.0 - tau) * group_part
}

/// `Ω` via the ε-dual-norm identity (Eq. 19) — used in tests to cross-check
/// `omega`.
pub fn omega_via_epsilon(beta: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    let mut total = 0.0;
    for (g, a, b) in groups.iter() {
        let scale = tau + (1.0 - tau) * w[g];
        if scale == 0.0 {
            continue;
        }
        let eps = epsilon_g(tau, w[g]);
        total += scale * epsilon_dual_norm(&beta[a..b], eps);
    }
    total
}

/// The dual norm `Ω^D_{τ,w}(ξ) = max_g ‖ξ_g‖_{ε_g} / (τ + (1−τ)w_g)`
/// (Eq. 20), evaluated per group with Algorithm 1 (Eq. 23).
pub fn omega_dual(xi: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    debug_assert_eq!(xi.len(), groups.p());
    let mut best = 0.0_f64;
    for (g, a, b) in groups.iter() {
        best = best.max(omega_dual_group(&xi[a..b], tau, w[g]));
    }
    best
}

/// Single-group contribution `‖ξ_g‖_{ε_g} / (τ + (1−τ)w_g)`.
#[inline]
pub fn omega_dual_group(xi_g: &[f64], tau: f64, w_g: f64) -> f64 {
    let scale = tau + (1.0 - tau) * w_g;
    debug_assert!(scale > 0.0);
    let eps = epsilon_g(tau, w_g);
    // ||xi_g||_{eps} = Lambda(xi_g, 1-eps, eps)
    lambda(xi_g, 1.0 - eps, eps) / scale
}

/// Argmax group of the dual norm (needed by the DST3 rule, App. C) together
/// with the attained value.
pub fn omega_dual_argmax(xi: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> (usize, f64) {
    let mut best = (0, f64::NEG_INFINITY);
    for (g, a, b) in groups.iter() {
        let v = omega_dual_group(&xi[a..b], tau, w[g]);
        if v > best.1 {
            best = (g, v);
        }
    }
    best
}

/// Membership test for the dual unit ball via the geometric
/// characterization (Eq. 21): `∀g, ‖S_τ(ξ_g)‖ ≤ (1−τ) w_g` (within `tol`).
///
/// This is an `O(p)` feasibility check — much cheaper than evaluating the
/// dual norm — and is the paper's "easier way to characterize a dual
/// feasible point".
pub fn in_dual_unit_ball(xi: &[f64], groups: &Groups, tau: f64, w: &[f64], tol: f64) -> bool {
    for (g, a, b) in groups.iter() {
        let st = soft_threshold_vec(&xi[a..b], tau);
        if simd::l2_norm(&st) > (1.0 - tau) * w[g] + tol {
            return false;
        }
    }
    true
}

/// Naive `O(n_g²)` dual-norm evaluation per group (direct scan over all
/// candidate active-set sizes without pruning or incremental sums). This is
/// the baseline that Algorithm 1 improves on; kept for the complexity
/// benchmark (`benches/bench_dual_norm.rs`) and as another oracle in tests.
pub fn omega_dual_naive(xi: &[f64], groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    let mut best = 0.0_f64;
    for (g, a, b) in groups.iter() {
        let scale = tau + (1.0 - tau) * w[g];
        let eps = epsilon_g(tau, w[g]);
        best = best.max(epsilon_norm_naive(&xi[a..b], eps) / scale);
    }
    best
}

/// Quadratic-time ε-norm: for each candidate active count k, recompute the
/// sums from scratch and test the root against the interval.
pub fn epsilon_norm_naive(x: &[f64], eps: f64) -> f64 {
    let alpha = 1.0 - eps;
    let r = eps;
    let mut abs: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    abs.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let norm_inf = abs.first().copied().unwrap_or(0.0);
    if norm_inf == 0.0 {
        return 0.0;
    }
    if alpha == 0.0 {
        return l2_norm(x) / r;
    }
    if r == 0.0 {
        return norm_inf / alpha;
    }
    let d = abs.len();
    for k in 1..=d {
        // O(k) recomputation each time => O(d^2) total.
        let s: f64 = abs[..k].iter().sum();
        let s2: f64 = abs[..k].iter().map(|v| v * v).sum();
        let denom = alpha * alpha * (k as f64) - r * r;
        let nu = if denom.abs() <= 1e-14 {
            s2 / (2.0 * alpha * s)
        } else {
            let disc = (alpha * alpha * s * s - s2 * denom).max(0.0);
            (alpha * s - disc.sqrt()) / denom
        };
        // Check interval (x_(k+1)/alpha, x_(k)/alpha].
        let hi = abs[k - 1] / alpha;
        let lo = if k < d { abs[k] / alpha } else { 0.0 };
        if nu > lo - 1e-12 * hi.max(1.0) && nu <= hi + 1e-12 * hi.max(1.0) && nu > 0.0 {
            return nu;
        }
    }
    // Fall back (should not happen): all coordinates active.
    let s: f64 = abs.iter().sum();
    let s2: f64 = abs.iter().map(|v| v * v).sum();
    let denom = alpha * alpha * (d as f64) - r * r;
    let disc = (alpha * alpha * s * s - s2 * denom).max(0.0);
    (alpha * s - disc.sqrt()) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::norms::epsilon::epsilon_norm;
    use crate::util::proptest::{check, check_close, forall};
    use crate::util::rng::Pcg;

    fn toy_groups() -> (Groups, Vec<f64>) {
        let g = Groups::from_sizes(&[2, 3, 1]);
        let w = g.sqrt_size_weights();
        (g, w)
    }

    #[test]
    fn omega_lasso_and_group_lasso_limits() {
        let (g, w) = toy_groups();
        let beta = [1.0, -2.0, 0.0, 3.0, -1.0, 0.5];
        // tau = 1: pure l1.
        assert!((omega(&beta, &g, 1.0, &w) - l1_norm(&beta)).abs() < 1e-12);
        // tau = 0: pure weighted group norm.
        let gl: f64 = w[0] * l2_norm(&beta[0..2]) + w[1] * l2_norm(&beta[2..5]) + w[2] * 0.5;
        assert!((omega(&beta, &g, 0.0, &w) - gl).abs() < 1e-12);
    }

    #[test]
    fn omega_matches_epsilon_identity() {
        forall("omega = sum of eps dual norms (Eq 19)", 100, |gen| {
            let sizes = [2usize, 3, 4, 1];
            let g = Groups::from_sizes(&sizes);
            let w = g.sqrt_size_weights();
            let tau = gen.f64_in(0.01..0.99);
            let beta: Vec<f64> = (0..g.p()).map(|_| gen.normal()).collect();
            check_close(
                omega(&beta, &g, tau, &w),
                omega_via_epsilon(&beta, &g, tau, &w),
                1e-9,
                "Eq 19",
            )
        });
    }

    #[test]
    fn dual_norm_duality_holds() {
        // <beta, xi> <= Omega(beta) * Omega^D(xi), with near-tightness over
        // random search directions.
        forall("generalized Cauchy-Schwarz", 150, |gen| {
            let g = Groups::from_sizes(&[3, 2, 4]);
            let w = g.sqrt_size_weights();
            let tau = gen.f64_in(0.0..1.0);
            let beta: Vec<f64> = (0..g.p()).map(|_| gen.normal()).collect();
            let xi: Vec<f64> = (0..g.p()).map(|_| gen.normal()).collect();
            let ip: f64 = beta.iter().zip(&xi).map(|(a, b)| a * b).sum();
            let bound = omega(&beta, &g, tau, &w) * omega_dual(&xi, &g, tau, &w);
            check(ip.abs() <= bound * (1.0 + 1e-9) + 1e-12, &format!("{ip} vs {bound}"))
        });
    }

    #[test]
    fn dual_ball_characterization_matches_dual_norm() {
        // Eq (21) <=> Omega^D(xi) <= 1 (Eq 20).
        forall("dual ball Eq 21 <=> Eq 20", 300, |gen| {
            let g = Groups::from_sizes(&[2, 3]);
            let w = g.sqrt_size_weights();
            let tau = gen.f64_in(0.0..1.0);
            let xi: Vec<f64> = (0..g.p()).map(|_| gen.normal() * 1.2).collect();
            let dn = omega_dual(&xi, &g, tau, &w);
            let inside_ball = in_dual_unit_ball(&xi, &g, tau, &w, 1e-10);
            // Skip knife-edge cases where the two tests can disagree by
            // floating-point tolerance.
            if (dn - 1.0).abs() < 1e-6 {
                return Ok(());
            }
            check(inside_ball == (dn <= 1.0), &format!("dn={dn} inside={inside_ball}"))
        });
    }

    #[test]
    fn dual_norm_scaling_normalizes() {
        // xi / Omega^D(xi) lies on the dual unit sphere.
        let (g, w) = toy_groups();
        let mut rng = Pcg::seeded(3);
        for _ in 0..20 {
            let xi: Vec<f64> = (0..g.p()).map(|_| rng.normal()).collect();
            let tau = rng.uniform();
            let dn = omega_dual(&xi, &g, tau, &w);
            if dn == 0.0 {
                continue;
            }
            let scaled: Vec<f64> = xi.iter().map(|v| v / dn).collect();
            let dn2 = omega_dual(&scaled, &g, tau, &w);
            assert!((dn2 - 1.0).abs() < 1e-9, "dn2={dn2}");
        }
    }

    #[test]
    fn naive_matches_fast() {
        forall("naive dual norm == Algorithm 1", 150, |gen| {
            let g = Groups::from_sizes(&[4, 2, 6]);
            let w = g.sqrt_size_weights();
            let tau = gen.f64_in(0.01..0.99);
            let xi: Vec<f64> = (0..g.p()).map(|_| gen.normal()).collect();
            check_close(
                omega_dual(&xi, &g, tau, &w),
                omega_dual_naive(&xi, &g, tau, &w),
                1e-8,
                "naive vs fast",
            )
        });
    }

    #[test]
    fn epsilon_norm_naive_matches_fast() {
        forall("naive eps norm", 150, |gen| {
            let x = gen.vec_normal(1..30);
            let eps = gen.f64_in(0.01..0.99);
            check_close(epsilon_norm_naive(&x, eps), epsilon_norm(&x, eps), 1e-8, "eps norm")
        });
    }

    #[test]
    fn epsilon_g_limits() {
        assert_eq!(epsilon_g(1.0, 3.0), 0.0); // lasso: pure l1
        assert_eq!(epsilon_g(0.0, 3.0), 1.0); // group lasso: pure l2
        let e = epsilon_g(0.5, 1.0);
        assert!((e - 0.5).abs() < 1e-12);
    }
}
