//! The ε-norm of Burdakov (1988) and the paper's Algorithm 1.
//!
//! For `ε ∈ [0, 1]`, `‖x‖_ε` is the unique nonnegative root `ν` of
//!
//! ```text
//!   Σ_i ( |x_i| − (1−ε)ν )₊²  =  (εν)²          (paper Eq. 16)
//! ```
//!
//! interpolating between `‖x‖_∞` (ε = 0) and `‖x‖₂` (ε = 1). The paper's
//! key computational tool (Prop. 9 / Algorithm 1) evaluates the generalized
//! root `Λ(x, α, R)` of `Σ_i S_{να}(x_i)² = (νR)²` in `O(n_I log n_I)`
//! after pruning to the `n_I` coordinates that can be active (Remark 9).
//! The Sparse-Group Lasso dual norm is a max of per-group `Λ`s (Eq. 23).

use crate::linalg::ops::{inf_norm, l1_norm, l2_norm};

/// Exact evaluation of `Λ(x, α, R)` — paper Algorithm 1.
///
/// Returns the unique `ν ≥ 0` with `Σ_i S_{να}(|x_i|)² = (νR)²`
/// (`+∞` in the degenerate case `α = R = 0` with `x ≠ 0`, by convention,
/// and `0` for `x = 0` with `R > 0`).
pub fn lambda(x: &[f64], alpha: f64, r: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&alpha), "alpha={alpha} outside [0,1]");
    debug_assert!(r >= 0.0);
    let norm_inf = inf_norm(x);
    if alpha == 0.0 && r == 0.0 {
        return if norm_inf == 0.0 { 0.0 } else { f64::INFINITY };
    }
    if norm_inf == 0.0 {
        return 0.0;
    }
    if alpha == 0.0 {
        return l2_norm(x) / r;
    }
    if r == 0.0 {
        return norm_inf / alpha;
    }
    // Remark 9 pruning: a coordinate with |x_i| <= alpha*||x||_inf/(alpha+R)
    // is below the solution's threshold nu*alpha and contributes nothing.
    let prune = alpha * norm_inf / (alpha + r);
    let mut kept: Vec<f64> = x.iter().map(|v| v.abs()).filter(|&v| v > prune).collect();
    // Sort descending.
    kept.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    lambda_sorted_desc(&kept, alpha, r)
}

/// `Λ` on an already |·|-valued, descending-sorted slice (no pruning).
/// Exposed for callers that maintain sorted buffers (hot path reuse).
pub fn lambda_sorted_desc(sorted_abs_desc: &[f64], alpha: f64, r: f64) -> f64 {
    let n = sorted_abs_desc.len();
    debug_assert!(n > 0 && alpha > 0.0 && r > 0.0);
    let ratio = (r * r) / (alpha * alpha);
    // Find j0 with b_{j0} <= R^2/alpha^2 < b_{j0+1}, where
    //   b_k = S2_{k-1}/x_(k)^2 - 2 S_{k-1}/x_(k) + (k-1)
    // is phi(x_(k)/alpha)/alpha^2 for phi(nu) = sum S_alpha(x_j/nu)^2.
    // b_1 = 0 <= ratio always; b_{n+1} = +inf (next value treated as 0).
    let (mut s, mut s2) = (0.0_f64, 0.0_f64);
    let mut j0 = n;
    for k in 1..=n {
        let xk = sorted_abs_desc[k - 1];
        s += xk;
        s2 += xk * xk;
        let b_next = if k < n {
            let xn = sorted_abs_desc[k];
            if xn == 0.0 {
                f64::INFINITY
            } else {
                s2 / (xn * xn) - 2.0 * s / xn + k as f64
            }
        } else {
            f64::INFINITY
        };
        if ratio < b_next {
            j0 = k;
            break;
        }
    }
    // Solve (alpha^2 j0 - R^2) nu^2 - 2 alpha S nu + S2 = 0 on R+, taking the
    // root in (x_(j0+1)/alpha, x_(j0)/alpha] (paper Eq. 33/36: always nu_1).
    let denom = alpha * alpha * (j0 as f64) - r * r;
    if denom.abs() <= 1e-14 * (r * r).max(1.0) {
        return s2 / (2.0 * alpha * s);
    }
    let disc = (alpha * alpha * s * s - s2 * denom).max(0.0);
    (alpha * s - disc.sqrt()) / denom
}

/// Reference implementation of `Λ` by bisection on
/// `phi(nu) = Σ S_{να}(x)² − (νR)²` (independent of Algorithm 1; used by
/// unit and property tests, and as the "naive" baseline in benches).
pub fn lambda_bisect(x: &[f64], alpha: f64, r: f64, tol: f64) -> f64 {
    let norm_inf = inf_norm(x);
    if alpha == 0.0 && r == 0.0 {
        return if norm_inf == 0.0 { 0.0 } else { f64::INFINITY };
    }
    if norm_inf == 0.0 {
        return 0.0;
    }
    if alpha == 0.0 {
        return l2_norm(x) / r;
    }
    if r == 0.0 {
        return norm_inf / alpha;
    }
    let f = |nu: f64| -> f64 {
        let mut lhs = 0.0;
        for &v in x {
            let t = v.abs() - nu * alpha;
            if t > 0.0 {
                lhs += t * t;
            }
        }
        lhs - (nu * r) * (nu * r)
    };
    // Solution lies in (0, ||x||_inf / alpha).
    let mut lo = 0.0;
    let mut hi = norm_inf / alpha;
    debug_assert!(f(hi) <= 0.0);
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if f(mid) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo <= tol * hi.max(1e-300) {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// The ε-norm `‖x‖_ε` (Eq. 16): `Λ(x, 1−ε, ε)`.
pub fn epsilon_norm(x: &[f64], eps: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&eps));
    lambda(x, 1.0 - eps, eps)
}

/// Dual of the ε-norm (Lemma 4): `ε‖x‖₂ + (1−ε)‖x‖₁`.
pub fn epsilon_dual_norm(x: &[f64], eps: f64) -> f64 {
    eps * l2_norm(x) + (1.0 - eps) * l1_norm(x)
}

/// The ε-decomposition `x = x^ε + x^{1−ε}` of Lemma 1:
/// `x^ε = S_{(1−ε)‖x‖_ε}(x)` with `‖x^ε‖ = ε‖x‖_ε` and
/// `‖x^{1−ε}‖_∞ = (1−ε)‖x‖_ε`. Returns `(x_eps, x_one_minus_eps)`.
pub fn epsilon_decomposition(x: &[f64], eps: f64) -> (Vec<f64>, Vec<f64>) {
    let nu = epsilon_norm(x, eps);
    let t = (1.0 - eps) * nu;
    let x_eps: Vec<f64> = x.iter().map(|&v| v.signum() * (v.abs() - t).max(0.0)).collect();
    let x_rest: Vec<f64> = x.iter().zip(&x_eps).map(|(v, e)| v - e).collect();
    (x_eps, x_rest)
}

/// (Sub)gradient of the ε-norm at `x != 0` (Lemma 5): `x^ε / ‖x^ε‖_ε^D`.
///
/// At `ε = 0` the ε-norm is `‖·‖_∞`, whose ε-part `x^ε` vanishes; we return
/// the standard `ℓ∞` subgradient `sign(x_{j*}) e_{j*}` instead (any
/// supporting-hyperplane normal is valid for the DST3 construction).
pub fn epsilon_norm_gradient(x: &[f64], eps: f64) -> Vec<f64> {
    assert!(x.iter().any(|&v| v != 0.0), "epsilon-norm gradient undefined at 0");
    let (x_eps, _) = epsilon_decomposition(x, eps);
    let d = epsilon_dual_norm(&x_eps, eps);
    if d <= 0.0 {
        // eps = 0 (pure sup-norm) or total tie degeneracy.
        let j_star = x
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        let mut g = vec![0.0; x.len()];
        g[j_star] = x[j_star].signum();
        return g;
    }
    x_eps.iter().map(|v| v / d).collect()
}

/// Number of coordinates surviving the Remark-9 pruning (exposed for the
/// complexity experiment in `benches/bench_dual_norm.rs`).
pub fn pruned_count(x: &[f64], alpha: f64, r: f64) -> usize {
    let norm_inf = inf_norm(x);
    if norm_inf == 0.0 || alpha + r == 0.0 {
        return 0;
    }
    let prune = alpha * norm_inf / (alpha + r);
    x.iter().filter(|v| v.abs() > prune).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, check_close, forall};

    /// Residual of the defining equation (17) at nu.
    fn defining_residual(x: &[f64], alpha: f64, r: f64, nu: f64) -> f64 {
        let lhs: f64 = x
            .iter()
            .map(|&v| {
                let t = v.abs() - nu * alpha;
                if t > 0.0 {
                    t * t
                } else {
                    0.0
                }
            })
            .sum();
        lhs - (nu * r) * (nu * r)
    }

    #[test]
    fn special_cases() {
        let x = [3.0, -4.0];
        assert_eq!(lambda(&x, 0.0, 2.0), 2.5); // ||x||/R
        assert_eq!(lambda(&x, 0.5, 0.0), 8.0); // ||x||_inf/alpha
        assert_eq!(lambda(&[0.0, 0.0], 0.3, 0.7), 0.0);
        assert!(lambda(&x, 0.0, 0.0).is_infinite());
        assert_eq!(lambda(&[0.0], 0.0, 0.0), 0.0);
    }

    #[test]
    fn epsilon_norm_interpolates() {
        let x = [1.0, -2.0, 3.0];
        assert!((epsilon_norm(&x, 0.0) - 3.0).abs() < 1e-12); // inf-norm
        assert!((epsilon_norm(&x, 1.0) - (14.0f64).sqrt()).abs() < 1e-12); // l2
        let mid = epsilon_norm(&x, 0.5);
        assert!(mid > 3.0 && mid < 2.0 * (14.0f64).sqrt());
    }

    #[test]
    fn single_active_coordinate_closed_form() {
        // x_(2) far below x_(1): nu = x_(1)/(alpha+R).
        let x = [10.0, 0.1, 0.05];
        let (alpha, r) = (0.6, 0.3);
        let nu = lambda(&x, alpha, r);
        assert!((nu - 10.0 / 0.9).abs() < 1e-10, "nu={nu}");
    }

    #[test]
    fn matches_bisection_reference() {
        let xs: Vec<Vec<f64>> = vec![
            vec![1.0, 2.0, 3.0, 4.0, 5.0],
            vec![5.0, 5.0, 5.0],
            vec![1.0, 1.0, 1.0, 10.0],
            vec![0.3, -0.2, 0.1, 0.9, -0.5, 0.0],
        ];
        for x in &xs {
            for &alpha in &[0.1, 0.5, 0.9, 1.0] {
                for &r in &[0.05, 0.3, 1.0, 2.0] {
                    let fast = lambda(x, alpha, r);
                    let slow = lambda_bisect(x, alpha, r, 1e-13);
                    assert!(
                        (fast - slow).abs() < 1e-8 * fast.max(1.0),
                        "x={x:?} alpha={alpha} r={r}: {fast} vs {slow}"
                    );
                }
            }
        }
    }

    #[test]
    fn property_solves_defining_equation() {
        forall("lambda solves its equation", 300, |g| {
            let x = g.vec_f64(1..40, -10.0..10.0);
            if x.iter().all(|&v| v == 0.0) {
                return Ok(());
            }
            let alpha = g.f64_in(0.01..1.0);
            let r = g.f64_in(0.01..3.0);
            let nu = lambda(&x, alpha, r);
            check(nu.is_finite() && nu > 0.0, "nu positive finite")?;
            let res = defining_residual(&x, alpha, r, nu);
            let scale: f64 = x.iter().map(|v| v * v).sum();
            check(res.abs() <= 1e-9 * scale.max(1.0), &format!("residual {res:.3e}"))
        });
    }

    #[test]
    fn property_matches_bisection() {
        forall("lambda == bisection", 200, |g| {
            let x = g.vec_normal(1..60);
            if x.iter().all(|&v| v == 0.0) {
                return Ok(());
            }
            let alpha = g.f64_in(0.05..1.0);
            let r = g.f64_in(0.05..2.0);
            check_close(lambda(&x, alpha, r), lambda_bisect(&x, alpha, r, 1e-13), 1e-7, "Λ")
        });
    }

    #[test]
    fn property_duality_inequality() {
        // |<x,y>| <= ||x||_eps * ||y||_eps^D (generalized Cauchy-Schwarz)
        forall("epsilon-norm duality", 200, |g| {
            let n = g.usize_in(1..30);
            let x: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
            let eps = g.f64_in(0.01..1.0);
            let ip: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            let bound = epsilon_norm(&x, eps) * epsilon_dual_norm(&y, eps);
            check(ip.abs() <= bound * (1.0 + 1e-9) + 1e-12, &format!("{ip} vs {bound}"))
        });
    }

    #[test]
    fn decomposition_lemma1() {
        forall("epsilon decomposition", 150, |g| {
            let x = g.vec_normal(1..25);
            if inf_norm(&x) == 0.0 {
                return Ok(());
            }
            let eps = g.f64_in(0.05..0.95);
            let nu = epsilon_norm(&x, eps);
            let (xe, xr) = epsilon_decomposition(&x, eps);
            check_close(l2_norm(&xe), eps * nu, 1e-8, "||x^eps|| = eps*nu")?;
            check_close(inf_norm(&xr), (1.0 - eps) * nu, 1e-8, "||x^{1-eps}||_inf")?;
            for i in 0..x.len() {
                check_close(xe[i] + xr[i], x[i], 1e-10, "decomposition sums")?;
            }
            Ok(())
        });
    }

    #[test]
    fn gradient_lemma5_is_unit_dual_norm() {
        // The gradient of a norm has dual norm 1 and <grad, x> = ||x||_eps.
        forall("epsilon-norm gradient", 100, |g| {
            let x = g.vec_normal(2..20);
            if inf_norm(&x) == 0.0 {
                return Ok(());
            }
            let eps = g.f64_in(0.1..0.9);
            let grad = epsilon_norm_gradient(&x, eps);
            let ip: f64 = grad.iter().zip(&x).map(|(a, b)| a * b).sum();
            check_close(ip, epsilon_norm(&x, eps), 1e-7, "<grad,x> = ||x||_eps")
        });
    }

    #[test]
    fn pruning_counts() {
        let x = [10.0, 0.01, 0.02, 9.5];
        // prune threshold = 0.9*10/(0.9+0.1) = 9.0: keeps 10.0 and 9.5.
        let n_i = pruned_count(&x, 0.9, 0.1);
        assert_eq!(n_i, 2);
        assert_eq!(pruned_count(&[0.0; 4], 0.5, 0.5), 0);
    }

    #[test]
    fn homogeneity() {
        forall("positive homogeneity", 100, |g| {
            let x = g.vec_normal(1..20);
            let eps = g.f64_in(0.05..0.95);
            let c = g.f64_in(0.1..10.0);
            let cx: Vec<f64> = x.iter().map(|v| c * v).collect();
            check_close(epsilon_norm(&cx, eps), c * epsilon_norm(&x, eps), 1e-8, "homog")
        });
    }
}
