//! Norms and proximal operators for the Sparse-Group Lasso:
//!
//! - [`epsilon`] — the ε-norm of Burdakov (1988) and the paper's
//!   Algorithm 1 for `Λ(x, α, R)` (Prop. 9);
//! - [`sgl`] — `Ω_{τ,w}`, its dual (Eq. 20/23), and the dual-ball
//!   characterization (Eq. 21);
//! - [`prox`] — soft-thresholding, group soft-thresholding, and the fused
//!   two-level SGL prox (§6);
//! - [`block`] — row-norm (ℓ2,1-style) generalizations of the above for
//!   multi-task problems where each feature carries a row of `q` task
//!   coefficients (arXiv 1506.03736): block row norms, the multi-task
//!   `Ω`/`Ω^D` over row norms, and the row-block SGL prox. Every entry
//!   point degenerates to its scalar counterpart bit-for-bit at `q = 1`.

pub mod block;
pub mod epsilon;
pub mod prox;
pub mod sgl;
