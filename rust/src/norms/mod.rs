//! Norms and proximal operators for the Sparse-Group Lasso:
//!
//! - [`epsilon`] — the ε-norm of Burdakov (1988) and the paper's
//!   Algorithm 1 for `Λ(x, α, R)` (Prop. 9);
//! - [`sgl`] — `Ω_{τ,w}`, its dual (Eq. 20/23), and the dual-ball
//!   characterization (Eq. 21);
//! - [`prox`] — soft-thresholding, group soft-thresholding, and the fused
//!   two-level SGL prox (§6).

pub mod epsilon;
pub mod prox;
pub mod sgl;
