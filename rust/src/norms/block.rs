//! Row-block (multi-task) generalizations of the SGL norm machinery
//! (arXiv 1506.03736).
//!
//! In the multi-task problem each feature `j` carries a *row* of `q` task
//! coefficients, stored feature-major: row `j` occupies `x[j·q .. (j+1)·q]`.
//! The penalty replaces `|β_j|` with the row norm `‖B_j‖₂` and `‖β_g‖₂`
//! with the Frobenius norm of the group's row block, so
//!
//! ```text
//!   Ω(B) = τ Σ_j ‖B_j‖₂ + (1−τ) Σ_g w_g ‖B_g‖_F
//! ```
//!
//! and its dual norm is the scalar `Ω^D` evaluated on the p-vector of row
//! norms (the ε-norm machinery applies unchanged because it only sees the
//! non-negative score per feature).
//!
//! Every function here degenerates to its scalar `norms::{sgl, prox}`
//! counterpart **bit-for-bit** at `q = 1`: the `q == 1` branches call the
//! scalar code paths directly rather than re-deriving them through
//! `sqrt(x²)`, which is not the bitwise identity on `|x|`.

use super::prox::{group_soft_threshold_inplace, sgl_prox_inplace, soft_threshold_vec};
use super::sgl;
use crate::linalg::simd;
use crate::solver::groups::Groups;

/// ℓ2 norms of the `p` feature rows of a feature-major `p × q` matrix,
/// written into `out` (length `p`). At `q = 1` this is `|x_j|` bit-for-bit.
pub fn row_norms_into(x: &[f64], q: usize, out: &mut [f64]) {
    assert!(q >= 1, "row_norms_into needs at least one task");
    assert_eq!(x.len(), out.len() * q, "feature-major layout mismatch");
    if q == 1 {
        for (o, v) in out.iter_mut().zip(x) {
            *o = v.abs();
        }
        return;
    }
    for (o, row) in out.iter_mut().zip(x.chunks_exact(q)) {
        *o = simd::l2_norm(row);
    }
}

/// Allocating convenience wrapper around [`row_norms_into`].
pub fn row_norms(x: &[f64], q: usize) -> Vec<f64> {
    let mut out = vec![0.0; x.len() / q.max(1)];
    row_norms_into(x, q, &mut out);
    out
}

/// The multi-task SGL norm `Ω(B) = τ Σ_j ‖B_j‖ + (1−τ) Σ_g w_g ‖B_g‖_F`
/// over a feature-major `p × q` matrix. Delegates to the scalar
/// [`sgl::omega`] at `q = 1`.
pub fn omega_rows(x: &[f64], q: usize, groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    if q == 1 {
        return sgl::omega(x, groups, tau, w);
    }
    debug_assert_eq!(x.len(), groups.p() * q);
    debug_assert_eq!(w.len(), groups.n_groups());
    let mut row_part = 0.0;
    for row in x.chunks_exact(q) {
        row_part += simd::l2_norm(row);
    }
    let mut group_part = 0.0;
    for (g, a, b) in groups.iter() {
        // Frobenius norm of the group's row block == flat l2 of the
        // contiguous feature-major slice.
        group_part += w[g] * simd::l2_norm(&x[a * q..b * q]);
    }
    tau * row_part + (1.0 - tau) * group_part
}

/// The multi-task dual norm: the scalar `Ω^D` (Eq. 20/23) evaluated on the
/// p-vector of row norms. Delegates to [`sgl::omega_dual`] at `q = 1`.
pub fn omega_dual_rows(xi: &[f64], q: usize, groups: &Groups, tau: f64, w: &[f64]) -> f64 {
    if q == 1 {
        return sgl::omega_dual(xi, groups, tau, w);
    }
    let scores = row_norms(xi, q);
    sgl::omega_dual(&scores, groups, tau, w)
}

/// Argmax-group variant of [`omega_dual_rows`] (DST3 geometry, App. C).
pub fn omega_dual_argmax_rows(
    xi: &[f64],
    q: usize,
    groups: &Groups,
    tau: f64,
    w: &[f64],
) -> (usize, f64) {
    if q == 1 {
        return sgl::omega_dual_argmax(xi, groups, tau, w);
    }
    let scores = row_norms(xi, q);
    sgl::omega_dual_argmax(&scores, groups, tau, w)
}

/// Row-wise ℓ2 soft-thresholding: every feature row is block-shrunk by `t`
/// (`(1 − t/‖B_j‖)₊ B_j`). At `q = 1` this is scalar soft-thresholding
/// bit-for-bit (via the scalar path, not `sqrt(x²)`).
pub fn row_soft_threshold_inplace(x: &mut [f64], q: usize, t: f64) {
    assert!(q >= 1, "row_soft_threshold_inplace needs at least one task");
    if q == 1 {
        super::prox::soft_threshold_inplace(x, t);
        return;
    }
    for row in x.chunks_exact_mut(q) {
        group_soft_threshold_inplace(row, t);
    }
}

/// The fused multi-task SGL block prox on a group's feature-major row
/// block: row-wise ℓ2 shrink by `a = τ α_g`, then a Frobenius block shrink
/// by `b = (1−τ) w_g α_g` — the exact prox of
/// `α_g (τ Σ_j ‖·_j‖ + (1−τ) w_g ‖·‖_F)` (the row/group norms nest just
/// like ℓ1/ℓ2 do in the scalar case, §6). Delegates to the scalar
/// [`sgl_prox_inplace`] at `q = 1`.
pub fn sgl_prox_rows_inplace(u: &mut [f64], q: usize, a: f64, b: f64) {
    if q == 1 {
        sgl_prox_inplace(u, a, b);
        return;
    }
    row_soft_threshold_inplace(u, q, a);
    group_soft_threshold_inplace(u, b);
}

/// Multi-task dual-ball membership (the row generalization of Eq. 21):
/// `∀g, ‖S^row_τ(ξ_g)‖_F ≤ (1−τ) w_g` where `S^row` is the row-wise ℓ2
/// shrink. Delegates to [`sgl::in_dual_unit_ball`] at `q = 1`.
pub fn in_dual_unit_ball_rows(
    xi: &[f64],
    q: usize,
    groups: &Groups,
    tau: f64,
    w: &[f64],
    tol: f64,
) -> bool {
    if q == 1 {
        return sgl::in_dual_unit_ball(xi, groups, tau, w, tol);
    }
    for (g, a, b) in groups.iter() {
        let mut block = xi[a * q..b * q].to_vec();
        row_soft_threshold_inplace(&mut block, q, tau);
        if simd::l2_norm(&block) > (1.0 - tau) * w[g] + tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::l2_norm;
    use crate::util::proptest::{check, check_close, forall};

    fn toy_groups() -> (Groups, Vec<f64>) {
        let g = Groups::from_sizes(&[2, 3, 1]);
        let w = g.sqrt_size_weights();
        (g, w)
    }

    #[test]
    fn q1_paths_are_bitwise_scalar() {
        forall("q=1 block norms == scalar norms bitwise", 100, |gen| {
            let (g, w) = toy_groups();
            let tau = gen.f64_in(0.01..0.99);
            let x: Vec<f64> = (0..g.p()).map(|_| gen.normal() * 2.0).collect();
            let rn = row_norms(&x, 1);
            for (r, v) in rn.iter().zip(&x) {
                check(r.to_bits() == v.abs().to_bits(), "row norm == |x| bitwise")?;
            }
            check(
                omega_rows(&x, 1, &g, tau, &w).to_bits() == sgl::omega(&x, &g, tau, &w).to_bits(),
                "omega bitwise",
            )?;
            check(
                omega_dual_rows(&x, 1, &g, tau, &w).to_bits()
                    == sgl::omega_dual(&x, &g, tau, &w).to_bits(),
                "omega_dual bitwise",
            )?;
            let (a, b) = (gen.f64_in(0.0..1.5), gen.f64_in(0.0..1.5));
            let mut u1 = x.clone();
            let mut u2 = x.clone();
            sgl_prox_rows_inplace(&mut u1, 1, a, b);
            sgl_prox_inplace(&mut u2, a, b);
            for (p1, p2) in u1.iter().zip(&u2) {
                check(p1.to_bits() == p2.to_bits(), "prox bitwise")?;
            }
            Ok(())
        });
    }

    #[test]
    fn row_norms_known_values() {
        // 2 features, q = 2: rows (3,4) and (0,-5).
        let x = [3.0, 4.0, 0.0, -5.0];
        assert_eq!(row_norms(&x, 2), vec![5.0, 5.0]);
    }

    #[test]
    fn row_prox_shrinks_rows_then_block() {
        // One group of 2 features, q = 2. Row norms 5 and 5.
        let mut u = [3.0, 4.0, 0.0, -5.0];
        // a = 2.5 shrinks each row by factor 0.5; b = 0 leaves the block.
        sgl_prox_rows_inplace(&mut u, 2, 2.5, 0.0);
        assert_eq!(u, [1.5, 2.0, 0.0, -2.5]);
        // A large b zeroes the whole block.
        sgl_prox_rows_inplace(&mut u, 2, 0.0, 100.0);
        assert_eq!(u, [0.0; 4]);
    }

    #[test]
    fn generalized_cauchy_schwarz_for_rows() {
        forall("<B, Xi> <= Omega(B) * Omega^D(Xi) for q > 1", 150, |gen| {
            let g = Groups::from_sizes(&[3, 2, 4]);
            let w = g.sqrt_size_weights();
            let q = gen.usize_in(2..5);
            let tau = gen.f64_in(0.0..1.0);
            let b: Vec<f64> = (0..g.p() * q).map(|_| gen.normal()).collect();
            let xi: Vec<f64> = (0..g.p() * q).map(|_| gen.normal()).collect();
            let ip: f64 = b.iter().zip(&xi).map(|(u, v)| u * v).sum();
            let bound = omega_rows(&b, q, &g, tau, &w) * omega_dual_rows(&xi, q, &g, tau, &w);
            check(ip.abs() <= bound * (1.0 + 1e-9) + 1e-12, &format!("{ip} vs {bound}"))
        });
    }

    #[test]
    fn dual_ball_rows_matches_dual_norm() {
        forall("row dual ball <=> Omega^D_rows <= 1", 200, |gen| {
            let g = Groups::from_sizes(&[2, 3]);
            let w = g.sqrt_size_weights();
            let q = gen.usize_in(2..4);
            let tau = gen.f64_in(0.0..1.0);
            let xi: Vec<f64> = (0..g.p() * q).map(|_| gen.normal() * 0.9).collect();
            let dn = omega_dual_rows(&xi, q, &g, tau, &w);
            if (dn - 1.0).abs() < 1e-6 {
                return Ok(());
            }
            let inside = in_dual_unit_ball_rows(&xi, q, &g, tau, &w, 1e-10);
            check(inside == (dn <= 1.0), &format!("dn={dn} inside={inside}"))
        });
    }

    #[test]
    fn row_prox_optimality_condition() {
        // p = prox(u) of a*sum_j||row_j|| + b*||.||_F iff u - p lies in the
        // subdifferential; spot-check via the zero/nonzero row cases.
        forall("row prox optimality", 150, |gen| {
            let q = gen.usize_in(2..4);
            let d = gen.usize_in(1..5);
            let u: Vec<f64> = (0..d * q).map(|_| gen.normal() * 3.0).collect();
            let a = gen.f64_in(0.0..2.0);
            let b = gen.f64_in(0.0..2.0);
            let mut p = u.clone();
            sgl_prox_rows_inplace(&mut p, q, a, b);
            let pn = l2_norm(&p);
            if pn > 0.0 {
                for j in 0..d {
                    let (ur, pr) = (&u[j * q..(j + 1) * q], &p[j * q..(j + 1) * q]);
                    let rn = l2_norm(pr);
                    if rn > 0.0 {
                        // u_j - p_j = a * p_j/||p_j|| + b * p_j/||p||_F.
                        for t in 0..q {
                            let sub = a * pr[t] / rn + b * pr[t] / pn;
                            check_close(ur[t] - pr[t], sub, 1e-8, "active row subgrad")?;
                        }
                    } else {
                        // Zero row: the residual row must fit in a*B_2.
                        let rr: Vec<f64> = ur.to_vec();
                        check(l2_norm(&rr) <= a + 1e-10, "inactive row in a*ball")?;
                    }
                }
            } else {
                // All-zero: row-shrunk u must fit in b*B_F.
                let mut s = u.clone();
                row_soft_threshold_inplace(&mut s, q, a);
                check(l2_norm(&s) <= b + 1e-10, "zero block optimality")?;
            }
            Ok(())
        });
    }

    #[test]
    fn row_soft_threshold_q1_is_scalar() {
        let x = [1.5, -0.3, 0.0, -2.0];
        let mut a = x;
        row_soft_threshold_inplace(&mut a, 1, 0.5);
        let b = soft_threshold_vec(&x, 0.5);
        assert_eq!(a.to_vec(), b);
    }
}
