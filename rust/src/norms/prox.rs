//! Proximal operators: soft-thresholding `S_τ`, group soft-thresholding
//! `S^gp_τ`, and the fused two-level Sparse-Group Lasso prox
//! `S^gp_b ∘ S_a` used by the ISTA-BC update (paper §6).

use crate::linalg::ops::l2_norm;

/// Scalar soft-thresholding `S_t(v) = sign(v)(|v| − t)₊`.
#[inline]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    debug_assert!(t >= 0.0);
    let a = v.abs() - t;
    if a > 0.0 {
        a * v.signum()
    } else {
        0.0
    }
}

/// Vector soft-thresholding into a new vector.
pub fn soft_threshold_vec(x: &[f64], t: f64) -> Vec<f64> {
    x.iter().map(|&v| soft_threshold(v, t)).collect()
}

/// In-place vector soft-thresholding.
pub fn soft_threshold_inplace(x: &mut [f64], t: f64) {
    for v in x.iter_mut() {
        *v = soft_threshold(*v, t);
    }
}

/// Group soft-thresholding `S^gp_t(x) = (1 − t/‖x‖)₊ x` (block shrinkage).
pub fn group_soft_threshold(x: &[f64], t: f64) -> Vec<f64> {
    let mut out = x.to_vec();
    group_soft_threshold_inplace(&mut out, t);
    out
}

/// In-place group soft-thresholding. Returns the shrink factor applied
/// (0.0 means the whole block was zeroed).
pub fn group_soft_threshold_inplace(x: &mut [f64], t: f64) -> f64 {
    debug_assert!(t >= 0.0);
    let n = l2_norm(x);
    if n <= t {
        x.fill(0.0);
        return 0.0;
    }
    let shrink = 1.0 - t / n;
    for v in x.iter_mut() {
        *v *= shrink;
    }
    shrink
}

/// The fused SGL block prox (paper §6):
///
/// ```text
///   prox(u) = S^gp_{(1−τ) w_g α_g}( S_{τ α_g}(u) )
/// ```
///
/// which is the exact proximal operator of `α_g (τ‖·‖₁ + (1−τ)w_g‖·‖)`.
/// `a = τ α_g`, `b = (1−τ) w_g α_g`. Works in place on the block.
pub fn sgl_prox_inplace(u: &mut [f64], a: f64, b: f64) {
    soft_threshold_inplace(u, a);
    group_soft_threshold_inplace(u, b);
}

/// Out-of-place fused SGL block prox.
pub fn sgl_prox(u: &[f64], a: f64, b: f64) -> Vec<f64> {
    let mut out = u.to_vec();
    sgl_prox_inplace(&mut out, a, b);
    out
}

/// Projection onto the scaled `ℓ∞` ball `τ B_∞` (used by screening-rule
/// geometry; `S_τ = Id − Π_{τB_∞}`, paper Notation).
pub fn project_inf_ball(x: &[f64], t: f64) -> Vec<f64> {
    x.iter().map(|&v| v.clamp(-t, t)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, check_close, forall};

    #[test]
    fn scalar_soft_threshold() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }

    #[test]
    fn group_soft_threshold_shrinks_or_zeroes() {
        let x = [3.0, 4.0]; // norm 5
        assert_eq!(group_soft_threshold(&x, 5.0), vec![0.0, 0.0]);
        assert_eq!(group_soft_threshold(&x, 10.0), vec![0.0, 0.0]);
        let y = group_soft_threshold(&x, 2.5);
        assert_eq!(y, vec![1.5, 2.0]); // factor 0.5
    }

    #[test]
    fn identity_decomposition() {
        // S_t = Id - proj onto tB_inf
        forall("soft-threshold = Id - projection", 100, |g| {
            let x = g.vec_f64(1..20, -5.0..5.0);
            let t = g.f64_in(0.0..3.0);
            let st = soft_threshold_vec(&x, t);
            let pj = project_inf_ball(&x, t);
            for i in 0..x.len() {
                check_close(st[i] + pj[i], x[i], 1e-12, "S_t + proj = Id")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prox_optimality_condition() {
        // p = prox(u) of a*||.||_1 + b*||.|| iff
        // u - p in a*sub||.||_1(p) + b*sub||.||(p).
        forall("sgl prox optimality", 200, |g| {
            let u = g.vec_f64(1..15, -5.0..5.0);
            let a = g.f64_in(0.0..2.0);
            let b = g.f64_in(0.0..2.0);
            let p = sgl_prox(&u, a, b);
            let r: Vec<f64> = u.iter().zip(&p).map(|(x, y)| x - y).collect();
            let pn = l2_norm(&p);
            if pn > 0.0 {
                for i in 0..p.len() {
                    let grad_l2 = b * p[i] / pn;
                    let rest = r[i] - grad_l2;
                    if p[i] != 0.0 {
                        check_close(rest, a * p[i].signum(), 1e-8, "active coord subgrad")?;
                    } else {
                        check(rest.abs() <= a + 1e-10, "inactive coord in [-a,a]")?;
                    }
                }
            } else {
                // 0 optimal iff residual in a*B_inf + b*B, i.e. ||S_a(u)|| <= b.
                let s = soft_threshold_vec(&u, a);
                check(l2_norm(&s) <= b + 1e-10, "zero block optimality")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prox_is_nonexpansive() {
        forall("prox nonexpansive", 200, |g| {
            let n = g.usize_in(1..12);
            let u: Vec<f64> = (0..n).map(|_| g.normal() * 3.0).collect();
            let v: Vec<f64> = (0..n).map(|_| g.normal() * 3.0).collect();
            let a = g.f64_in(0.0..2.0);
            let b = g.f64_in(0.0..2.0);
            let pu = sgl_prox(&u, a, b);
            let pv = sgl_prox(&v, a, b);
            let d_in: f64 = u.iter().zip(&v).map(|(x, y)| (x - y) * (x - y)).sum();
            let d_out: f64 = pu.iter().zip(&pv).map(|(x, y)| (x - y) * (x - y)).sum();
            check(d_out <= d_in * (1.0 + 1e-9) + 1e-12, "nonexpansive")
        });
    }

    #[test]
    fn zero_thresholds_are_identity() {
        let u = [1.0, -2.0, 0.5];
        assert_eq!(sgl_prox(&u, 0.0, 0.0), u.to_vec());
    }
}
