//! The DST3 safe sphere (paper App. C, Prop. 11): the Xiang et al. (2011) /
//! Bonnefoy et al. (2014) construction generalized to the Sparse-Group
//! Lasso via the ε-norm geometry.
//!
//! Construction. Let `g★` attain `λ_max = Ω^D(Xᵀy)`. The dual feasible set
//! is contained in the half-space `H★⁻ = {θ : ⟨θ, η⟩ ≤ τ + (1−τ)w_{g★}}`
//! where `η = X_{g★} ∇‖·‖_{ε_{g★}}(X_{g★}ᵀ y/λ_max)` is the normal of the
//! constraint surface at `y/λ_max` (Lemma 5). Intersecting the dynamic
//! ball `B(y/λ, ‖y/λ − θ_k‖)` with `H★⁻` and re-sphering gives center
//! `θ_c = Π_{H★⁻}(y/λ)` and radius
//! `r² = ‖y/λ − θ_k‖² − ‖y/λ − θ_c‖²`.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::linalg::ops::{dot, l2_norm_sq};
use crate::linalg::Design;
use crate::norms::block::row_norms;
use crate::norms::epsilon::epsilon_norm_gradient;
use crate::norms::sgl::epsilon_g;
use crate::solver::datafit::Datafit;
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

pub struct Dst3Rule {
    /// `Xᵀη` (center shift in correlation space).
    xt_eta: Vec<f64>,
    /// `Xᵀy`.
    xty: Vec<f64>,
    /// `⟨η, y⟩`.
    eta_dot_y: f64,
    /// `‖η‖²`.
    eta_norm_sq: f64,
    /// Hyperplane offset `τ + (1−τ) w_{g★}`.
    offset: f64,
}

impl Dst3Rule {
    /// Derived for the plain least-squares dual (scalar or multi-task);
    /// [`super::make_rule`] rejects other datafits before constructing
    /// this.
    ///
    /// Multi-task construction: the dual constraint surface is
    /// `Ω^D(row_norms(XᵀΘ)) ≤ 1`, so the supporting-hyperplane normal at
    /// `Y/λ_max` composes the scalar ε-norm gradient (on the row-norm
    /// scores of the touching group) with the row-norm gradient
    /// `∂‖B_j‖/∂B_j = B_j/‖B_j‖` — the same Lemma-5 geometry on the
    /// Frobenius inner-product space. All carried quantities stay flat
    /// (`xty`/`xt_eta` feature-major `p · q`, `⟨η, Y⟩` and `‖η‖²`
    /// Frobenius), so [`Self::sphere`] is layout-agnostic.
    pub fn new<D: Design, F: Datafit>(pb: &SglProblem<D, F>) -> Self {
        let q = pb.datafit.tasks();
        let xty = pb.xt_zero_residual();
        let (g_star, lambda_max) = pb.lambda_max_argmax();
        let (a, b) = pb.groups.bounds(g_star);
        let eps = epsilon_g(pb.tau, pb.weights[g_star]);
        let n = pb.n();
        let offset = pb.tau + (1.0 - pb.tau) * pb.weights[g_star];
        if q == 1 {
            // xi = X_{g*}^T y / lambda_max, the touching point direction.
            let xi: Vec<f64> = xty[a..b].iter().map(|v| v / lambda_max).collect();
            // eta = X_{g*} * grad ||.||_eps (xi)  (Lemma 5: grad = xi^eps / ||xi^eps||_eps^D).
            let grad = epsilon_norm_gradient(&xi, eps);
            let mut eta = vec![0.0; n];
            for (k, j) in (a..b).enumerate() {
                pb.x.col_axpy(j, grad[k], &mut eta);
            }
            let xt_eta = pb.x.tmatvec(&eta);
            let eta_dot_y = dot(&eta, &pb.y);
            let eta_norm_sq = l2_norm_sq(&eta);
            return Dst3Rule { xt_eta, xty, eta_dot_y, eta_norm_sq, offset };
        }
        // Multi-task: scores of the touching group's correlation panel.
        let block = &xty[a * q..b * q];
        let scores = row_norms(block, q);
        let xi: Vec<f64> = scores.iter().map(|v| v / lambda_max).collect();
        let grad = epsilon_norm_gradient(&xi, eps);
        // Chain rule: G[k, t] = grad_k · B[k, t] / ‖B_k‖ (unit row
        // direction; a zero row has zero gradient).
        let mut eta = vec![0.0; n * q];
        for t in 0..q {
            let eta_t = &mut eta[t * n..(t + 1) * n];
            for (k, j) in (a..b).enumerate() {
                let gkt =
                    if scores[k] > 0.0 { grad[k] * block[k * q + t] / scores[k] } else { 0.0 };
                if gkt != 0.0 {
                    pb.x.col_axpy(j, gkt, eta_t);
                }
            }
        }
        let mut xt_eta = vec![0.0; pb.p() * q];
        for t in 0..q {
            let col = pb.x.tmatvec(&eta[t * n..(t + 1) * n]);
            for (j, v) in col.iter().enumerate() {
                xt_eta[j * q + t] = *v;
            }
        }
        let eta_dot_y = dot(&eta, &pb.y);
        let eta_norm_sq = l2_norm_sq(&eta);
        Dst3Rule { xt_eta, xty, eta_dot_y, eta_norm_sq, offset }
    }
}

impl<D: Design, F: Datafit> ScreeningRule<D, F> for Dst3Rule {
    fn kind(&self) -> RuleKind {
        RuleKind::Dst3
    }

    fn sphere(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        snap: &DualSnapshot,
    ) -> Option<Sphere> {
        // Violation of the half-space by y/lambda (>= 0 for lambda <= lmax).
        let violation = (self.eta_dot_y / lambda - self.offset) / self.eta_norm_sq;
        let dyn_radius = snap.dist_to_y_over_lambda(&pb.y, lambda);
        if violation <= 0.0 || self.eta_norm_sq == 0.0 {
            // y/lambda already inside the half-space: DST3 degenerates to
            // the dynamic sphere.
            let xt_center: Vec<f64> = self.xty.iter().map(|v| v / lambda).collect();
            return Some(Sphere { xt_center, radius: dyn_radius });
        }
        // theta_c = y/lambda - violation * eta; ||y/lambda - theta_c|| =
        // violation * ||eta||.
        let dist_center_sq = violation * violation * self.eta_norm_sq;
        let radius = (dyn_radius * dyn_radius - dist_center_sq).max(0.0).sqrt();
        let xt_center: Vec<f64> = self
            .xty
            .iter()
            .zip(&self.xt_eta)
            .map(|(ty, te)| ty / lambda - violation * te)
            .collect();
        Some(Sphere { xt_center, radius })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn problem(seed: u64, tau: f64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 3, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(9, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn never_worse_than_dynamic() {
        for seed in 1..6 {
            let pb = problem(seed, 0.4);
            let lmax = pb.lambda_max();
            for frac in [0.9, 0.5, 0.2] {
                let lambda = frac * lmax;
                let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lambda);
                let mut dst3 = Dst3Rule::new(&pb);
                let mut dynr = super::super::dynamic_rule::DynamicRule::new(&pb);
                let r3 = dst3.sphere(&pb, lambda, &snap).unwrap().radius;
                let rd = dynr.sphere(&pb, lambda, &snap).unwrap().radius;
                assert!(r3 <= rd + 1e-12, "seed {seed} frac {frac}: {r3} vs {rd}");
            }
        }
    }

    #[test]
    fn safe_for_dual_optimum_at_trivial_lambda() {
        // At lambda slightly below lmax with beta well-solved, the DST3
        // ball must contain theta_hat. Use beta=0 (optimal at lmax) and
        // lambda=lmax: theta_hat = y/lmax and radius should cover it.
        let pb = problem(7, 0.3);
        let lmax = pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lmax);
        let mut dst3 = Dst3Rule::new(&pb);
        let s = dst3.sphere(&pb, lmax, &snap).unwrap();
        // theta_hat = y/lmax; in correlation space X^T theta_hat = xty/lmax.
        let xtth: Vec<f64> = pb.x.tmatvec(&pb.y).iter().map(|v| v / lmax).collect();
        // The sphere in theta-space maps into correlation space per-feature
        // with |X_j^T(theta - theta_c)| <= r ||X_j||; verify containment in
        // those terms.
        for j in 0..pb.p() {
            let diff = (xtth[j] - s.xt_center[j]).abs();
            assert!(
                diff <= s.radius * pb.col_norms[j] + 1e-9,
                "feature {j}: {diff} vs {}",
                s.radius * pb.col_norms[j]
            );
        }
    }

    #[test]
    fn degenerate_eta_falls_back_to_dynamic() {
        // tau = 1 (pure lasso): offset = 1, eta well-defined; just smoke
        // test that the rule produces a finite sphere across lambdas.
        let pb = problem(9, 1.0);
        let lmax = pb.lambda_max();
        let mut dst3 = Dst3Rule::new(&pb);
        for frac in [1.0, 0.5, 0.1] {
            let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, frac * lmax);
            let s = dst3.sphere(&pb, frac * lmax, &snap).unwrap();
            assert!(s.radius.is_finite());
            assert!(s.xt_center.iter().all(|v| v.is_finite()));
        }
    }
}
