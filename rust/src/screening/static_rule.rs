//! The static safe sphere, the App. C extension of El Ghaoui et al. (2012)
//! to the Sparse-Group Lasso: `B(y/λ, ‖y/λ_max − y/λ‖)`.
//!
//! Validity: `y/λ_max` is dual feasible and `θ̂ = Π_Δ(y/λ)` (Rmk. 1), so
//! the distance from `y/λ` to `θ̂` is at most the distance to any feasible
//! point. The sphere never changes during the solve — hence "static" — and
//! its radius does not vanish, which caps how much it can ever screen.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::linalg::ops::l2_norm;
use crate::linalg::Design;
use crate::solver::datafit::Datafit;
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

pub struct StaticRule {
    /// `Xᵀy`, reused as the sphere center correlation `Xᵀ(y/λ) = Xᵀy/λ`.
    xty: Vec<f64>,
    y_norm: f64,
    lambda_max: f64,
}

impl StaticRule {
    /// Derived for the plain least-squares dual (scalar or multi-task;
    /// the projection argument only needs `θ̂ = Π_Δ(Y/λ)`, which holds for
    /// the Frobenius dual of the multi-task quadratic too);
    /// [`super::make_rule`] rejects other datafits before constructing
    /// this.
    pub fn new<D: Design, F: Datafit>(pb: &SglProblem<D, F>) -> Self {
        // Feature-major `XᵀY` (`p · q`; the plain `Xᵀy` at q = 1) and the
        // Frobenius norm of Y.
        let xty = pb.xt_zero_residual();
        let y_norm = l2_norm(&pb.y);
        let lambda_max = pb.lambda_max();
        StaticRule { xty, y_norm, lambda_max }
    }
}

impl<D: Design, F: Datafit> ScreeningRule<D, F> for StaticRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Static
    }

    fn sphere(
        &mut self,
        _pb: &SglProblem<D, F>,
        lambda: f64,
        _snap: &DualSnapshot,
    ) -> Option<Sphere> {
        // ||y/lmax - y/lambda|| = ||y|| * |1/lambda - 1/lmax|.
        let radius = self.y_norm * (1.0 / lambda - 1.0 / self.lambda_max).abs();
        let xt_center: Vec<f64> = self.xty.iter().map(|v| v / lambda).collect();
        Some(Sphere { xt_center, radius })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[2, 3]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(6, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.3)
    }

    #[test]
    fn radius_zero_at_lambda_max() {
        let pb = problem(1);
        let mut rule = StaticRule::new(&pb);
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, pb.lambda_max());
        let s = rule.sphere(&pb, pb.lambda_max(), &snap).unwrap();
        assert!(s.radius < 1e-12);
    }

    #[test]
    fn radius_grows_as_lambda_shrinks() {
        let pb = problem(2);
        let mut rule = StaticRule::new(&pb);
        let lmax = pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lmax);
        let r1 = rule.sphere(&pb, 0.5 * lmax, &snap).unwrap().radius;
        let r2 = rule.sphere(&pb, 0.1 * lmax, &snap).unwrap().radius;
        assert!(r2 > r1 && r1 > 0.0);
    }

    #[test]
    fn center_is_scaled_xty() {
        let pb = problem(3);
        let mut rule = StaticRule::new(&pb);
        let lambda = 0.7 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lambda);
        let s = rule.sphere(&pb, lambda, &snap).unwrap();
        let explicit: Vec<f64> =
            pb.x.tmatvec(&pb.y).iter().map(|v| v / lambda).collect();
        for (a, b) in s.xt_center.iter().zip(&explicit) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
