//! The dynamic safe sphere, the App. C extension of Bonnefoy et al. (2014)
//! to the Sparse-Group Lasso: `B(y/λ, ‖θ_k − y/λ‖)`.
//!
//! Validity: `θ̂` is the projection of `y/λ` onto the dual feasible set
//! (Rmk. 1), so for *any* feasible `θ_k`, `‖θ̂ − y/λ‖ ≤ ‖θ_k − y/λ‖`. The
//! center stays at `y/λ` but the radius improves as the dual-scaled
//! iterates `θ_k` approach `θ̂`; it converges to `‖θ̂ − y/λ‖ > 0`, not to
//! zero — the structural gap to the GAP safe sphere.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::linalg::Design;
use crate::solver::datafit::Datafit;
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

pub struct DynamicRule {
    xty: Vec<f64>,
}

impl DynamicRule {
    /// Derived for the plain least-squares dual (scalar or multi-task —
    /// the projection argument holds for the Frobenius dual as well);
    /// [`super::make_rule`] rejects other datafits before constructing
    /// this. `xty` is feature-major `XᵀY` (`p · q`; plain `Xᵀy` at q = 1).
    pub fn new<D: Design, F: Datafit>(pb: &SglProblem<D, F>) -> Self {
        DynamicRule { xty: pb.xt_zero_residual() }
    }
}

impl<D: Design, F: Datafit> ScreeningRule<D, F> for DynamicRule {
    fn kind(&self) -> RuleKind {
        RuleKind::Dynamic
    }

    fn sphere(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        snap: &DualSnapshot,
    ) -> Option<Sphere> {
        let radius = snap.dist_to_y_over_lambda(&pb.y, lambda);
        let xt_center: Vec<f64> = self.xty.iter().map(|v| v / lambda).collect();
        Some(Sphere { xt_center, radius })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(7, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.5)
    }

    #[test]
    fn radius_matches_distance_to_center() {
        let pb = problem(1);
        let lambda = 0.6 * pb.lambda_max();
        let beta = vec![0.0; pb.p()];
        let snap = DualSnapshot::compute(&pb, &beta, &pb.y, lambda);
        let mut rule = DynamicRule::new(&pb);
        let s = rule.sphere(&pb, lambda, &snap).unwrap();
        let dist: f64 = snap
            .theta
            .iter()
            .zip(&pb.y)
            .map(|(t, y)| {
                let d = t - y / lambda;
                d * d
            })
            .sum::<f64>()
            .sqrt();
        assert!((s.radius - dist).abs() < 1e-12);
    }

    #[test]
    fn tighter_than_static_at_start() {
        // With beta = 0, theta_k = y/max(lambda, Omega^D(X^T y)) =
        // lambda_max scaling: ||theta_k - y/lambda|| = ||y||(1/lambda - 1/lmax),
        // i.e. exactly the static radius; dynamic is never worse.
        let pb = problem(2);
        let lambda = 0.4 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lambda);
        let mut dynr = DynamicRule::new(&pb);
        let mut statr = super::super::static_rule::StaticRule::new(&pb);
        let rd = dynr.sphere(&pb, lambda, &snap).unwrap().radius;
        let rs = statr.sphere(&pb, lambda, &snap).unwrap().radius;
        assert!(rd <= rs + 1e-12);
        assert!((rd - rs).abs() < 1e-9, "equal at beta=0: {rd} vs {rs}");
    }
}
