//! The GAP safe sphere (paper Theorem 2) — the paper's contribution.
//!
//! Center: the dual-scaled residual `θ_k = ρ_k / max(λ, Ω^D(Xᵀρ_k))`
//! (Eq. 15). Radius: `r = sqrt(2·(P(β_k) − D(θ_k)) / λ²)`.
//!
//! Because `θ_k → θ̂` and the gap → 0 as the primal iterate converges
//! (Prop. 5), these spheres are a *converging* sequence of safe regions
//! (Rmk. 7): the rule keeps screening more variables as the solver
//! proceeds, and in finite time identifies the optimal active sets
//! (Prop. 6). The baselines in this module's siblings all keep a radius
//! bounded away from zero, which is exactly why they plateau in Fig. 2.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

/// GAP safe rule: entirely derived from the current dual snapshot, so the
/// rule itself is stateless.
pub struct GapSafeRule;

impl ScreeningRule for GapSafeRule {
    fn kind(&self) -> RuleKind {
        RuleKind::GapSafe
    }

    fn sphere(&mut self, _pb: &SglProblem, _lambda: f64, snap: &DualSnapshot) -> Option<Sphere> {
        Some(Sphere { xt_center: snap.xt_theta.clone(), radius: snap.radius })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[2, 2, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(8, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.4)
    }

    #[test]
    fn sphere_uses_snapshot_center_and_radius() {
        let pb = problem(1);
        let beta = vec![0.0; pb.p()];
        let rho = pb.y.clone();
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
        let mut rule = GapSafeRule;
        let s = rule.sphere(&pb, lambda, &snap).unwrap();
        assert_eq!(s.xt_center, snap.xt_theta);
        assert_eq!(s.radius, snap.radius);
    }

    #[test]
    fn radius_shrinks_with_better_iterates() {
        // beta closer to the optimum => smaller gap => smaller GAP sphere.
        let pb = problem(2);
        let lambda = 0.4 * pb.lambda_max();
        let beta0 = vec![0.0; pb.p()];
        let snap0 = DualSnapshot::compute(&pb, &beta0, &pb.y, lambda);
        // one crude prox-gradient step improves the primal
        let l: f64 = pb.lipschitz.iter().sum();
        let grad = pb.x.tmatvec(&pb.y);
        let mut beta1 = beta0.clone();
        for j in 0..pb.p() {
            beta1[j] = grad[j] / l;
        }
        for (g, a, b) in pb.groups.iter() {
            crate::norms::prox::sgl_prox_inplace(
                &mut beta1[a..b],
                pb.tau * lambda / l,
                (1.0 - pb.tau) * pb.weights[g] * lambda / l,
            );
        }
        let xb = pb.x.matvec(&beta1);
        let rho1: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let snap1 = DualSnapshot::compute(&pb, &beta1, &rho1, lambda);
        assert!(snap1.gap <= snap0.gap + 1e-12);
        assert!(snap1.radius <= snap0.radius + 1e-12);
    }
}
