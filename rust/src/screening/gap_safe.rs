//! The GAP safe sphere (paper Theorem 2) — the paper's contribution.
//!
//! Center: the dual-scaled residual `θ_k = ρ_k / max(λ, Ω^D(Xᵀρ_k))`
//! (Eq. 15). Radius: `r = sqrt(2·(P(β_k) − D(θ_k)) / λ²)`.
//!
//! Because `θ_k → θ̂` and the gap → 0 as the primal iterate converges
//! (Prop. 5), these spheres are a *converging* sequence of safe regions
//! (Rmk. 7): the rule keeps screening more variables as the solver
//! proceeds, and in finite time identifies the optimal active sets
//! (Prop. 6). The baselines in this module's siblings all keep a radius
//! bounded away from zero, which is exactly why they plateau in Fig. 2.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::linalg::Design;
use crate::solver::datafit::Datafit;
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

/// GAP safe rule: entirely derived from the current dual snapshot, so the
/// rule itself is stateless (and datafit-generic for free — the snapshot
/// already used the datafit's dual and curvature).
pub struct GapSafeRule;

impl<D: Design, F: Datafit> ScreeningRule<D, F> for GapSafeRule {
    fn kind(&self) -> RuleKind {
        RuleKind::GapSafe
    }

    fn sphere(
        &mut self,
        _pb: &SglProblem<D, F>,
        _lambda: f64,
        snap: &DualSnapshot,
    ) -> Option<Sphere> {
        Some(Sphere { xt_center: snap.xt_theta.clone(), radius: snap.radius })
    }
}

/// Dual point carried across grid points by the sequential rule.
struct CarriedDual {
    theta: Vec<f64>,
    xt_theta: Vec<f64>,
    /// Squared augmented-block norm of θ (ridge datafits; see
    /// [`DualSnapshot::theta_aug_sq`]) — needed to re-evaluate the dual at
    /// later λ without the β that built θ.
    theta_aug_sq: f64,
}

/// Sequential GAP safe rule (`GAPSAFE_SEQ`, paper Alg. 2 "previous
/// ε-solution"): screens exactly **once per λ**, at the first gap check,
/// using the dual point inherited from the previous grid point of a
/// warm-started path.
///
/// Validity: the dual feasible set `Δ_X = {θ : Ω^D(Xᵀθ) ≤ 1}` does not
/// depend on λ, so the θ stored at `λ_{t−1}` is still feasible at `λ_t`
/// and Theorem 2 applies verbatim to the pair `(β_warm, θ_prev)`:
/// `‖θ̂(λ_t) − θ_prev‖ ≤ sqrt(2·c·(P_{λ_t}(β_warm) − D_{λ_t}(θ_prev)))/λ_t`
/// with `c` the datafit curvature. For datafits whose conjugate also has a
/// *domain* constraint (logistic: `y − λθ ∈ [0,1]`), feasibility at
/// smaller λ follows from the scaling: θ was built as `r/s` with `s ≥ λ`,
/// so `λ_t/s ≤ λ_{t−1}/s ≤ 1` keeps `y − λ_t θ` a convex combination of
/// in-domain points (the dual-scaling contract of
/// [`crate::solver::datafit`]).
/// Because warm starts make that gap small for adjacent grid points,
/// screening fires *at epoch 0*, before any new iterations — and since
/// `Xᵀθ_prev` was saved alongside θ, the epoch-0 sphere costs **no extra
/// matvec**. After that one application the rule stays silent until the
/// next λ (the sequential/dynamic distinction of Ndiaye et al. 2017).
pub struct GapSafeSeqRule {
    prev: Option<CarriedDual>,
    /// λ of the last emitted sphere — used to detect grid-point changes.
    last_lambda: Option<f64>,
}

impl GapSafeSeqRule {
    pub fn new() -> Self {
        GapSafeSeqRule { prev: None, last_lambda: None }
    }
}

impl Default for GapSafeSeqRule {
    fn default() -> Self {
        Self::new()
    }
}

impl<D: Design, F: Datafit> ScreeningRule<D, F> for GapSafeSeqRule {
    fn kind(&self) -> RuleKind {
        RuleKind::GapSafeSeq
    }

    fn sphere(
        &mut self,
        pb: &SglProblem<D, F>,
        lambda: f64,
        snap: &DualSnapshot,
    ) -> Option<Sphere> {
        if self.last_lambda == Some(lambda) {
            return None; // sequential: a single screening pass per grid point
        }
        self.last_lambda = Some(lambda);
        match &self.prev {
            Some(carried) => {
                let dual =
                    pb.datafit.dual_at(&pb.y, &carried.theta, carried.theta_aug_sq, lambda);
                let gap = (snap.primal - dual).max(0.0);
                // Same cancellation-error floor as DualSnapshot::compute:
                // a radius-0 sphere must never arise from round-off alone.
                let floor = 16.0 * f64::EPSILON * (snap.primal.abs() + dual.abs());
                let radius = (2.0 * pb.datafit.curvature() * gap.max(floor)).sqrt() / lambda;
                Some(Sphere { xt_center: carried.xt_theta.clone(), radius })
            }
            // First grid point: nothing carried yet; fall back to the
            // current snapshot's sphere (= the dynamic rule at this check).
            None => Some(Sphere { xt_center: snap.xt_theta.clone(), radius: snap.radius }),
        }
    }

    fn on_solve_complete(&mut self, _pb: &SglProblem<D, F>, _lambda: f64, snap: &DualSnapshot) {
        self.prev = Some(CarriedDual {
            theta: snap.theta.clone(),
            xt_theta: snap.xt_theta.clone(),
            theta_aug_sq: snap.theta_aug_sq,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;
    use crate::util::rng::Pcg;

    fn problem(seed: u64) -> SglProblem {
        let groups = Groups::from_sizes(&[2, 2, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(8, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, 0.4)
    }

    #[test]
    fn sphere_uses_snapshot_center_and_radius() {
        let pb = problem(1);
        let beta = vec![0.0; pb.p()];
        let rho = pb.y.clone();
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &beta, &rho, lambda);
        let mut rule = GapSafeRule;
        let s = rule.sphere(&pb, lambda, &snap).unwrap();
        assert_eq!(s.xt_center, snap.xt_theta);
        assert_eq!(s.radius, snap.radius);
    }

    #[test]
    fn radius_shrinks_with_better_iterates() {
        // beta closer to the optimum => smaller gap => smaller GAP sphere.
        let pb = problem(2);
        let lambda = 0.4 * pb.lambda_max();
        let beta0 = vec![0.0; pb.p()];
        let snap0 = DualSnapshot::compute(&pb, &beta0, &pb.y, lambda);
        // one crude prox-gradient step improves the primal
        let l: f64 = pb.lipschitz.iter().sum();
        let grad = pb.x.tmatvec(&pb.y);
        let mut beta1 = beta0.clone();
        for j in 0..pb.p() {
            beta1[j] = grad[j] / l;
        }
        for (g, a, b) in pb.groups.iter() {
            crate::norms::prox::sgl_prox_inplace(
                &mut beta1[a..b],
                pb.tau * lambda / l,
                (1.0 - pb.tau) * pb.weights[g] * lambda / l,
            );
        }
        let xb = pb.x.matvec(&beta1);
        let rho1: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let snap1 = DualSnapshot::compute(&pb, &beta1, &rho1, lambda);
        assert!(snap1.gap <= snap0.gap + 1e-12);
        assert!(snap1.radius <= snap0.radius + 1e-12);
    }

    #[test]
    fn seq_rule_screens_once_per_lambda() {
        let pb = problem(3);
        let lambda = 0.5 * pb.lambda_max();
        let snap = DualSnapshot::compute(&pb, &vec![0.0; pb.p()], &pb.y, lambda);
        let mut rule = GapSafeSeqRule::new();
        assert!(rule.sphere(&pb, lambda, &snap).is_some(), "first check must screen");
        assert!(rule.sphere(&pb, lambda, &snap).is_none(), "second check must not");
        // A new lambda re-arms the rule.
        let lambda2 = 0.4 * pb.lambda_max();
        assert!(rule.sphere(&pb, lambda2, &snap).is_some());
    }

    #[test]
    fn seq_rule_uses_carried_dual_point() {
        let pb = problem(4);
        let l1 = 0.6 * pb.lambda_max();
        let l2 = 0.5 * pb.lambda_max();
        let beta = vec![0.0; pb.p()];
        let snap1 = DualSnapshot::compute(&pb, &beta, &pb.y, l1);
        let mut rule = GapSafeSeqRule::new();
        rule.on_solve_complete(&pb, l1, &snap1);
        let snap2 = DualSnapshot::compute(&pb, &beta, &pb.y, l2);
        let s = rule.sphere(&pb, l2, &snap2).expect("first check at new lambda");
        // Center is X^T theta_prev, not the fresh snapshot's center.
        assert_eq!(s.xt_center, snap1.xt_theta);
        // Radius follows Theorem 2 for the carried pair.
        let dual = crate::solver::duality::dual_value(&pb.y, &snap1.theta, l2);
        let gap = (snap2.primal - dual).max(0.0);
        let expect = (2.0 * gap.max(16.0 * f64::EPSILON * (snap2.primal.abs() + dual.abs())))
            .sqrt()
            / l2;
        assert!((s.radius - expect).abs() < 1e-12);
    }
}
