//! Safe screening rules for the Sparse-Group Lasso (paper §4 and App. C).
//!
//! A screening rule supplies a **safe sphere** `B(θ_c, r)` guaranteed to
//! contain the dual optimum `θ̂`. Theorem 1 then eliminates:
//!
//! - groups with `T_g < (1−τ)w_g` (group-level test, Eq. 14), and
//! - features with `|X_jᵀθ_c| + r‖X_j‖ < τ` (feature-level test, Eq. 13).
//!
//! Implemented rules: [`gap_safe`] (the paper's contribution),
//! [`static_rule`], [`dynamic_rule`], [`dst3`] (the App. C extensions of
//! prior work to SGL), and a no-op baseline. All spheres are applied by the
//! shared [`apply_sphere`] machinery, so rule comparisons (Fig. 2c / 3b)
//! measure exactly the sphere quality.

pub mod dst3;
pub mod dynamic_rule;
pub mod gap_safe;
pub mod none;
pub mod static_rule;

use crate::linalg::ops::{inf_norm, l2_norm};
use crate::linalg::Design;
use crate::norms::block::row_norms;
use crate::norms::prox::soft_threshold_vec;
use crate::solver::datafit::{Datafit, FitState, Quadratic};
use crate::solver::duality::DualSnapshot;
use crate::solver::groups::Groups;
use crate::solver::problem::SglProblem;
use crate::solver::sweep::SweepCtx;
use crate::util::pool::SharedSlice;

/// Which screening rule to run (CLI/config selectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// No screening (plain solver baseline).
    None,
    /// Static safe sphere of El Ghaoui et al. (2012), App. C.
    Static,
    /// Dynamic safe sphere of Bonnefoy et al. (2014), App. C.
    Dynamic,
    /// DST3 sphere (Xiang et al. 2011 / Bonnefoy et al. 2014), App. C.
    Dst3,
    /// GAP safe sphere (Theorem 2) — the paper's rule, applied
    /// *dynamically* at every gap evaluation.
    GapSafe,
    /// Sequential GAP safe sphere (paper Alg. 2, "previous ε-solution"):
    /// screens **once per λ**, at the first gap check, using the dual
    /// point carried over from the previous grid point of a warm-started
    /// path. This is the `GAPSAFE_SEQ` variant of the authors' reference
    /// implementation.
    GapSafeSeq,
}

impl RuleKind {
    pub fn name(self) -> &'static str {
        match self {
            RuleKind::None => "none",
            RuleKind::Static => "static",
            RuleKind::Dynamic => "dynamic",
            RuleKind::Dst3 => "dst3",
            RuleKind::GapSafe => "gap_safe",
            RuleKind::GapSafeSeq => "gap_safe_seq",
        }
    }

    /// All rules, in the order the paper's figures list them (the
    /// sequential GAP variant last, as in the authors' comparison).
    pub fn all() -> [RuleKind; 6] {
        [
            RuleKind::None,
            RuleKind::Static,
            RuleKind::Dynamic,
            RuleKind::Dst3,
            RuleKind::GapSafe,
            RuleKind::GapSafeSeq,
        ]
    }

    pub fn from_name(s: &str) -> Option<RuleKind> {
        Self::all().into_iter().find(|r| r.name() == s)
    }
}

/// A safe sphere `B(θ_c, r)` in correlation space: we carry `Xᵀθ_c` (what
/// every test consumes) rather than `θ_c` itself.
#[derive(Clone, Debug)]
pub struct Sphere {
    /// `Xᵀθ_c` for the sphere center.
    pub xt_center: Vec<f64>,
    /// Sphere radius `r`.
    pub radius: f64,
}

/// A screening rule: builds a safe sphere from the current dual snapshot.
///
/// Generic over the [`Design`] backend and the [`Datafit`] so one rule
/// instance serves dense and sparse, regression and classification
/// problems alike; rule state never depends on the backend. The datafit
/// defaults to [`Quadratic`] so historical `ScreeningRule<D>` bounds keep
/// compiling.
pub trait ScreeningRule<D: Design, F: Datafit = Quadratic>: Send {
    fn kind(&self) -> RuleKind;

    /// Produce the safe sphere for the current iterate. `snap` carries the
    /// dual-scaled feasible point `θ_k` (Eq. 15), its `Xᵀθ_k`, and the
    /// duality gap.
    fn sphere(&mut self, pb: &SglProblem<D, F>, lambda: f64, snap: &DualSnapshot)
        -> Option<Sphere>;

    /// Hook invoked by the solver when the solve at `lambda` terminates,
    /// with the final dual snapshot. Sequential rules
    /// ([`RuleKind::GapSafeSeq`]) store the dual point here and reuse it to
    /// screen at epoch 0 of the *next* grid point of a warm-started path
    /// (the rule instance is constructed once per path and carried across
    /// λ's). Stateless rules ignore it.
    fn on_solve_complete(&mut self, _pb: &SglProblem<D, F>, _lambda: f64, _snap: &DualSnapshot) {
    }
}

/// Construct the rule implementation for a [`RuleKind`].
///
/// Rules may precompute per-problem/per-λ quantities (`Xᵀy`, `λ_max`, the
/// DST3 hyperplane); constructing once per path solve amortizes that.
///
/// The static/dynamic/DST3 baselines are derived for the plain
/// least-squares dual (their centers/radii hard-code `y/λ` geometry), so
/// requesting them for any other datafit — logistic, or a ridge-carrying
/// quadratic — is rejected here rather than silently screening unsafely.
pub fn make_rule<D: Design, F: Datafit>(
    kind: RuleKind,
    pb: &SglProblem<D, F>,
) -> Box<dyn ScreeningRule<D, F>> {
    let quadratic_only = || {
        assert!(
            pb.datafit.state_is_residual() && pb.datafit.ridge() == 0.0,
            "screening rule `{}` is only safe for the plain least-squares datafit; \
             use none/gap_safe/gap_safe_seq with `{}`",
            kind.name(),
            pb.datafit.kind().name(),
        );
    };
    match kind {
        RuleKind::None => Box::new(none::NoRule),
        RuleKind::Static => {
            quadratic_only();
            Box::new(static_rule::StaticRule::new(pb))
        }
        RuleKind::Dynamic => {
            quadratic_only();
            Box::new(dynamic_rule::DynamicRule::new(pb))
        }
        RuleKind::Dst3 => {
            quadratic_only();
            Box::new(dst3::Dst3Rule::new(pb))
        }
        RuleKind::GapSafe => Box::new(gap_safe::GapSafeRule),
        RuleKind::GapSafeSeq => Box::new(gap_safe::GapSafeSeqRule::new()),
    }
}

/// Active-set bookkeeping shared by the solvers.
#[derive(Clone, Debug)]
pub struct ActiveSet {
    /// Per-feature activity mask.
    pub feature: Vec<bool>,
    /// Per-group activity mask (a group is inactive iff screened as a
    /// whole; it may still be active with some features screened).
    pub group: Vec<bool>,
}

impl ActiveSet {
    /// Everything active.
    pub fn full(groups: &Groups) -> Self {
        ActiveSet { feature: vec![true; groups.p()], group: vec![true; groups.n_groups()] }
    }

    pub fn n_active_features(&self) -> usize {
        self.feature.iter().filter(|&&a| a).count()
    }

    pub fn n_active_groups(&self) -> usize {
        self.group.iter().filter(|&&a| a).count()
    }

    /// Active feature indices of group `g`.
    pub fn active_in_group(&self, groups: &Groups, g: usize) -> Vec<usize> {
        let (a, b) = groups.bounds(g);
        (a..b).filter(|&j| self.feature[j]).collect()
    }
}

/// Outcome counts of one screening application.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScreenOutcome {
    pub groups_screened: usize,
    pub features_screened: usize,
    /// True if a *nonzero* coefficient was zeroed (the residual changed, so
    /// cached primal/dual values are stale).
    pub beta_changed: bool,
}

/// Apply Theorem 1 with the given sphere: shrink `active`, zero the
/// eliminated coordinates of `beta`, and patch the residual `rho = y − Xβ`
/// accordingly. Only currently-active variables are tested (screening is
/// monotone along the solve).
///
/// Legacy residual-slice entry point (residual-state datafits only);
/// generic solvers use [`apply_sphere_state`].
pub fn apply_sphere<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    sphere: &Sphere,
    active: &mut ActiveSet,
    beta: &mut [f64],
    rho: &mut [f64],
) -> ScreenOutcome {
    apply_sphere_ctx(pb, sphere, active, beta, rho, &SweepCtx::serial())
}

/// [`apply_sphere`] with the per-group Theorem-1 tests fanned over a
/// [`SweepCtx`] crew (legacy residual-slice form; asserts the datafit's
/// state is the residual).
pub fn apply_sphere_ctx<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    sphere: &Sphere,
    active: &mut ActiveSet,
    beta: &mut [f64],
    rho: &mut [f64],
    ctx: &SweepCtx,
) -> ScreenOutcome {
    assert!(pb.datafit.state_is_residual(), "residual-slice screening needs a residual-state datafit");
    apply_sphere_core(pb, sphere, active, beta, rho, ctx)
}

/// [`apply_sphere`] on a full datafit state: patches
/// [`FitState::main`] per eliminated coordinate and re-syncs the derived
/// residual once at the end if anything changed.
pub fn apply_sphere_state<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    sphere: &Sphere,
    active: &mut ActiveSet,
    beta: &mut [f64],
    state: &mut FitState,
    ctx: &SweepCtx,
) -> ScreenOutcome {
    let out = apply_sphere_core(pb, sphere, active, beta, &mut state.main, ctx);
    if out.beta_changed {
        pb.datafit.sync_residual(&pb.y, state);
    }
    out
}

/// The shared Theorem-1 engine. The decision pass reads only the sphere
/// and the problem precomputations — never `beta`/`main` — so it
/// parallelizes with disjoint writes and the decisions are bit-identical
/// to the serial pass. The mutations (mask shrink, `beta` zeroing, `main`
/// patch) replay serially in the exact order of the serial loop, so the
/// whole outcome is bit-for-bit the same.
fn apply_sphere_core<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    sphere: &Sphere,
    active: &mut ActiveSet,
    beta: &mut [f64],
    rho: &mut [f64],
    ctx: &SweepCtx,
) -> ScreenOutcome {
    let tau = pb.tau;
    let r = sphere.radius;
    // Relative slack guarding the strict inequalities of Theorem 1 against
    // round-off: boundary-active variables (equality in the tests) must
    // never be eliminated by floating-point noise.
    let slack = 1e-12;
    let ng = pb.n_groups();
    // Multi-response spheres carry the feature-major p × q center
    // correlations; the Theorem-1 tests run on the per-feature row-norm
    // *scores* (arXiv 1506.03736) — non-negative, so the same scalar
    // decision pass applies verbatim (|s| = s, soft-threshold unchanged).
    // At q = 1 the sphere's own vector is used directly, bit-for-bit.
    let q = pb.datafit.tasks();
    let scores = if q == 1 { Vec::new() } else { row_norms(&sphere.xt_center, q) };
    let xt_center: &[f64] = if q == 1 { &sphere.xt_center } else { &scores };
    // -- decision pass: pure per-group tests (Eq. 13/14), parallelizable.
    let mut kill_group = vec![false; ng];
    let mut kill_feature = vec![false; pb.p()];
    {
        let kg = SharedSlice::new(&mut kill_group);
        let kf = SharedSlice::new(&mut kill_feature);
        let active_ref = &*active;
        ctx.for_each(ng, 16, 32, |g| {
            if !active_ref.group[g] {
                return;
            }
            let (a, b) = pb.groups.bounds(g);
            let xi_c = &xt_center[a..b];
            // Group-level bound T_g (Eq. 14 / Theorem 1).
            let xi_inf = inf_norm(xi_c);
            let t_g = if xi_inf > tau {
                l2_norm(&soft_threshold_vec(xi_c, tau)) + r * pb.group_spectral_norms[g]
            } else {
                (xi_inf + r * pb.group_spectral_norms[g] - tau).max(0.0)
            };
            let w_thresh = (1.0 - tau) * pb.weights[g];
            if t_g < w_thresh - slack * w_thresh.max(1.0) {
                // SAFETY: one group per worker; feature ranges disjoint.
                unsafe { kg.set(g, true) };
                return;
            }
            // Feature-level tests within the surviving group (Eq. 13).
            for j in a..b {
                if active_ref.feature[j]
                    && xt_center[j].abs() + r * pb.col_norms[j] < tau - slack * tau.max(1.0)
                {
                    unsafe { kf.set(j, true) };
                }
            }
        });
    }
    // -- apply pass: serial, same order and mutations as the historical
    // single-threaded loop.
    let mut out = ScreenOutcome::default();
    for (g, a, b) in pb.groups.iter() {
        if !active.group[g] {
            continue;
        }
        if kill_group[g] {
            // Entire group is eliminated.
            active.group[g] = false;
            out.groups_screened += 1;
            for j in a..b {
                if active.feature[j] {
                    active.feature[j] = false;
                    out.features_screened += 1;
                }
                out.beta_changed |= zero_coord(pb, j, beta, rho);
            }
            continue;
        }
        for j in a..b {
            if active.feature[j] && kill_feature[j] {
                active.feature[j] = false;
                out.features_screened += 1;
                out.beta_changed |= zero_coord(pb, j, beta, rho);
            }
        }
        // A group whose features were all individually screened is inactive.
        if (a..b).all(|j| !active.feature[j]) {
            active.group[g] = false;
        }
    }
    out
}

/// Zero `beta[j]` (the whole coefficient row for multi-response datafits),
/// removing its contribution from the maintained state vector
/// (`rho += β_j X_j` for the residual, `Xβ −= β_j X_j` for the linear
/// predictor; per task slice when `q > 1`). Returns true if any
/// coefficient was nonzero (i.e. the state changed).
#[inline]
fn zero_coord<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    j: usize,
    beta: &mut [f64],
    rho: &mut [f64],
) -> bool {
    let q = pb.datafit.tasks();
    if q == 1 {
        let bj = beta[j];
        if bj != 0.0 {
            pb.x.col_axpy(j, -pb.datafit.delta_sign() * bj, rho);
            beta[j] = 0.0;
            return true;
        }
        return false;
    }
    let n = pb.x.n_rows();
    let mut changed = false;
    for t in 0..q {
        let bjt = beta[j * q + t];
        if bjt != 0.0 {
            pb.x.col_axpy(j, -pb.datafit.delta_sign() * bjt, &mut rho[t * n..(t + 1) * n]);
            beta[j * q + t] = 0.0;
            changed = true;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg;

    fn toy_problem(seed: u64, tau: f64) -> SglProblem {
        let groups = Groups::from_sizes(&[3, 3, 2]);
        let mut rng = Pcg::seeded(seed);
        let x = Matrix::from_fn(10, groups.p(), |_, _| rng.normal());
        let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        SglProblem::new(x, y, groups, tau)
    }

    #[test]
    fn rule_kind_round_trip() {
        for k in RuleKind::all() {
            assert_eq!(RuleKind::from_name(k.name()), Some(k));
        }
        assert_eq!(RuleKind::from_name("bogus"), None);
    }

    #[test]
    fn zero_radius_screens_by_optimal_tests() {
        // With r = 0 and center = theta_hat the tests reduce to Prop. 3.
        // Build a sphere with tiny center correlations: everything screens.
        let pb = toy_problem(1, 0.5);
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = vec![0.0; pb.p()];
        let mut rho = pb.y.clone();
        let sphere = Sphere { xt_center: vec![1e-6; pb.p()], radius: 0.0 };
        let out = apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho);
        assert_eq!(out.groups_screened, pb.n_groups());
        assert_eq!(active.n_active_features(), 0);
        assert_eq!(active.n_active_groups(), 0);
    }

    #[test]
    fn huge_radius_screens_nothing() {
        let pb = toy_problem(2, 0.5);
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = vec![0.0; pb.p()];
        let mut rho = pb.y.clone();
        let sphere = Sphere { xt_center: vec![0.0; pb.p()], radius: 1e9 };
        let out = apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho);
        assert_eq!(out.features_screened, 0);
        assert_eq!(out.groups_screened, 0);
    }

    #[test]
    fn screened_coordinates_are_zeroed_and_residual_patched() {
        let pb = toy_problem(3, 0.6);
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = vec![0.1; pb.p()];
        let xb = pb.x.matvec(&beta);
        let mut rho: Vec<f64> = pb.y.iter().zip(&xb).map(|(y, v)| y - v).collect();
        let sphere = Sphere { xt_center: vec![0.0; pb.p()], radius: 0.0 };
        apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho);
        assert!(beta.iter().all(|&b| b == 0.0));
        // rho must now equal y exactly.
        for (r, y) in rho.iter().zip(&pb.y) {
            assert!((r - y).abs() < 1e-10);
        }
    }

    #[test]
    fn tau_one_disables_group_test_but_keeps_feature_test() {
        let pb = toy_problem(4, 1.0);
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = vec![0.0; pb.p()];
        let mut rho = pb.y.clone();
        // Small correlations: features screen via |xt| + r||Xj|| < tau = 1.
        let sphere = Sphere { xt_center: vec![0.01; pb.p()], radius: 1e-6 };
        let out = apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho);
        assert_eq!(out.features_screened, pb.p());
        // groups become inactive because all their features died
        assert_eq!(active.n_active_groups(), 0);
    }

    #[test]
    fn tau_zero_disables_feature_test() {
        let pb = toy_problem(5, 0.0);
        let mut active = ActiveSet::full(&pb.groups);
        let mut beta = vec![0.0; pb.p()];
        let mut rho = pb.y.clone();
        // tau=0: feature test can never fire; group test uses
        // (||xi||_inf + r||Xg|| - 0)+ < w_g.
        let sphere = Sphere { xt_center: vec![1e-4; pb.p()], radius: 1e-6 };
        let out = apply_sphere(&pb, &sphere, &mut active, &mut beta, &mut rho);
        assert_eq!(out.groups_screened, pb.n_groups());
        assert!(out.features_screened == pb.p());
    }

    #[test]
    fn active_set_bookkeeping() {
        let groups = Groups::from_sizes(&[2, 3]);
        let mut a = ActiveSet::full(&groups);
        assert_eq!(a.n_active_features(), 5);
        assert_eq!(a.n_active_groups(), 2);
        a.feature[3] = false;
        assert_eq!(a.active_in_group(&groups, 1), vec![2, 4]);
    }
}
