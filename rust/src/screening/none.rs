//! No-screening baseline: the plain solver, used as the reference point for
//! the speed-up factors in Fig. 2c / 3b.

use super::{RuleKind, ScreeningRule, Sphere};
use crate::linalg::Design;
use crate::solver::datafit::Datafit;
use crate::solver::duality::DualSnapshot;
use crate::solver::problem::SglProblem;

pub struct NoRule;

impl<D: Design, F: Datafit> ScreeningRule<D, F> for NoRule {
    fn kind(&self) -> RuleKind {
        RuleKind::None
    }

    fn sphere(
        &mut self,
        _pb: &SglProblem<D, F>,
        _lambda: f64,
        _snap: &DualSnapshot,
    ) -> Option<Sphere> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::solver::groups::Groups;

    #[test]
    fn produces_no_sphere() {
        let groups = Groups::from_sizes(&[2]);
        let x = Matrix::from_row_major(&[1.0, 0.0, 0.0, 1.0], 2, 2);
        let pb = SglProblem::new(x, vec![1.0, 2.0], groups, 0.5);
        let snap = DualSnapshot::compute(&pb, &[0.0, 0.0], &pb.y.clone(), 1.0);
        let mut rule: Box<dyn ScreeningRule<Matrix>> = Box::new(NoRule);
        assert!(rule.sphere(&pb, 1.0, &snap).is_none());
        assert_eq!(rule.kind(), RuleKind::None);
    }
}
