//! Run configuration: a TOML-subset parser (serde/toml are unavailable
//! offline) plus typed experiment configs with validation.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! float, integer, boolean and flat-array values, `#` comments. That covers
//! every config this project ships (`configs/*.toml`).

pub mod toml;

use crate::linalg::simd::KernelPolicy;
use crate::screening::RuleKind;
use crate::solver::datafit::FitKind;
use crate::solver::sweep::{SweepMode, SweepTuning};
use crate::solver::SolverKind;
use anyhow::{bail, ensure, Context, Result};
use std::fmt;
use std::path::Path;
use toml::TomlDoc;

/// Which design-matrix backend a run instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DesignBackend {
    /// Column-major dense storage ([`crate::linalg::Matrix`]).
    Dense,
    /// Compressed sparse columns ([`crate::linalg::CscMatrix`]): per-epoch
    /// cost scales with `nnz` instead of `n·p`.
    Csc,
}

impl DesignBackend {
    pub fn name(self) -> &'static str {
        match self {
            DesignBackend::Dense => "dense",
            DesignBackend::Csc => "csc",
        }
    }

    pub fn all() -> [DesignBackend; 2] {
        [DesignBackend::Dense, DesignBackend::Csc]
    }

    pub fn from_name(s: &str) -> Option<DesignBackend> {
        Self::all().into_iter().find(|b| b.name() == s)
    }
}

/// Typed error for an unrecognized `design = "..."` selection. Carried as
/// the payload of the `anyhow` chain so callers (the CLI) can
/// `downcast_ref` it and print the valid backend names.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownBackendError {
    pub given: String,
}

impl fmt::Display for UnknownBackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown design backend {:?}", self.given)
    }
}

impl std::error::Error for UnknownBackendError {}

/// Parse a backend name, preserving the typed error for `downcast_ref`.
pub fn parse_design_backend(name: &str) -> Result<DesignBackend> {
    ensure!(!name.is_empty(), "design backend must not be empty");
    DesignBackend::from_name(name)
        .ok_or_else(|| anyhow::Error::new(UnknownBackendError { given: name.to_string() }))
}

/// Which dataset a run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetChoice {
    Synthetic,
    Climate,
    /// Load `X`/`y` from CSV files with a uniform group size.
    Csv { x_path: String, y_path: String, group_size: usize },
    /// Load a libsvm/svmlight text file straight into the CSC backend
    /// (no dense detour); defaults `design` to `csc` unless overridden.
    Libsvm { path: String, group_size: usize },
}

/// A full solve/experiment configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: DatasetChoice,
    /// Design-matrix backend (`[dataset] design = "dense" | "csc"`).
    pub design: DesignBackend,
    /// Inner solver (`[solver] algo = "cd" | "ista" | "fista"`).
    pub algo: SolverKind,
    /// Loss the path is fit under
    /// (`[solver] datafit = "quadratic" | "logistic" | "multitask"`).
    pub datafit: FitKind,
    /// Response columns `q` for the multi-task datafit (`[solver] tasks`
    /// / `--tasks`). Must be 1 unless `datafit = "multitask"`; the q = 1
    /// multi-task run is bit-identical to the scalar quadratic one.
    pub tasks: usize,
    pub tau: f64,
    pub tol: f64,
    pub fce: usize,
    pub max_epochs: usize,
    pub rule: RuleKind,
    /// Intra-solve epoch mode (`[solver] sweep = "serial" | "parallel"`):
    /// parallel runs work-stealing sweeps over the active-set group
    /// ranges inside every single solve.
    pub sweep: SweepMode,
    /// Worker threads per parallel sweep (`[solver] sweep_threads`,
    /// 0 = auto). Independent of `run.threads` (across-path fan-out).
    pub sweep_threads: usize,
    /// Kernel implementation policy (`[solver] kernels = "auto" | "scalar"
    /// | "simd"`, `--kernels`): `scalar` is bit-identical to the pre-SIMD
    /// solver, `simd` agrees to ≤ 1e-12 relative per kernel, `auto`
    /// resolves via `SGL_KERNELS` (default simd). Process-global, applied
    /// by the CLI via [`crate::linalg::simd::set_policy`].
    pub kernels: KernelPolicy,
    /// Per-worker engage floor for the parallel `Xᵀv` sweeps
    /// (`[solver] xt_floor`).
    pub sweep_xt_floor: usize,
    /// Per-worker engage floor for the row-partitioned residual kernels
    /// (`[solver] residual_floor`).
    pub sweep_residual_floor: usize,
    /// Per-worker engage floor for the parallel dual-norm sweep
    /// (`[solver] omega_dual_floor`).
    pub sweep_omega_dual_floor: usize,
    /// Per-worker engage floor for the ISTA/FISTA prox sweeps
    /// (`[solver] prox_floor`).
    pub sweep_prox_floor: usize,
    /// Per-worker group floor below which parallel CD falls back to the
    /// serial cyclic sweep (`[solver] cd_floor`).
    pub sweep_cd_floor: usize,
    /// Simultaneous block updates per round and worker in the parallel CD
    /// epoch (`[solver] groups_per_round`).
    pub sweep_groups_per_round: usize,
    /// λ-path: `λ_t = λ_max 10^{-δt/(T-1)}`.
    pub delta: f64,
    pub t_count: usize,
    pub seed: u64,
    pub threads: usize,
    /// Synthetic-dataset overrides.
    pub synth_n: usize,
    pub synth_groups: usize,
    pub synth_group_size: usize,
    pub synth_rho: f64,
    pub synth_gamma1: usize,
    pub synth_gamma2: usize,
    /// Climate-dataset overrides.
    pub climate_lon: usize,
    pub climate_lat: usize,
    pub climate_months: usize,
    /// Solve-service sizing (`[service]`): worker threads (0 = auto).
    pub service_workers: usize,
    /// Max queued (unstarted) jobs before `submit` backpressures.
    pub service_queue_depth: usize,
    /// λ-range shards per path job submitted by the CLI (1 = monolithic).
    pub service_shards: usize,
    /// Max terminal jobs retained by the service's result store before
    /// the oldest retrieved ones are reaped (`[service] result_capacity`).
    pub service_result_capacity: usize,
    /// Max entries in the service's fingerprint cache before LRU
    /// eviction (`[service] cache_capacity`).
    pub service_cache_capacity: usize,
    /// Remote worker addresses (`[service] fleet = "host:port,host:port"`
    /// / `--fleet`). Empty = solve in-process; non-empty = the service
    /// drains shards into a `coordinator::remote::RemoteFleet`.
    pub service_fleet: Vec<String>,
    /// Connections (= concurrent shards) opened per fleet worker
    /// (`[service] fleet_conns`).
    pub service_fleet_conns: usize,
    /// Datasets whose wire encoding exceeds this many MiB ship to fleet
    /// workers as chunked column-range frames instead of one monolithic
    /// frame (`[service] fleet_chunk_mb` / `--fleet-chunk-mb`).
    pub service_fleet_chunk_mb: usize,
    /// Milliseconds a fleet exchange may go without *any* frame (reply or
    /// progress ping) before the worker is written off and the shard
    /// requeued (`[service] progress_deadline_ms` /
    /// `--progress-deadline-ms`). 0 disables the deadline.
    pub service_progress_deadline_ms: u64,
    /// Milliseconds a shard waits for a worker to rejoin (via the
    /// registration listener) when the whole fleet is dead, before the
    /// batch fails (`[service] rejoin_grace_ms` / `--rejoin-grace-ms`).
    /// 0 fails immediately.
    pub service_rejoin_grace_ms: u64,
    /// Registration listener address (`[service] register_addr` /
    /// `--register-addr`): restarted `sgl worker --register` processes
    /// announce themselves here to rejoin the fleet.
    pub service_register_addr: Option<String>,
    /// Chrome trace-event output path (`[trace] out` / `--trace-out` /
    /// `SGL_TRACE`). `None` leaves the collector disabled — solver output
    /// is bit-identical either way ([`crate::util::trace`]'s contract).
    pub trace_out: Option<String>,
    /// Sampling divisor for high-frequency trace sites (`[trace] sample`
    /// / `--trace-sample`): record every k-th gap-check event, 1 = all.
    pub trace_sample: u64,
    /// Prometheus text-exposition listen address
    /// (`[service] metrics_addr` / `--metrics-addr`): `sgl serve` answers
    /// HTTP GETs on it with the coordinator registry's `render_text`.
    pub metrics_addr: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: DatasetChoice::Synthetic,
            design: DesignBackend::Dense,
            algo: SolverKind::Cd,
            datafit: FitKind::Quadratic,
            tasks: 1,
            tau: 0.2,
            tol: 1e-8,
            fce: 10,
            max_epochs: 20_000,
            rule: RuleKind::GapSafe,
            sweep: SweepMode::Serial,
            sweep_threads: 0, // 0 = auto
            kernels: KernelPolicy::Auto,
            sweep_xt_floor: SweepTuning::default().xt_floor,
            sweep_residual_floor: SweepTuning::default().residual_floor,
            sweep_omega_dual_floor: SweepTuning::default().omega_dual_floor,
            sweep_prox_floor: SweepTuning::default().prox_floor,
            sweep_cd_floor: SweepTuning::default().cd_floor,
            sweep_groups_per_round: SweepTuning::default().groups_per_round,
            delta: 3.0,
            t_count: 100,
            seed: 42,
            threads: 0, // 0 = auto
            synth_n: 100,
            synth_groups: 1000,
            synth_group_size: 10,
            synth_rho: 0.5,
            synth_gamma1: 10,
            synth_gamma2: 4,
            climate_lon: 37,
            climate_lat: 18,
            climate_months: 814,
            service_workers: 0, // 0 = auto
            service_queue_depth: 64,
            service_shards: 1,
            service_result_capacity: 1024,
            service_cache_capacity: 256,
            service_fleet: Vec::new(),
            service_fleet_conns: 1,
            service_fleet_chunk_mb: 1024,
            service_progress_deadline_ms: 0,
            service_rejoin_grace_ms: 0,
            service_register_addr: None,
            trace_out: None,
            trace_sample: 1,
            metrics_addr: None,
        }
    }
}

/// Parse a comma-separated `host:port` list (the `--fleet` / `[service]
/// fleet` value). Whitespace around entries is ignored; every entry must
/// name a port.
pub fn parse_fleet_list(s: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        ensure!(
            part.contains(':'),
            "fleet worker {part:?} is not a host:port address"
        );
        out.push(part.to_string());
    }
    ensure!(!out.is_empty(), "fleet list {s:?} names no workers");
    Ok(out)
}

impl RunConfig {
    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(name) = doc.get_str("dataset", "kind") {
            cfg.dataset = match name.as_str() {
                "synthetic" => DatasetChoice::Synthetic,
                "climate" => DatasetChoice::Climate,
                "csv" => DatasetChoice::Csv {
                    x_path: doc
                        .get_str("dataset", "x_path")
                        .context("csv dataset requires dataset.x_path")?,
                    y_path: doc
                        .get_str("dataset", "y_path")
                        .context("csv dataset requires dataset.y_path")?,
                    group_size: doc.get_int("dataset", "group_size").unwrap_or(1) as usize,
                },
                "libsvm" => {
                    // Sparse loaders default to the CSC backend; an
                    // explicit `design` key below still wins.
                    cfg.design = DesignBackend::Csc;
                    DatasetChoice::Libsvm {
                        path: doc
                            .get_str("dataset", "path")
                            .context("libsvm dataset requires dataset.path")?,
                        group_size: doc.get_int("dataset", "group_size").unwrap_or(1)
                            as usize,
                    }
                }
                other => bail!("unknown dataset kind {other:?}"),
            };
        }
        if let Some(d) = doc.get_str("dataset", "design") {
            cfg.design = parse_design_backend(&d)
                .with_context(|| format!("parsing dataset.design = {d:?}"))?;
        }
        if let Some(a) = doc.get_str("solver", "algo") {
            cfg.algo = SolverKind::from_name(&a)
                .with_context(|| format!("unknown solver algo {a:?} (cd|ista|fista)"))?;
        }
        macro_rules! take {
            ($field:ident, $sect:expr, $key:expr, f64) => {
                if let Some(v) = doc.get_f64($sect, $key) {
                    cfg.$field = v;
                }
            };
            ($field:ident, $sect:expr, $key:expr, usize) => {
                if let Some(v) = doc.get_int($sect, $key) {
                    cfg.$field = v as usize;
                }
            };
            ($field:ident, $sect:expr, $key:expr, u64) => {
                if let Some(v) = doc.get_int($sect, $key) {
                    cfg.$field = v as u64;
                }
            };
        }
        take!(tasks, "solver", "tasks", usize);
        take!(tau, "solver", "tau", f64);
        take!(tol, "solver", "tol", f64);
        take!(fce, "solver", "fce", usize);
        take!(max_epochs, "solver", "max_epochs", usize);
        take!(delta, "path", "delta", f64);
        take!(t_count, "path", "t_count", usize);
        take!(seed, "run", "seed", u64);
        take!(threads, "run", "threads", usize);
        take!(synth_n, "synthetic", "n", usize);
        take!(synth_groups, "synthetic", "n_groups", usize);
        take!(synth_group_size, "synthetic", "group_size", usize);
        take!(synth_rho, "synthetic", "rho", f64);
        take!(synth_gamma1, "synthetic", "gamma1", usize);
        take!(synth_gamma2, "synthetic", "gamma2", usize);
        take!(climate_lon, "climate", "grid_lon", usize);
        take!(climate_lat, "climate", "grid_lat", usize);
        take!(climate_months, "climate", "n_months", usize);
        take!(sweep_threads, "solver", "sweep_threads", usize);
        take!(sweep_xt_floor, "solver", "xt_floor", usize);
        take!(sweep_residual_floor, "solver", "residual_floor", usize);
        take!(sweep_omega_dual_floor, "solver", "omega_dual_floor", usize);
        take!(sweep_prox_floor, "solver", "prox_floor", usize);
        take!(sweep_cd_floor, "solver", "cd_floor", usize);
        take!(sweep_groups_per_round, "solver", "groups_per_round", usize);
        take!(service_workers, "service", "workers", usize);
        take!(service_queue_depth, "service", "queue_depth", usize);
        take!(service_shards, "service", "shards", usize);
        take!(service_result_capacity, "service", "result_capacity", usize);
        take!(service_cache_capacity, "service", "cache_capacity", usize);
        take!(service_fleet_conns, "service", "fleet_conns", usize);
        take!(service_fleet_chunk_mb, "service", "fleet_chunk_mb", usize);
        take!(service_progress_deadline_ms, "service", "progress_deadline_ms", u64);
        take!(service_rejoin_grace_ms, "service", "rejoin_grace_ms", u64);
        take!(trace_sample, "trace", "sample", u64);
        if let Some(out) = doc.get_str("trace", "out") {
            cfg.trace_out = Some(out);
        }
        if let Some(addr) = doc.get_str("service", "metrics_addr") {
            cfg.metrics_addr = Some(addr);
        }
        if let Some(addr) = doc.get_str("service", "register_addr") {
            cfg.service_register_addr = Some(addr);
        }
        if let Some(fleet) = doc.get_str("service", "fleet") {
            cfg.service_fleet =
                parse_fleet_list(&fleet).context("parsing service.fleet")?;
        }
        if let Some(rule) = doc.get_str("solver", "rule") {
            cfg.rule = RuleKind::from_name(&rule)
                .with_context(|| format!("unknown screening rule {rule:?}"))?;
        }
        if let Some(df) = doc.get_str("solver", "datafit") {
            cfg.datafit = FitKind::from_name(&df).with_context(|| {
                format!("unknown datafit {df:?} (quadratic|logistic|multitask)")
            })?;
        }
        if let Some(sweep) = doc.get_str("solver", "sweep") {
            cfg.sweep = SweepMode::from_name(&sweep)
                .with_context(|| format!("unknown sweep mode {sweep:?} (serial|parallel)"))?;
        }
        if let Some(kernels) = doc.get_str("solver", "kernels") {
            cfg.kernels = KernelPolicy::from_name(&kernels).with_context(|| {
                format!("unknown kernel policy {kernels:?} (auto|scalar|simd)")
            })?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.tau) {
            bail!("tau must be in [0, 1], got {}", self.tau);
        }
        if self.tol <= 0.0 {
            bail!("tol must be positive");
        }
        if self.fce == 0 {
            bail!("fce must be >= 1");
        }
        if self.t_count == 0 {
            bail!("t_count must be >= 1");
        }
        if self.delta < 0.0 {
            bail!("delta must be nonnegative");
        }
        // The static/dynamic/DST3 spheres hard-code the least-squares
        // dual geometry (`make_rule` would panic mid-path): reject the
        // combination at config time instead.
        if self.datafit == FitKind::Logistic
            && !matches!(
                self.rule,
                RuleKind::None | RuleKind::GapSafe | RuleKind::GapSafeSeq
            )
        {
            bail!(
                "screening rule {:?} is least-squares only; logistic runs take \
                 none|gap_safe|gap_safe_seq",
                self.rule.name()
            );
        }
        if self.tasks == 0 {
            bail!("tasks must be >= 1");
        }
        // A widened response needs the matrix-valued datafit; a scalar
        // loss silently reading a task-major y would misalign the rows.
        if self.tasks > 1 && self.datafit != FitKind::MultiTask {
            bail!(
                "tasks = {} requires datafit = \"multitask\" (got {:?})",
                self.tasks,
                self.datafit.name()
            );
        }
        if self.service_queue_depth == 0 {
            bail!("service queue_depth must be >= 1");
        }
        if self.service_shards == 0 {
            bail!("service shards must be >= 1");
        }
        if self.service_result_capacity == 0 {
            bail!("service result_capacity must be >= 1");
        }
        if self.service_cache_capacity == 0 {
            bail!("service cache_capacity must be >= 1");
        }
        if self.service_fleet_conns == 0 {
            bail!("service fleet_conns must be >= 1");
        }
        if self.service_fleet_chunk_mb == 0 {
            bail!("service fleet_chunk_mb must be >= 1");
        }
        if let Some(addr) = &self.service_register_addr {
            if !addr.contains(':') {
                bail!("service register_addr {addr:?} is not a host:port address");
            }
        }
        if self.trace_sample == 0 {
            bail!("trace sample must be >= 1 (record every k-th event)");
        }
        if let Some(out) = &self.trace_out {
            if out.is_empty() {
                bail!("trace out must be a non-empty path");
            }
        }
        if let Some(addr) = &self.metrics_addr {
            if !addr.contains(':') {
                bail!("service metrics_addr must be host:port, got {addr:?}");
            }
        }
        if let DatasetChoice::Libsvm { group_size, .. } = &self.dataset {
            if *group_size == 0 {
                bail!("libsvm group_size must be >= 1");
            }
        }
        for (name, v) in [
            ("xt_floor", self.sweep_xt_floor),
            ("residual_floor", self.sweep_residual_floor),
            ("omega_dual_floor", self.sweep_omega_dual_floor),
            ("prox_floor", self.sweep_prox_floor),
            ("cd_floor", self.sweep_cd_floor),
            ("groups_per_round", self.sweep_groups_per_round),
        ] {
            if v == 0 {
                bail!("solver {name} must be >= 1");
            }
        }
        Ok(())
    }

    /// `threads` with `0 = auto` resolved to the machine default, so no
    /// caller can ever size a zero-worker pool from the raw field.
    pub fn effective_threads(&self) -> usize {
        crate::util::pool::resolve_threads(self.threads)
    }

    /// The `[solver]` floor knobs packed into the struct
    /// [`SolveOptions`](crate::solver::cd::SolveOptions) carries.
    pub fn sweep_tuning(&self) -> SweepTuning {
        SweepTuning {
            xt_floor: self.sweep_xt_floor,
            residual_floor: self.sweep_residual_floor,
            omega_dual_floor: self.sweep_omega_dual_floor,
            prox_floor: self.sweep_prox_floor,
            cd_floor: self.sweep_cd_floor,
            groups_per_round: self.sweep_groups_per_round,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_defaults() {
        let c = RunConfig::default();
        assert_eq!(c.fce, 10);
        assert_eq!(c.t_count, 100);
        assert_eq!(c.delta, 3.0);
        assert_eq!(c.rule, RuleKind::GapSafe);
        c.validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# experiment config
[dataset]
kind = "synthetic"

[solver]
tau = 0.4
tol = 1e-6
rule = "dst3"
fce = 5

[path]
delta = 2.5
t_count = 50

[run]
seed = 7
threads = 4

[synthetic]
n = 50
n_groups = 20
group_size = 5
rho = 0.9
"#;
        let c = RunConfig::from_toml_str(text).unwrap();
        assert_eq!(c.tau, 0.4);
        assert_eq!(c.tol, 1e-6);
        assert_eq!(c.rule, RuleKind::Dst3);
        assert_eq!(c.fce, 5);
        assert_eq!(c.delta, 2.5);
        assert_eq!(c.t_count, 50);
        assert_eq!(c.seed, 7);
        assert_eq!(c.synth_n, 50);
        assert_eq!(c.synth_rho, 0.9);
    }

    #[test]
    fn csv_dataset_requires_paths() {
        let text = "[dataset]\nkind = \"csv\"\n";
        assert!(RunConfig::from_toml_str(text).is_err());
        let ok = "[dataset]\nkind = \"csv\"\nx_path = \"x.csv\"\ny_path = \"y.csv\"\ngroup_size = 3\n";
        let c = RunConfig::from_toml_str(ok).unwrap();
        assert_eq!(
            c.dataset,
            DatasetChoice::Csv {
                x_path: "x.csv".into(),
                y_path: "y.csv".into(),
                group_size: 3
            }
        );
    }

    #[test]
    fn parses_design_backend_and_algo() {
        let c = RunConfig::from_toml_str(
            "[dataset]\nkind = \"synthetic\"\ndesign = \"csc\"\n[solver]\nalgo = \"fista\"\n",
        )
        .unwrap();
        assert_eq!(c.design, DesignBackend::Csc);
        assert_eq!(c.algo, SolverKind::Fista);
        // Default stays dense/cd.
        let d = RunConfig::default();
        assert_eq!(d.design, DesignBackend::Dense);
        assert_eq!(d.algo, SolverKind::Cd);
    }

    #[test]
    fn unknown_backend_is_a_typed_downcastable_error() {
        let err = RunConfig::from_toml_str("[dataset]\ndesign = \"coo\"\n").unwrap_err();
        let ub = err
            .downcast_ref::<UnknownBackendError>()
            .expect("typed payload must survive the context chain");
        assert_eq!(ub.given, "coo");
        // And the human-readable chain still mentions the context.
        assert!(format!("{err:#}").contains("dataset.design"));
        assert!(RunConfig::from_toml_str("[solver]\nalgo = \"sgd\"\n").is_err());
    }

    #[test]
    fn parses_sequential_rule() {
        let c = RunConfig::from_toml_str("[solver]\nrule = \"gap_safe_seq\"\n").unwrap();
        assert_eq!(c.rule, RuleKind::GapSafeSeq);
    }

    #[test]
    fn parses_datafit_and_gates_quadratic_only_rules() {
        let c = RunConfig::from_toml_str("[solver]\ndatafit = \"logistic\"\n").unwrap();
        assert_eq!(c.datafit, FitKind::Logistic);
        // Default stays quadratic.
        assert_eq!(RunConfig::default().datafit, FitKind::Quadratic);
        // Logistic works with the gap rules and the no-screening baseline…
        for rule in ["none", "gap_safe", "gap_safe_seq"] {
            let text = format!("[solver]\ndatafit = \"logistic\"\nrule = \"{rule}\"\n");
            assert!(RunConfig::from_toml_str(&text).is_ok(), "{rule}");
        }
        // …but the least-squares-only spheres are rejected at parse time.
        for rule in ["static", "dynamic", "dst3"] {
            let text = format!("[solver]\ndatafit = \"logistic\"\nrule = \"{rule}\"\n");
            let err = RunConfig::from_toml_str(&text).unwrap_err();
            assert!(format!("{err:#}").contains("least-squares only"), "{rule}: {err:#}");
        }
        assert!(RunConfig::from_toml_str("[solver]\ndatafit = \"poisson\"\n").is_err());
    }

    #[test]
    fn parses_multitask_datafit_and_tasks() {
        let c = RunConfig::from_toml_str("[solver]\ndatafit = \"multitask\"\ntasks = 4\n")
            .unwrap();
        assert_eq!(c.datafit, FitKind::MultiTask);
        assert_eq!(c.tasks, 4);
        // q = 1 multi-task is valid (the bit-identity configuration), and
        // the scalar default stays tasks = 1.
        let one = RunConfig::from_toml_str("[solver]\ndatafit = \"multitask\"\n").unwrap();
        assert_eq!(one.tasks, 1);
        assert_eq!(RunConfig::default().tasks, 1);
        // The multi-task dual geometry is quadratic, so every rule is
        // admissible — unlike logistic.
        for rule in ["none", "static", "dynamic", "dst3", "gap_safe", "gap_safe_seq"] {
            let text =
                format!("[solver]\ndatafit = \"multitask\"\ntasks = 2\nrule = \"{rule}\"\n");
            assert!(RunConfig::from_toml_str(&text).is_ok(), "{rule}");
        }
        // A widened response without the multi-task datafit is rejected.
        for df in ["quadratic", "logistic"] {
            let text = format!("[solver]\ndatafit = \"{df}\"\ntasks = 3\n");
            let err = RunConfig::from_toml_str(&text).unwrap_err();
            assert!(format!("{err:#}").contains("multitask"), "{df}: {err:#}");
        }
        assert!(RunConfig::from_toml_str("[solver]\ntasks = 0\n").is_err());
    }

    #[test]
    fn parses_sweep_mode_and_threads() {
        let c = RunConfig::from_toml_str(
            "[solver]\nsweep = \"parallel\"\nsweep_threads = 3\n",
        )
        .unwrap();
        assert_eq!(c.sweep, SweepMode::Parallel);
        assert_eq!(c.sweep_threads, 3);
        // Defaults: serial sweeps, auto threads.
        let d = RunConfig::default();
        assert_eq!(d.sweep, SweepMode::Serial);
        assert_eq!(d.sweep_threads, 0);
        // Unknown modes are rejected with the valid choices named.
        let err = RunConfig::from_toml_str("[solver]\nsweep = \"jacobi\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("serial|parallel"));
    }

    #[test]
    fn parses_kernel_policy_and_sweep_tuning() {
        let c = RunConfig::from_toml_str(
            "[solver]\nkernels = \"scalar\"\nxt_floor = 128\ngroups_per_round = 2\n",
        )
        .unwrap();
        assert_eq!(c.kernels, KernelPolicy::Scalar);
        assert_eq!(c.sweep_tuning().xt_floor, 128);
        assert_eq!(c.sweep_tuning().groups_per_round, 2);
        // Defaults: auto policy, the floors the kernels shipped with.
        let d = RunConfig::default();
        assert_eq!(d.kernels, KernelPolicy::Auto);
        assert_eq!(d.sweep_tuning(), SweepTuning::default());
        // Bad values are rejected with the valid choices named.
        let err = RunConfig::from_toml_str("[solver]\nkernels = \"avx\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("auto|scalar|simd"));
        assert!(RunConfig::from_toml_str("[solver]\ncd_floor = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[solver]\ngroups_per_round = 0\n").is_err());
    }

    #[test]
    fn parses_service_capacities() {
        let c = RunConfig::from_toml_str(
            "[service]\nresult_capacity = 16\ncache_capacity = 8\n",
        )
        .unwrap();
        assert_eq!(c.service_result_capacity, 16);
        assert_eq!(c.service_cache_capacity, 8);
        let d = RunConfig::default();
        assert_eq!(d.service_result_capacity, 1024);
        assert_eq!(d.service_cache_capacity, 256);
        assert!(RunConfig::from_toml_str("[service]\nresult_capacity = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\ncache_capacity = 0\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(RunConfig::from_toml_str("[solver]\ntau = 1.5\n").is_err());
        assert!(RunConfig::from_toml_str("[solver]\nrule = \"magic\"\n").is_err());
        assert!(RunConfig::from_toml_str("[solver]\ntol = -1.0\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\nqueue_depth = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\nshards = 0\n").is_err());
    }

    #[test]
    fn parses_service_section() {
        let c = RunConfig::from_toml_str(
            "[service]\nworkers = 3\nqueue_depth = 128\nshards = 4\n",
        )
        .unwrap();
        assert_eq!(c.service_workers, 3);
        assert_eq!(c.service_queue_depth, 128);
        assert_eq!(c.service_shards, 4);
        // Defaults: auto workers, depth 64, monolithic paths.
        let d = RunConfig::default();
        assert_eq!(d.service_workers, 0);
        assert_eq!(d.service_queue_depth, 64);
        assert_eq!(d.service_shards, 1);
        assert!(d.effective_threads() >= 1);
    }

    #[test]
    fn parses_fleet_addresses() {
        let c = RunConfig::from_toml_str(
            "[service]\nfleet = \"10.0.0.1:7171, 10.0.0.2:7171\"\nfleet_conns = 2\n",
        )
        .unwrap();
        assert_eq!(
            c.service_fleet,
            vec!["10.0.0.1:7171".to_string(), "10.0.0.2:7171".to_string()]
        );
        assert_eq!(c.service_fleet_conns, 2);
        // Defaults: no fleet (local execution), one connection per worker.
        let d = RunConfig::default();
        assert!(d.service_fleet.is_empty());
        assert_eq!(d.service_fleet_conns, 1);
        // Port-less entries and empty lists are rejected.
        assert!(RunConfig::from_toml_str("[service]\nfleet = \"nohost\"\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\nfleet = \" , \"\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\nfleet_conns = 0\n").is_err());
        assert!(parse_fleet_list("a:1,,b:2").unwrap().len() == 2);
    }

    #[test]
    fn parses_elastic_fleet_knobs() {
        let c = RunConfig::from_toml_str(
            "[service]\nfleet_chunk_mb = 64\nprogress_deadline_ms = 2000\n\
             rejoin_grace_ms = 5000\nregister_addr = \"0.0.0.0:7272\"\n",
        )
        .unwrap();
        assert_eq!(c.service_fleet_chunk_mb, 64);
        assert_eq!(c.service_progress_deadline_ms, 2000);
        assert_eq!(c.service_rejoin_grace_ms, 5000);
        assert_eq!(c.service_register_addr.as_deref(), Some("0.0.0.0:7272"));
        // Defaults: 1 GiB chunk threshold, both elasticity timers off, no
        // registration listener.
        let d = RunConfig::default();
        assert_eq!(d.service_fleet_chunk_mb, 1024);
        assert_eq!(d.service_progress_deadline_ms, 0);
        assert_eq!(d.service_rejoin_grace_ms, 0);
        assert!(d.service_register_addr.is_none());
        assert!(RunConfig::from_toml_str("[service]\nfleet_chunk_mb = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[service]\nregister_addr = \"nohost\"\n").is_err());
    }

    #[test]
    fn parses_trace_and_metrics_endpoint() {
        let c = RunConfig::from_toml_str(
            "[trace]\nout = \"solve.trace.json\"\nsample = 4\n\
             [service]\nmetrics_addr = \"127.0.0.1:9next\"\n",
        );
        // `:9next` still has a colon, so validate accepts it — binding
        // decides the real fate; the parser only rejects port-less addrs.
        let c = c.unwrap();
        assert_eq!(c.trace_out.as_deref(), Some("solve.trace.json"));
        assert_eq!(c.trace_sample, 4);
        assert_eq!(c.metrics_addr.as_deref(), Some("127.0.0.1:9next"));
        // Defaults: tracing off, every event, no endpoint.
        let d = RunConfig::default();
        assert!(d.trace_out.is_none());
        assert_eq!(d.trace_sample, 1);
        assert!(d.metrics_addr.is_none());
        // Degenerate values are rejected at parse time.
        assert!(RunConfig::from_toml_str("[trace]\nsample = 0\n").is_err());
        assert!(RunConfig::from_toml_str("[trace]\nout = \"\"\n").is_err());
        assert!(
            RunConfig::from_toml_str("[service]\nmetrics_addr = \"noport\"\n").is_err()
        );
    }

    #[test]
    fn libsvm_dataset_defaults_to_csc() {
        let c = RunConfig::from_toml_str(
            "[dataset]\nkind = \"libsvm\"\npath = \"d.svm\"\ngroup_size = 5\n",
        )
        .unwrap();
        assert_eq!(
            c.dataset,
            DatasetChoice::Libsvm { path: "d.svm".into(), group_size: 5 }
        );
        assert_eq!(c.design, DesignBackend::Csc);
        // An explicit design key still wins.
        let d = RunConfig::from_toml_str(
            "[dataset]\nkind = \"libsvm\"\npath = \"d.svm\"\ndesign = \"dense\"\n",
        )
        .unwrap();
        assert_eq!(d.design, DesignBackend::Dense);
        // Missing path and zero group size are rejected.
        assert!(RunConfig::from_toml_str("[dataset]\nkind = \"libsvm\"\n").is_err());
        assert!(RunConfig::from_toml_str(
            "[dataset]\nkind = \"libsvm\"\npath = \"d.svm\"\ngroup_size = 0\n"
        )
        .is_err());
    }
}
