//! TOML-subset parser: `[section]`, `key = value` (string / float / int /
//! bool / flat array), `#` comments. Enough for `configs/*.toml`; anything
//! fancier is a parse error, never a silent misread.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// A parsed document: `(section, key) -> value`. Keys before any section
/// header live in section `""`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let Some(name) = inner.strip_suffix(']') else {
                    bail!("line {}: malformed section header {raw:?}", lineno + 1);
                };
                section = name.trim().to_string();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {raw:?}", lineno + 1);
            };
            let key = key.trim().to_string();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(val.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            doc.entries.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key) {
            Some(TomlValue::Str(s)) => Some(s.clone()),
            _ => None,
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(TomlValue::Float(v)) => Some(*v),
            Some(TomlValue::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(TomlValue::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(TomlValue::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_f64_array(&self, section: &str, key: &str) -> Option<Vec<f64>> {
        match self.get(section, key) {
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Float(f) => Some(*f),
                    TomlValue::Int(i) => Some(*i as f64),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str) -> Result<TomlValue> {
    if tok.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = tok.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("unterminated string {tok:?}");
        };
        return Ok(TomlValue::Str(s.to_string()));
    }
    if tok == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if tok == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = tok.strip_prefix('[') {
        let Some(body) = inner.strip_suffix(']') else {
            bail!("unterminated array {tok:?}");
        };
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items: Result<Vec<TomlValue>> =
            body.split(',').map(|t| parse_value(t.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    // Integer first (no '.', 'e', 'E'), then float.
    if !tok.contains(['.', 'e', 'E']) {
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = tok.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {tok:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
top = 1
[a]
s = "hello"   # trailing comment
f = 2.5
i = -3
b = true
arr = [1, 2.5, 3]
[b]
e = 1e-8
"#,
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "s"), Some("hello".into()));
        assert_eq!(doc.get_f64("a", "f"), Some(2.5));
        assert_eq!(doc.get_int("a", "i"), Some(-3));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_f64_array("a", "arr"), Some(vec![1.0, 2.5, 3.0]));
        assert_eq!(doc.get_f64("b", "e"), Some(1e-8));
    }

    #[test]
    fn int_coerces_to_f64_not_reverse() {
        let doc = TomlDoc::parse("x = 2\ny = 2.0\n").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(2.0));
        assert_eq!(doc.get_int("", "y"), None);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b".into()));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(TomlDoc::parse("[oops\n").is_err());
        assert!(TomlDoc::parse("just words\n").is_err());
        assert!(TomlDoc::parse("k = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("k = [1, 2\n").is_err());
        assert!(TomlDoc::parse("k = zzz\n").is_err());
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert!(doc.get("b", "x").is_none());
        assert!(doc.get_str("a", "x").is_none()); // wrong type
    }
}
