//! Figure 2: the synthetic-data experiments (§7.1, ρ=0.5, γ₁=10, γ₂=4,
//! τ=0.2).
//!
//! - **2a** — proportion of active *features* as a function of `λ_t` and
//!   the epoch budget `K`;
//! - **2b** — same at the *group* level;
//! - **2c** — wall-clock to solve the whole path vs target duality gap,
//!   for every screening rule.

use crate::coordinator::jobs::{run_rule_comparison, RuleComparisonJob, RuleTiming};
use crate::data::synthetic::{generate, SyntheticConfig};
use crate::linalg::Design;
use crate::screening::make_rule;
use crate::screening::RuleKind;
use crate::solver::cd::{solve_with_rule, SolveOptions};
use crate::solver::datafit::{Datafit, Logistic};
use crate::solver::problem::SglProblem;

/// Active-proportion surfaces for Fig. 2a/2b.
#[derive(Clone, Debug)]
pub struct ActiveSurface {
    pub lambdas: Vec<f64>,
    /// Epoch budgets (the K axis).
    pub k_values: Vec<usize>,
    /// `fractions[k_idx][lambda_idx]` — active fraction after at most K
    /// epochs.
    pub feature_fractions: Vec<Vec<f64>>,
    pub group_fractions: Vec<Vec<f64>>,
}

/// Fig. 2a/2b: solve the path once per epoch budget K and record the
/// final active proportions per λ.
pub fn active_surfaces(
    cfg: &SyntheticConfig,
    tau: f64,
    delta: f64,
    t_count: usize,
    k_values: &[usize],
    fce: usize,
) -> ActiveSurface {
    let data = generate(cfg);
    let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, tau);
    active_surfaces_on(&pb, delta, t_count, k_values, fce)
}

/// The Fig. 2a/2b protocol on a sparse-group *logistic* path: the same
/// synthetic design with the target binarized at its mean. The GAP safe
/// sphere is the only rule the logistic dual admits, so this is the
/// rejection-rate figure for the classification datafit.
pub fn logistic_active_surfaces(
    cfg: &SyntheticConfig,
    tau: f64,
    delta: f64,
    t_count: usize,
    k_values: &[usize],
    fce: usize,
) -> ActiveSurface {
    let data = generate(cfg);
    let mean = data.dataset.y.iter().sum::<f64>() / data.dataset.y.len() as f64;
    let labels: Vec<f64> = data.dataset.y.iter().map(|&v| f64::from(v > mean)).collect();
    let weights = data.dataset.groups.sqrt_size_weights();
    let pb = SglProblem::with_datafit(
        data.dataset.x,
        labels,
        data.dataset.groups,
        tau,
        weights,
        Logistic,
    );
    active_surfaces_on(&pb, delta, t_count, k_values, fce)
}

/// Shared surface protocol over an already-built problem (any backend,
/// any datafit — the GAP safe sphere works for all of them).
pub fn active_surfaces_on<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    delta: f64,
    t_count: usize,
    k_values: &[usize],
    fce: usize,
) -> ActiveSurface {
    let lambda_max = pb.lambda_max();
    let lambdas = SglProblem::lambda_grid(lambda_max, delta, t_count);
    let p = pb.p() as f64;
    let n_g = pb.n_groups() as f64;

    let mut feature_fractions = Vec::with_capacity(k_values.len());
    let mut group_fractions = Vec::with_capacity(k_values.len());
    for &k in k_values {
        let mut rule = make_rule(RuleKind::GapSafe, pb);
        let opts = SolveOptions {
            tol: 0.0, // never stop early: K is the budget under study
            max_epochs: k,
            fce,
            rule: RuleKind::GapSafe,
            record_history: false,
            ..Default::default()
        };
        let mut warm: Option<Vec<f64>> = None;
        let mut feats = Vec::with_capacity(lambdas.len());
        let mut groups = Vec::with_capacity(lambdas.len());
        for &lambda in &lambdas {
            let res = solve_with_rule(pb, lambda, warm.as_deref(), &opts, rule.as_mut());
            warm = Some(res.beta.clone());
            feats.push(res.active.n_active_features() as f64 / p);
            groups.push(res.active.n_active_groups() as f64 / n_g);
        }
        feature_fractions.push(feats);
        group_fractions.push(groups);
    }
    ActiveSurface { lambdas, k_values: k_values.to_vec(), feature_fractions, group_fractions }
}

/// Fig. 2c: time-to-converge per rule per tolerance on the synthetic path.
pub fn rule_timings(
    cfg: &SyntheticConfig,
    tau: f64,
    job: &RuleComparisonJob,
    threads: usize,
) -> Vec<RuleTiming> {
    let data = generate(cfg);
    let pb = SglProblem::new(data.dataset.x, data.dataset.y, data.dataset.groups, tau);
    run_rule_comparison(std::sync::Arc::new(pb), job, threads, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> SyntheticConfig {
        SyntheticConfig {
            n: 40,
            n_groups: 15,
            group_size: 4,
            gamma1: 3,
            gamma2: 2,
            seed: 3,
            ..Default::default()
        }
    }

    #[test]
    fn surfaces_have_expected_shape_properties() {
        let surf = active_surfaces(&tiny_cfg(), 0.2, 2.0, 8, &[10, 100], 10);
        assert_eq!(surf.feature_fractions.len(), 2);
        assert_eq!(surf.feature_fractions[0].len(), 8);
        // More epochs => weakly fewer active variables at every lambda
        // (smaller gap => smaller safe sphere).
        for li in 0..8 {
            assert!(
                surf.feature_fractions[1][li] <= surf.feature_fractions[0][li] + 1e-12,
                "lambda {li}: K=100 {} vs K=10 {}",
                surf.feature_fractions[1][li],
                surf.feature_fractions[0][li]
            );
            assert!(surf.group_fractions[1][li] <= surf.group_fractions[0][li] + 1e-12);
        }
        // Fractions are valid proportions.
        for row in surf.feature_fractions.iter().chain(&surf.group_fractions) {
            assert!(row.iter().all(|&f| (0.0..=1.0).contains(&f)));
        }
    }

    #[test]
    fn logistic_surfaces_screen_and_stay_valid() {
        let surf = logistic_active_surfaces(&tiny_cfg(), 0.2, 2.0, 8, &[10, 100], 10);
        assert_eq!(surf.feature_fractions.len(), 2);
        assert_eq!(surf.feature_fractions[0].len(), 8);
        for li in 0..8 {
            // Tighter gaps (more epochs) never enlarge the safe sphere.
            assert!(
                surf.feature_fractions[1][li] <= surf.feature_fractions[0][li] + 1e-12,
                "lambda {li}: K=100 {} vs K=10 {}",
                surf.feature_fractions[1][li],
                surf.feature_fractions[0][li]
            );
            assert!(surf.group_fractions[1][li] <= surf.group_fractions[0][li] + 1e-12);
        }
        for row in surf.feature_fractions.iter().chain(&surf.group_fractions) {
            assert!(row.iter().all(|&f| (0.0..=1.0).contains(&f)));
        }
        // The GAP sphere must actually reject on the logistic path: at
        // the tight end of the grid with a generous budget, some of the
        // design is screened away.
        assert!(
            surf.feature_fractions[1].iter().any(|&f| f < 1.0),
            "{:?}",
            surf.feature_fractions[1]
        );
    }

    #[test]
    fn timings_cover_rules_and_tols() {
        let job = RuleComparisonJob {
            rules: vec![RuleKind::None, RuleKind::Static, RuleKind::GapSafe],
            tolerances: vec![1e-4],
            t_count: 6,
            delta: 2.0,
            ..Default::default()
        };
        let out = rule_timings(&tiny_cfg(), 0.2, &job, 3);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|t| t.converged && t.seconds >= 0.0));
    }
}
