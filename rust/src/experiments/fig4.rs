//! Figure 4: the support map — active groups for the prediction of air
//! temperature near the target cell, with the highest absolute coefficient
//! among the 7 variables shown per location.

use crate::data::climate::ClimateData;

/// Per-location max-|coefficient| map plus localization diagnostics.
#[derive(Clone, Debug)]
pub struct SupportMap {
    /// `values[loc]` = max |β_j| over the 7 variables at the location.
    pub values: Vec<f64>,
    pub grid_lon: usize,
    pub grid_lat: usize,
    pub target: usize,
    /// Number of active (nonzero) groups.
    pub active_groups: usize,
    /// Mean grid distance of active groups to the target, weighted by
    /// coefficient magnitude (small = localized support, the Fig. 4 story).
    pub weighted_mean_distance: f64,
    /// Mean distance of *all* grid cells to the target (baseline for the
    /// localization claim).
    pub baseline_mean_distance: f64,
}

/// Build the map from fitted coefficients.
pub fn support_map(data: &ClimateData, beta: &[f64]) -> SupportMap {
    let groups = &data.dataset.groups;
    assert_eq!(beta.len(), data.dataset.p());
    let n_loc = groups.n_groups();
    let mut values = vec![0.0; n_loc];
    for (g, a, b) in groups.iter() {
        values[g] = beta[a..b].iter().fold(0.0_f64, |m, &v| m.max(v.abs()));
    }
    let (tx, ty) = (
        (data.target_group % data.cfg.grid_lon) as f64,
        (data.target_group / data.cfg.grid_lon) as f64,
    );
    let dist = |loc: usize| -> f64 {
        let x = (loc % data.cfg.grid_lon) as f64;
        let y = (loc / data.cfg.grid_lon) as f64;
        ((x - tx).powi(2) + (y - ty).powi(2)).sqrt()
    };
    let total_mag: f64 = values.iter().sum();
    let weighted_mean_distance = if total_mag > 0.0 {
        values.iter().enumerate().map(|(loc, v)| v * dist(loc)).sum::<f64>() / total_mag
    } else {
        0.0
    };
    let baseline_mean_distance =
        (0..n_loc).map(dist).sum::<f64>() / n_loc as f64;
    SupportMap {
        active_groups: values.iter().filter(|&&v| v > 0.0).count(),
        weighted_mean_distance,
        baseline_mean_distance,
        values,
        grid_lon: data.cfg.grid_lon,
        grid_lat: data.cfg.grid_lat,
        target: data.target_group,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::climate::{ClimateConfig, N_VARS};
    use crate::experiments::fig3::prepared_data;
    use crate::screening::RuleKind;
    use crate::solver::cd::{solve, SolveOptions};
    use crate::solver::problem::SglProblem;

    #[test]
    fn fitted_support_localizes_near_target() {
        let data = prepared_data(&ClimateConfig::small(31));
        let pb = SglProblem::new(
            data.dataset.x.clone(),
            data.dataset.y.clone(),
            data.dataset.groups.clone(),
            0.4,
        );
        let lambda = 0.25 * pb.lambda_max();
        let res = solve(
            &pb,
            lambda,
            None,
            &SolveOptions { rule: RuleKind::GapSafe, tol: 1e-6, ..Default::default() },
        );
        assert!(res.converged);
        let map = support_map(&data, &res.beta);
        assert!(map.active_groups > 0, "some groups must be selected");
        assert!(
            map.active_groups < data.dataset.groups.n_groups(),
            "solution must be group-sparse"
        );
        // The paper's qualitative claim: important coefficients sit near
        // the target region.
        assert!(
            map.weighted_mean_distance < map.baseline_mean_distance,
            "support not localized: {} vs baseline {}",
            map.weighted_mean_distance,
            map.baseline_mean_distance
        );
    }

    #[test]
    fn map_values_track_beta() {
        let data = prepared_data(&ClimateConfig::small(32));
        let mut beta = vec![0.0; data.dataset.p()];
        beta[3] = -2.0; // group 0, var 3
        beta[N_VARS + 1] = 0.5; // group 1
        let map = support_map(&data, &beta);
        assert_eq!(map.values[0], 2.0);
        assert_eq!(map.values[1], 0.5);
        assert_eq!(map.active_groups, 2);
    }
}
