//! Experiment drivers regenerating every figure of the paper
//! (DESIGN.md §4 maps figure → module → bench target).

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
