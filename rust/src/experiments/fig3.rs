//! Figure 3: the climate experiments (simulated NCEP/NCAR — see DESIGN.md
//! §Substitutions).
//!
//! - **3a** — held-out prediction error over the `(λ, τ)` grid with a
//!   50/50 train/test split (paper: best at τ★ = 0.4);
//! - **3b** — path wall-clock vs target accuracy at τ★, δ = 2.5, per rule.

use crate::coordinator::jobs::{run_rule_comparison, RuleComparisonJob, RuleTiming};
use crate::data::climate::{generate, preprocess, ClimateConfig, ClimateData};
use crate::solver::cd::SolveOptions;
use crate::solver::cv::{split_rows, validate_tau_grid, CvResult};
use crate::solver::path::PathOptions;
use crate::solver::problem::SglProblem;

/// Load + preprocess the simulated climate data.
pub fn prepared_data(cfg: &ClimateConfig) -> ClimateData {
    let mut data = generate(cfg);
    preprocess(&mut data);
    data
}

/// Fig. 3a: the validation grid.
pub fn validation_grid(
    data: &ClimateData,
    taus: &[f64],
    delta: f64,
    t_count: usize,
    tol: f64,
    threads: usize,
    split_seed: u64,
) -> CvResult {
    let split = split_rows(data.dataset.n(), 0.5, split_seed);
    let path_opts = PathOptions {
        delta,
        t_count,
        solve: SolveOptions { tol, record_history: false, ..Default::default() },
    };
    validate_tau_grid(
        &data.dataset.x,
        &data.dataset.y,
        &data.dataset.groups,
        taus,
        &path_opts,
        &split,
        threads,
    )
}

/// Fig. 3b: rule timings on the climate problem at the chosen τ★.
pub fn rule_timings(
    data: &ClimateData,
    tau_star: f64,
    job: &RuleComparisonJob,
    threads: usize,
) -> Vec<RuleTiming> {
    let pb = SglProblem::new(
        data.dataset.x.clone(),
        data.dataset.y.clone(),
        data.dataset.groups.clone(),
        tau_star,
    );
    run_rule_comparison(std::sync::Arc::new(pb), job, threads, None)
}

/// The paper's τ grid: {0, 0.1, …, 1}.
pub fn paper_tau_grid() -> Vec<f64> {
    (0..=10).map(|i| i as f64 / 10.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::RuleKind;

    #[test]
    fn tau_grid_matches_paper() {
        let g = paper_tau_grid();
        assert_eq!(g.len(), 11);
        assert_eq!(g[0], 0.0);
        assert_eq!(g[10], 1.0);
        assert!((g[4] - 0.4).abs() < 1e-15);
    }

    #[test]
    fn validation_beats_null_model_on_small_grid() {
        let data = prepared_data(&ClimateConfig::small(21));
        let cv = validation_grid(&data, &[0.2, 0.6], 2.0, 8, 1e-5, 2, 7);
        assert_eq!(cv.curves.len(), 2);
        // Null model on centered data: mse ~ var(y_test). Best must improve.
        assert!(cv.best_mse.is_finite() && cv.best_mse > 0.0);
        let worst_first: f64 = cv
            .curves
            .iter()
            .map(|c| c.test_mse[0])
            .fold(f64::INFINITY, f64::min);
        assert!(cv.best_mse < worst_first, "{} vs {worst_first}", cv.best_mse);
    }

    #[test]
    fn timings_run_on_climate() {
        let data = prepared_data(&ClimateConfig::small(22));
        let job = RuleComparisonJob {
            rules: vec![RuleKind::None, RuleKind::GapSafe],
            tolerances: vec![1e-4],
            t_count: 5,
            delta: 2.0,
            ..Default::default()
        };
        let out = rule_timings(&data, 0.4, &job, 2);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|t| t.converged));
    }
}
