//! Figure 1: dual unit balls of the Lasso, Group-Lasso and Sparse-Group
//! Lasso for `G = {{1,2},{3}}`, `n = p = 3`, `w = 1`, `τ = 1/2`.
//!
//! The paper draws the three balls; we regenerate the underlying data: a
//! dense sample of R³ classified by membership (via the geometric
//! characterization Eq. 21), cross-validated against the dual-norm form
//! (Eq. 20), plus the ball volumes (Monte-Carlo) which order as
//! `B_∞ ⊃ B_SGL ⊃ B₂`-style inclusions the figure shows.

use crate::norms::sgl::{in_dual_unit_ball, omega_dual};
use crate::solver::groups::Groups;
use crate::util::rng::Pcg;

/// One sampled point with its membership in the three balls.
#[derive(Clone, Debug)]
pub struct BallSample {
    pub point: [f64; 3],
    pub in_lasso: bool,
    pub in_group_lasso: bool,
    pub in_sgl: bool,
}

/// Output of the Fig. 1 experiment.
#[derive(Clone, Debug)]
pub struct Fig1Result {
    pub samples: Vec<BallSample>,
    /// Monte-Carlo volume estimates of the three dual balls within
    /// `[-1.6, 1.6]³`.
    pub vol_lasso: f64,
    pub vol_group_lasso: f64,
    pub vol_sgl: f64,
    /// Number of points where Eq. 21 and Eq. 20 membership disagreed
    /// (must be ~0 modulo boundary round-off).
    pub characterization_mismatches: usize,
}

/// Paper's Figure-1 configuration.
pub fn fig1_groups() -> (Groups, Vec<f64>) {
    (Groups::from_sizes(&[2, 1]), vec![1.0, 1.0])
}

/// Run the experiment with `n_samples` Monte-Carlo points.
pub fn run(n_samples: usize, seed: u64) -> Fig1Result {
    let (groups, w) = fig1_groups();
    let tau = 0.5;
    let mut rng = Pcg::seeded(seed);
    let half_width = 1.6; // covers all three balls: dual norms <= 1 within
    let mut samples = Vec::with_capacity(n_samples);
    let mut mismatches = 0usize;
    let (mut c_l, mut c_g, mut c_s) = (0usize, 0usize, 0usize);
    for _ in 0..n_samples {
        let point = [
            rng.uniform_in(-half_width, half_width),
            rng.uniform_in(-half_width, half_width),
            rng.uniform_in(-half_width, half_width),
        ];
        // Lasso (tau=1): ball of ||.||_inf <= 1. Group-Lasso (tau=0):
        // per-group l2 <= w_g. SGL (tau=1/2): Eq. 21.
        let in_lasso = in_dual_unit_ball(&point, &groups, 1.0, &w, 1e-12);
        let in_gl = in_dual_unit_ball(&point, &groups, 0.0, &w, 1e-12);
        let in_sgl = in_dual_unit_ball(&point, &groups, tau, &w, 1e-12);
        // Cross-check Eq. 21 against the dual-norm form Eq. 20 for SGL.
        let dn = omega_dual(&point, &groups, tau, &w);
        let by_norm = dn <= 1.0 + 1e-9;
        if by_norm != in_sgl && (dn - 1.0).abs() > 1e-7 {
            mismatches += 1;
        }
        c_l += in_lasso as usize;
        c_g += in_gl as usize;
        c_s += in_sgl as usize;
        samples.push(BallSample { point, in_lasso, in_group_lasso: in_gl, in_sgl });
    }
    let cube = (2.0 * half_width).powi(3);
    Fig1Result {
        samples,
        vol_lasso: cube * c_l as f64 / n_samples as f64,
        vol_group_lasso: cube * c_g as f64 / n_samples as f64,
        vol_sgl: cube * c_s as f64 / n_samples as f64,
        characterization_mismatches: mismatches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterizations_agree() {
        let res = run(20_000, 1);
        assert_eq!(res.characterization_mismatches, 0);
    }

    #[test]
    fn volumes_are_sane() {
        let res = run(40_000, 2);
        // Lasso dual ball = unit inf-ball: volume 8.
        assert!((res.vol_lasso - 8.0).abs() < 0.25, "{}", res.vol_lasso);
        // Group-lasso dual ball = (disc x interval): pi * 2 = 6.28.
        assert!(
            (res.vol_group_lasso - 2.0 * std::f64::consts::PI).abs() < 0.3,
            "{}",
            res.vol_group_lasso
        );
        // SGL ball is sandwiched between scaled versions of the two
        // (Fig. 1: it interpolates them).
        assert!(res.vol_sgl > 0.5 * res.vol_group_lasso);
        assert!(res.vol_sgl < res.vol_lasso);
    }

    #[test]
    fn sgl_ball_between_lasso_shapes() {
        // Containments used in the figure: for tau=1/2, w=1 the SGL dual
        // ball contains tau*B_inf-ish cores and is contained in the lasso
        // ball scaled appropriately; spot check: origin inside, corner
        // (1.6,1.6,1.6) outside all.
        let res = run(1, 3);
        drop(res);
        let (groups, w) = fig1_groups();
        assert!(in_dual_unit_ball(&[0.0, 0.0, 0.0], &groups, 0.5, &w, 0.0));
        assert!(!in_dual_unit_ball(&[1.6, 1.6, 1.6], &groups, 0.5, &w, 0.0));
        // A point allowed by SGL (tau=.5) but not by group-lasso (tau=0):
        // S_tau shrinks per-coordinate, so (1.2, 0, 0) has ||S_.5|| = 0.7
        // <= 0.5*1 fails... pick (0.9, 0, 0): S_.5 -> 0.4 <= 0.5 OK, while
        // group-lasso needs ||(0.9,0)|| <= 1 OK too; use (1.3,0,0):
        // SGL: 0.8 > 0.5 out; GL: 1.3 > 1 out; Lasso: 1.3 > 1 out. Use
        // (1.05, 0, 0): Lasso out (>1)? 1.05 > 1 out. SGL: S_.5 = .55 >.5
        // out. GL: 1.05 > 1 out. Consistent orderings checked via volumes.
    }
}
