//! L4 solve service: a non-blocking job queue over the path engine.
//!
//! [`PathBatch`](crate::solver::path::PathBatch) is a *blocking* fan-out —
//! the caller hands over a fixed batch and waits for all of it. A serving
//! workload is the opposite shape: heterogeneous requests arrive over
//! time, callers want a [`JobId`] back immediately and poll/wait/cancel
//! independently, duplicate traffic should be answered from cache, and
//! one huge path should be splittable into λ-range shards
//! ([`crate::coordinator::shard`]) without changing its result. This
//! module is that layer:
//!
//! - [`SolveService::submit`] enqueues a [`SolveRequest`] (priority-class
//!   FIFO queue, bounded by [`ServiceConfig::queue_depth`] — a full queue
//!   is a typed [`QueueFullError`], i.e. explicit backpressure, never an
//!   unbounded buffer);
//! - a persistent [`WorkerPool`] drains the queue; a sharded job is
//!   executed as a pipeline of shard tasks, each re-enqueued with the
//!   predecessor's [`DualHandoff`] so `GapSafeSeq` screening fires across
//!   shard boundaries exactly as it does mid-path;
//! - completed results land in a result store with
//!   [`poll`](SolveService::poll) / [`wait`](SolveService::wait) /
//!   [`cancel`](SolveService::cancel) semantics plus a completion stream
//!   ([`wait_next`](SolveService::wait_next));
//! - a fingerprint cache (hash of solve config + dataset identity →
//!   completed [`PathResult`]) serves duplicate requests without
//!   re-solving — the cache keeps the dataset `Arc` alive, so an identity
//!   pointer can never be recycled by a different problem;
//! - [`Metrics`] gains queue-depth gauges and per-job latency/queue-wait
//!   timers ([`Metrics::observe_secs`]).
//!
//! Requests are backend- and datafit-heterogeneous through
//! [`AnyProblem`]: one service instance serves dense and CSC problems,
//! least-squares and logistic fits (and any mix of rule/tolerance/solver)
//! side by side.

use super::metrics::Metrics;
use super::remote::RemoteFleet;
use super::shard::{plan_shards, stitch};
use crate::linalg::{CscMatrix, Matrix};
use crate::solver::datafit::{Datafit, FitKind, Logistic, MultiTaskQuadratic};
use crate::solver::path::{
    solve_path_with_handoff, DualHandoff, PathOptions, PathResult,
};
use crate::solver::problem::{lambda_grid, SglProblem};
use crate::solver::SolverKind;
use crate::util::lru::LruCache;
use crate::util::pool::{resolve_threads, WorkerPool};
use crate::util::timer::Stopwatch;
use crate::util::trace;
use anyhow::{bail, Result};
use std::cmp::Ordering as CmpOrdering;
use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::fmt;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Service sizing knobs (`[service]` in TOML / `--workers`,
/// `--queue-depth` on the CLI).
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker threads draining the queue (0 = auto: the
    /// `SGL_THREADS` / available-parallelism default).
    pub workers: usize,
    /// Maximum number of *queued* (not yet started) jobs; submissions
    /// beyond it fail with [`QueueFullError`].
    pub queue_depth: usize,
    /// Maximum number of *terminal* jobs the result store retains. Beyond
    /// it the oldest already-retrieved jobs are reaped (undelivered
    /// results are never evicted — the store only exceeds the bound while
    /// callers sit on unconsumed completions, and every retrieval
    /// re-trims); a reaped id polls as unknown. Keeps a long-lived
    /// service at O(capacity) memory instead of growing for the process
    /// lifetime.
    pub result_capacity: usize,
    /// Maximum number of fingerprint-cache entries; least-recently-used
    /// entries are evicted past it (`service_cache_evictions` counts).
    pub cache_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 0, queue_depth: 64, result_capacity: 1024, cache_capacity: 256 }
    }
}

/// A problem instance on either design backend under either datafit. The
/// service is deliberately *not* generic over
/// [`crate::linalg::Design`] / [`crate::solver::datafit::Datafit`]: one
/// instance serves mixed dense/CSC, regression/classification traffic,
/// which is what a shared front end sees.
#[derive(Clone, Debug)]
pub enum AnyProblem {
    Dense(Arc<SglProblem<Matrix>>),
    Csc(Arc<SglProblem<CscMatrix>>),
    DenseLogistic(Arc<SglProblem<Matrix, Logistic>>),
    CscLogistic(Arc<SglProblem<CscMatrix, Logistic>>),
    DenseMultiTask(Arc<SglProblem<Matrix, MultiTaskQuadratic>>),
    CscMultiTask(Arc<SglProblem<CscMatrix, MultiTaskQuadratic>>),
}

impl AnyProblem {
    pub fn backend_name(&self) -> &'static str {
        match self {
            AnyProblem::Dense(_)
            | AnyProblem::DenseLogistic(_)
            | AnyProblem::DenseMultiTask(_) => "dense",
            AnyProblem::Csc(_) | AnyProblem::CscLogistic(_) | AnyProblem::CscMultiTask(_) => {
                "csc"
            }
        }
    }

    /// Which loss this problem is fit under (see [`FitKind::name`]).
    pub fn datafit_kind(&self) -> FitKind {
        match self {
            AnyProblem::Dense(_) | AnyProblem::Csc(_) => FitKind::Quadratic,
            AnyProblem::DenseLogistic(_) | AnyProblem::CscLogistic(_) => FitKind::Logistic,
            AnyProblem::DenseMultiTask(_) | AnyProblem::CscMultiTask(_) => FitKind::MultiTask,
        }
    }

    /// Number of response columns `q` (1 for every scalar datafit).
    pub fn tasks(&self) -> usize {
        match self {
            AnyProblem::DenseMultiTask(p) => p.datafit.tasks(),
            AnyProblem::CscMultiTask(p) => p.datafit.tasks(),
            _ => 1,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            AnyProblem::Dense(p) => p.n(),
            AnyProblem::Csc(p) => p.n(),
            AnyProblem::DenseLogistic(p) => p.n(),
            AnyProblem::CscLogistic(p) => p.n(),
            AnyProblem::DenseMultiTask(p) => p.n(),
            AnyProblem::CscMultiTask(p) => p.n(),
        }
    }

    pub fn p(&self) -> usize {
        match self {
            AnyProblem::Dense(p) => p.p(),
            AnyProblem::Csc(p) => p.p(),
            AnyProblem::DenseLogistic(p) => p.p(),
            AnyProblem::CscLogistic(p) => p.p(),
            AnyProblem::DenseMultiTask(p) => p.p(),
            AnyProblem::CscMultiTask(p) => p.p(),
        }
    }

    /// `λ_max` of the underlying problem (one `Xᵀ·zero_residual(y)`
    /// product — workers call this off-lock when deriving a grid).
    pub fn lambda_max(&self) -> f64 {
        match self {
            AnyProblem::Dense(p) => p.lambda_max(),
            AnyProblem::Csc(p) => p.lambda_max(),
            AnyProblem::DenseLogistic(p) => p.lambda_max(),
            AnyProblem::CscLogistic(p) => p.lambda_max(),
            AnyProblem::DenseMultiTask(p) => p.lambda_max(),
            AnyProblem::CscMultiTask(p) => p.lambda_max(),
        }
    }

    /// Dataset identity for the fingerprint cache: the backend+datafit
    /// tag plus the `Arc` pointer. Two requests share an identity iff
    /// they share the problem *instance* — the cache holds a clone of the
    /// `Arc`, so the pointer stays pinned for the cache entry's lifetime.
    /// (The remote fleet keys its dataset registry the same way, and pins
    /// a clone for the same reason.)
    pub(crate) fn identity(&self) -> (u8, usize) {
        match self {
            AnyProblem::Dense(p) => (0, Arc::as_ptr(p) as usize),
            AnyProblem::Csc(p) => (1, Arc::as_ptr(p) as *const u8 as usize),
            AnyProblem::DenseLogistic(p) => (2, Arc::as_ptr(p) as usize),
            AnyProblem::CscLogistic(p) => (3, Arc::as_ptr(p) as *const u8 as usize),
            AnyProblem::DenseMultiTask(p) => (4, Arc::as_ptr(p) as usize),
            AnyProblem::CscMultiTask(p) => (5, Arc::as_ptr(p) as *const u8 as usize),
        }
    }

    /// Solve one explicit λ-range on this problem's backend and datafit,
    /// resuming from (and producing) a [`DualHandoff`]. The single
    /// dispatch point every executor — the local worker pool, the remote
    /// worker's serve loop, the cross-path scheduler — funnels through,
    /// so all of them run the identical arithmetic.
    pub fn solve_range(
        &self,
        lambdas: &[f64],
        opts: &PathOptions,
        solver: SolverKind,
        handoff: Option<&DualHandoff>,
    ) -> (PathResult, Option<DualHandoff>) {
        match self {
            AnyProblem::Dense(p) => solve_path_with_handoff(p, lambdas, opts, solver, handoff),
            AnyProblem::Csc(p) => solve_path_with_handoff(p, lambdas, opts, solver, handoff),
            AnyProblem::DenseLogistic(p) => {
                solve_path_with_handoff(p, lambdas, opts, solver, handoff)
            }
            AnyProblem::CscLogistic(p) => {
                solve_path_with_handoff(p, lambdas, opts, solver, handoff)
            }
            AnyProblem::DenseMultiTask(p) => {
                solve_path_with_handoff(p, lambdas, opts, solver, handoff)
            }
            AnyProblem::CscMultiTask(p) => {
                solve_path_with_handoff(p, lambdas, opts, solver, handoff)
            }
        }
    }
}

/// One solve-path request. Everything except `priority` and `label`
/// participates in the cache fingerprint.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub pb: AnyProblem,
    /// Explicit non-increasing λ grid; `None` derives the geometric grid
    /// of `opts` from `λ_max` (computed on a worker, not at submit).
    pub lambdas: Option<Vec<f64>>,
    pub opts: PathOptions,
    pub solver: SolverKind,
    /// Larger drains first; equal priorities are FIFO.
    pub priority: u8,
    /// Split the path into this many contiguous λ-range shards, pipelined
    /// through the queue with dual-point handoff (≤ 1 = monolithic).
    pub shards: usize,
    /// Free-form tag echoed in reports; not part of the fingerprint.
    pub label: String,
}

impl SolveRequest {
    /// A plain monolithic CD request with default priority.
    pub fn new(pb: AnyProblem, opts: PathOptions) -> Self {
        SolveRequest {
            pb,
            lambdas: None,
            opts,
            solver: SolverKind::Cd,
            priority: 0,
            shards: 1,
            label: String::new(),
        }
    }

    /// The exact cache key: dataset identity plus every solve-relevant
    /// knob (floats by bit pattern). `label` and `priority` are excluded —
    /// they cannot change the result. The cache map is keyed by this
    /// (full equality, not just a hash), so a hash collision can never
    /// serve the wrong result.
    fn cache_key(&self) -> CacheKey {
        CacheKey {
            identity: self.pb.identity(),
            solver: self.solver.name(),
            rule: self.opts.solve.rule.name(),
            tol: self.opts.solve.tol.to_bits(),
            fce: self.opts.solve.fce,
            max_epochs: self.opts.solve.max_epochs,
            record_history: self.opts.solve.record_history,
            // The parallel CD sweep reaches the same objective on a
            // different trajectory, so the sweep mode (and its thread
            // count, which fixes the round shape) must key the cache.
            sweep: self.opts.solve.sweep.name(),
            sweep_threads: self.opts.solve.sweep_threads,
            // The sweep-tuning floors shape the parallel-CD round
            // structure (same objective, different trajectory) — they
            // key the cache for the same reason sweep_threads does.
            tuning: self.opts.solve.tuning,
            // Kernel policy is process-global; it changes reduction
            // orderings (and hence exact iterates), so a cache filled
            // under one policy must not serve a run under another.
            kernels: crate::linalg::simd::effective().name(),
            delta: self.opts.delta.to_bits(),
            t_count: self.opts.t_count,
            shards: self.shards,
            lambdas: self
                .lambdas
                .as_ref()
                .map(|g| g.iter().map(|v| v.to_bits()).collect()),
        }
    }

    /// 64-bit digest of the (private) exact cache key — a compact
    /// identifier for logs and tests; the cache itself compares full keys.
    pub fn fingerprint(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.cache_key().hash(&mut h);
        h.finish()
    }
}

/// See [`SolveRequest::cache_key`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct CacheKey {
    identity: (u8, usize),
    solver: &'static str,
    rule: &'static str,
    tol: u64,
    fce: usize,
    max_epochs: usize,
    record_history: bool,
    sweep: &'static str,
    sweep_threads: usize,
    tuning: crate::solver::sweep::SweepTuning,
    kernels: &'static str,
    delta: u64,
    t_count: usize,
    shards: usize,
    lambdas: Option<Vec<u64>>,
}

/// Opaque handle returned by [`SolveService::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job#{}", self.0)
    }
}

/// Externally visible lifecycle state of a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Cancelled,
    Failed,
}

/// Typed payload for a rejected submission (backpressure): the queue
/// already holds `depth` unstarted jobs. Mirrors the
/// `UnknownBackendError` pattern — callers `downcast_ref` to distinguish
/// "retry later" from real errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFullError {
    pub depth: usize,
}

impl fmt::Display for QueueFullError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "service queue is full ({} jobs queued)", self.depth)
    }
}

impl std::error::Error for QueueFullError {}

enum JobState {
    Queued,
    Running,
    Done(Arc<PathResult>),
    Cancelled,
    Failed(String),
}

impl JobState {
    fn status(&self) -> JobStatus {
        match self {
            JobState::Queued => JobStatus::Queued,
            JobState::Running => JobStatus::Running,
            JobState::Done(_) => JobStatus::Done,
            JobState::Cancelled => JobStatus::Cancelled,
            JobState::Failed(_) => JobStatus::Failed,
        }
    }

    fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }
}

/// Execution state of a (possibly sharded) job once a worker planned it.
struct ShardProgress {
    /// One λ-range per shard, in path order.
    grids: Vec<Vec<f64>>,
    /// Completed shard results (always a prefix of `grids`).
    parts: Vec<PathResult>,
    /// Handoff out of the last completed shard, into the next.
    carried: Option<DualHandoff>,
}

struct Job {
    req: SolveRequest,
    state: JobState,
    progress: Option<ShardProgress>,
    /// Started at submit: measures queue wait and end-to-end latency.
    sw: Stopwatch,
    /// First worker pickup recorded (queue-wait observed once).
    started: bool,
    /// Served from the fingerprint cache without solving.
    cached: bool,
    /// The caller consumed the terminal outcome (`result`/`wait` returned
    /// it, or `wait_next` yielded the id): the job is first in line when
    /// the result store exceeds its capacity.
    retrieved: bool,
}

/// Queue entry: max-heap pops the highest priority first and, within a
/// priority class, the lowest sequence number (FIFO).
struct QueueItem {
    priority: u8,
    seq: u64,
    id: JobId,
}

impl Ord for QueueItem {
    fn cmp(&self, other: &Self) -> CmpOrdering {
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for QueueItem {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for QueueItem {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == CmpOrdering::Equal
    }
}

impl Eq for QueueItem {}

struct CacheEntry {
    /// Keeps the dataset `Arc` alive so its identity pointer can never be
    /// reused by a different problem while the entry exists.
    _pb: AnyProblem,
    result: Arc<PathResult>,
}

struct Shared {
    queue: BinaryHeap<QueueItem>,
    jobs: BTreeMap<JobId, Job>,
    /// Solved-path fingerprint cache, bounded by
    /// [`ServiceConfig::cache_capacity`] with LRU eviction (the shared
    /// [`LruCache`] also backs the remote workers' dataset stores).
    cache: LruCache<CacheKey, CacheEntry>,
    depth: usize,
    /// Bound on retained terminal jobs (see [`ServiceConfig::result_capacity`]).
    result_capacity: usize,
    /// Terminal jobs in completion order — the reaping scan order.
    terminal: VecDeque<JobId>,
    /// Jobs currently in state `Queued` (submitted, never started). The
    /// admission bound compares against this, not `queue.len()`: shard
    /// continuations of running jobs share the physical queue but must
    /// not shrink the bound.
    queued_new: usize,
    next_id: u64,
    next_seq: u64,
    /// Jobs submitted but not yet terminal (Done/Cancelled/Failed).
    outstanding: usize,
    /// Newly terminal jobs, in completion order, for [`SolveService::wait_next`].
    completions: VecDeque<JobId>,
    shutdown: bool,
}

/// Where a worker thread actually runs a claimed shard.
enum ShardExec {
    /// Solve in-process on the worker thread (the default).
    Local,
    /// Drain into a remote worker fleet: the thread leases a fleet slot,
    /// ships the shard over TCP and blocks on the reply. Slot accounting
    /// (and requeue onto survivors after a disconnect) lives in
    /// [`RemoteFleet::solve_shard`], so the slot is released before the
    /// outcome is integrated — a job cancelled mid-dispatch can never
    /// leak its worker slot.
    Fleet(Arc<RemoteFleet>),
}

struct Inner {
    state: Mutex<Shared>,
    /// Wakes workers: queue push or shutdown.
    work: Condvar,
    /// Wakes waiters: job became terminal or shutdown.
    done: Condvar,
    metrics: Arc<Metrics>,
    exec: ShardExec,
}

/// The async solve service. Dropping it signals shutdown and joins the
/// workers (in-flight shards finish; still-queued jobs are abandoned).
pub struct SolveService {
    inner: Arc<Inner>,
    pool: WorkerPool,
}

impl SolveService {
    /// Start the service with its own metrics registry.
    pub fn start(cfg: ServiceConfig) -> Self {
        Self::with_metrics(cfg, Arc::new(Metrics::new()))
    }

    /// Start the service recording into a shared metrics registry.
    pub fn with_metrics(cfg: ServiceConfig, metrics: Arc<Metrics>) -> Self {
        let workers = resolve_threads(cfg.workers);
        Self::spawn(cfg, metrics, workers, ShardExec::Local)
    }

    /// Start the service draining shards into a remote worker fleet
    /// instead of solving in-process. `workers = 0` sizes the local
    /// dispatch threads to the fleet's capacity, so every fleet slot can
    /// be kept busy (each dispatch thread blocks on one remote shard at
    /// a time).
    pub fn with_fleet(
        cfg: ServiceConfig,
        metrics: Arc<Metrics>,
        fleet: Arc<RemoteFleet>,
    ) -> Self {
        let workers = if cfg.workers == 0 { fleet.capacity().max(1) } else { cfg.workers };
        Self::spawn(cfg, metrics, workers, ShardExec::Fleet(fleet))
    }

    fn spawn(cfg: ServiceConfig, metrics: Arc<Metrics>, workers: usize, exec: ShardExec) -> Self {
        let inner = Arc::new(Inner {
            state: Mutex::new(Shared {
                queue: BinaryHeap::new(),
                jobs: BTreeMap::new(),
                cache: LruCache::new(cfg.cache_capacity.max(1)),
                depth: cfg.queue_depth.max(1),
                result_capacity: cfg.result_capacity.max(1),
                terminal: VecDeque::new(),
                queued_new: 0,
                next_id: 0,
                next_seq: 0,
                outstanding: 0,
                completions: VecDeque::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            metrics,
            exec,
        });
        let worker_inner = inner.clone();
        let pool = WorkerPool::spawn(workers, move |_i| worker_loop(&worker_inner));
        SolveService { inner, pool }
    }

    /// Number of worker threads draining the queue.
    pub fn workers(&self) -> usize {
        self.pool.len()
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.inner.metrics.clone()
    }

    /// Enqueue a request and return immediately. Duplicate traffic (same
    /// fingerprint as a completed job) is answered from the cache: the
    /// returned job is terminal at birth and shares the cached result
    /// `Arc`. A full queue is a typed [`QueueFullError`].
    pub fn submit(&self, req: SolveRequest) -> Result<JobId> {
        let m = &self.inner.metrics;
        let mut s = self.inner.state.lock().unwrap();
        if s.shutdown {
            bail!("service is shut down");
        }
        let id = JobId(s.next_id);
        s.next_id += 1;
        // `get` bumps recency: duplicates keep entries warm.
        let hit = s.cache.get(&req.cache_key()).map(|e| e.result.clone());
        if let Some(result) = hit {
            s.jobs.insert(
                id,
                Job {
                    req,
                    state: JobState::Done(result),
                    progress: None,
                    sw: Stopwatch::start(),
                    started: true,
                    cached: true,
                    retrieved: false,
                },
            );
            s.completions.push_back(id);
            s.terminal.push_back(id);
            reap_excess(&self.inner, &mut s);
            m.incr("service_submitted", 1);
            m.incr("service_cache_hits", 1);
            self.inner.done.notify_all();
            return Ok(id);
        }
        if s.queued_new >= s.depth {
            return Err(anyhow::Error::new(QueueFullError { depth: s.depth }));
        }
        let seq = s.next_seq;
        s.next_seq += 1;
        s.queue.push(QueueItem { priority: req.priority, seq, id });
        s.queued_new += 1;
        s.jobs.insert(
            id,
            Job {
                req,
                state: JobState::Queued,
                progress: None,
                sw: Stopwatch::start(),
                started: false,
                cached: false,
                retrieved: false,
            },
        );
        s.outstanding += 1;
        m.incr("service_submitted", 1);
        m.set("service_queue_depth", s.queue.len() as f64);
        m.set("service_outstanding", s.outstanding as f64);
        self.inner.work.notify_one();
        Ok(id)
    }

    /// Current lifecycle state (`None` for an unknown id). Observing a
    /// *failure-terminal* state (Failed/Cancelled — there is no result
    /// left to deliver) counts as retrieval for result-store reaping,
    /// so jobs whose owners only ever poll can't be pinned forever. A
    /// `Done` job is never marked here: its result still awaits delivery
    /// through [`result`](Self::result)/[`wait`](Self::wait), and
    /// reaping it early would lose the result the poll just reported.
    pub fn poll(&self, id: JobId) -> Option<JobStatus> {
        let mut s = self.inner.state.lock().unwrap();
        let job = s.jobs.get_mut(&id)?;
        let status = job.state.status();
        if matches!(job.state, JobState::Failed(_) | JobState::Cancelled) {
            job.retrieved = true;
            reap_excess(&self.inner, &mut s);
        }
        Some(status)
    }

    /// The completed result, if the job is `Done`. Retrieval marks the
    /// job reapable once the result store is over capacity.
    pub fn result(&self, id: JobId) -> Option<Arc<PathResult>> {
        let mut s = self.inner.state.lock().unwrap();
        let job = s.jobs.get_mut(&id)?;
        let out = match &job.state {
            JobState::Done(r) => {
                let r = r.clone();
                job.retrieved = true;
                Some(r)
            }
            _ => None,
        };
        if out.is_some() {
            reap_excess(&self.inner, &mut s);
        }
        out
    }

    /// Number of jobs (any state) currently held by the result store.
    /// Bounded by in-flight work plus [`ServiceConfig::result_capacity`].
    pub fn job_count(&self) -> usize {
        self.inner.state.lock().unwrap().jobs.len()
    }

    /// Number of entries in the fingerprint cache (≤
    /// [`ServiceConfig::cache_capacity`]).
    pub fn cache_len(&self) -> usize {
        self.inner.state.lock().unwrap().cache.len()
    }

    /// Whether the job was served from the fingerprint cache.
    pub fn was_cached(&self, id: JobId) -> bool {
        let s = self.inner.state.lock().unwrap();
        s.jobs.get(&id).is_some_and(|j| j.cached)
    }

    /// The request's label (empty for an unknown id).
    pub fn label(&self, id: JobId) -> String {
        let s = self.inner.state.lock().unwrap();
        s.jobs.get(&id).map(|j| j.req.label.clone()).unwrap_or_default()
    }

    /// Block until the job is terminal; `Err` if it was cancelled,
    /// failed, or the id is unknown. Observing the terminal state marks
    /// the job reapable once the result store is over capacity.
    pub fn wait(&self, id: JobId) -> Result<Arc<PathResult>> {
        let mut s = self.inner.state.lock().unwrap();
        loop {
            let outcome = match s.jobs.get_mut(&id) {
                None => bail!("unknown {id}"),
                Some(j) => match &j.state {
                    JobState::Done(r) => {
                        let r = r.clone();
                        j.retrieved = true;
                        Some(Ok(r))
                    }
                    JobState::Cancelled => {
                        j.retrieved = true;
                        Some(Err(anyhow::anyhow!("{id} was cancelled")))
                    }
                    JobState::Failed(e) => {
                        let e = e.clone();
                        j.retrieved = true;
                        Some(Err(anyhow::anyhow!("{id} failed: {e}")))
                    }
                    _ => None,
                },
            };
            if let Some(outcome) = outcome {
                reap_excess(&self.inner, &mut s);
                return outcome;
            }
            s = self.inner.done.wait(s).unwrap();
        }
    }

    /// Block until *any* job completes (in completion order) and return
    /// its id; `None` once every submitted job is terminal and the
    /// completion stream has been drained. A yielded Failed/Cancelled id
    /// counts as retrieved for result-store reaping; a `Done` id does
    /// not — its result is still undelivered until the caller fetches it
    /// ([`result`](Self::result) marks it then), so it cannot be reaped
    /// out from under the `wait_next` → `result` pattern.
    pub fn wait_next(&self) -> Option<JobId> {
        let mut s = self.inner.state.lock().unwrap();
        loop {
            if let Some(id) = s.completions.pop_front() {
                if let Some(job) = s.jobs.get_mut(&id) {
                    if matches!(job.state, JobState::Failed(_) | JobState::Cancelled) {
                        job.retrieved = true;
                        reap_excess(&self.inner, &mut s);
                    }
                }
                return Some(id);
            }
            if s.outstanding == 0 {
                return None;
            }
            s = self.inner.done.wait(s).unwrap();
        }
    }

    /// Cancel a job that has not completed. Queued jobs (and queued
    /// continuations of a sharded job) never run; a shard already being
    /// solved finishes on its worker and is discarded. Returns whether
    /// the cancellation took effect (false once terminal).
    pub fn cancel(&self, id: JobId) -> bool {
        let mut s = self.inner.state.lock().unwrap();
        let Some(job) = s.jobs.get_mut(&id) else {
            return false;
        };
        if job.state.is_terminal() {
            return false;
        }
        let was_queued = matches!(job.state, JobState::Queued);
        job.state = JobState::Cancelled;
        // The canceller owns this outcome: the job is immediately
        // reapable, so abandoned cancellations can't pin the store.
        job.retrieved = true;
        if was_queued {
            s.queued_new -= 1;
        }
        // Drop the queue item eagerly so tombstones never count against
        // `queue_depth` (a worker that already pulled the id discards it
        // on seeing the terminal state).
        s.queue.retain(|item| item.id != id);
        s.outstanding -= 1;
        s.completions.push_back(id);
        s.terminal.push_back(id);
        reap_excess(&self.inner, &mut s);
        self.inner.metrics.incr("service_cancelled", 1);
        self.inner.metrics.set("service_queue_depth", s.queue.len() as f64);
        self.inner.metrics.set("service_outstanding", s.outstanding as f64);
        self.inner.done.notify_all();
        true
    }

    fn signal_shutdown(&self) {
        let mut s = self.inner.state.lock().unwrap();
        s.shutdown = true;
        drop(s);
        self.inner.work.notify_all();
        self.inner.done.notify_all();
    }
}

impl Drop for SolveService {
    fn drop(&mut self) {
        self.signal_shutdown();
        self.pool.join_all();
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let id = {
            let mut s = inner.state.lock().unwrap();
            loop {
                if s.shutdown {
                    return;
                }
                if let Some(item) = s.queue.pop() {
                    inner.metrics.set("service_queue_depth", s.queue.len() as f64);
                    break item.id;
                }
                s = inner.work.wait(s).unwrap();
            }
        };
        run_one(inner, id);
    }
}

/// Advance a job by one shard (a monolithic job is a single shard).
fn run_one(inner: &Inner, id: JobId) {
    // -- claim the job; drop stale queue items of cancelled jobs.
    let (req, pulled) = {
        let mut s = inner.state.lock().unwrap();
        let Some(job) = s.jobs.get_mut(&id) else { return };
        if job.state.is_terminal() {
            return;
        }
        let newly_started = matches!(job.state, JobState::Queued);
        if newly_started {
            job.state = JobState::Running;
        }
        if !job.started {
            job.started = true;
            inner.metrics.observe_secs("service_queue_wait_s", job.sw.elapsed_s());
        }
        let pulled = job.progress.as_ref().map(|p| {
            let i = p.parts.len();
            (p.grids[i].clone(), p.carried.clone())
        });
        let out = (job.req.clone(), pulled);
        if newly_started {
            s.queued_new -= 1;
        }
        out
    };

    let (grid, handoff) = match pulled {
        Some(t) => t,
        None => {
            // First pickup: derive the grid and plan the shards. λ_max is
            // a full `Xᵀy` product — never computed under the lock — and a
            // panic here (e.g. a degenerate request asserting inside
            // `lambda_grid`) must become a Failed job, not a dead worker.
            let planned = catch_unwind(AssertUnwindSafe(|| {
                let full = match &req.lambdas {
                    Some(g) => g.clone(),
                    None => lambda_max_grid(&req),
                };
                plan_shards(full.len(), req.shards.max(1))
                    .into_iter()
                    .map(|(a, b)| full[a..b].to_vec())
                    .collect::<Vec<Vec<f64>>>()
            }));
            let mut s = inner.state.lock().unwrap();
            let Some(job) = s.jobs.get_mut(&id) else { return };
            if job.state.is_terminal() {
                return; // cancelled while planning
            }
            let grids = match planned {
                Ok(g) => g,
                Err(payload) => {
                    finish(inner, &mut s, id, Err(panic_message(payload)));
                    return;
                }
            };
            if grids.is_empty() {
                // Degenerate empty grid: complete with an empty result.
                let result =
                    Arc::new(PathResult { lambdas: Vec::new(), results: Vec::new(), total_s: 0.0 });
                finish(inner, &mut s, id, Ok(result));
                return;
            }
            let first = grids[0].clone();
            job.progress =
                Some(ShardProgress { grids, parts: Vec::new(), carried: None });
            (first, None)
        }
    };

    // -- solve this shard outside the lock (locally or on the fleet); a
    // panic becomes a job failure instead of poisoning the service, and a
    // remote failure (all workers gone, typed worker error) likewise.
    let sw = Stopwatch::start();
    let shard_span = trace::span_with("service_shard", || {
        vec![("job", id.0.into()), ("lambdas", grid.len().into())]
    });
    let solved = catch_unwind(AssertUnwindSafe(|| match &inner.exec {
        ShardExec::Local => Ok(req.pb.solve_range(&grid, &req.opts, req.solver, handoff.as_ref())),
        ShardExec::Fleet(fleet) => fleet
            .solve_shard(&req.pb, &grid, &req.opts, req.solver, handoff.as_ref())
            .map_err(|e| format!("{e:#}")),
    }));
    drop(shard_span);
    let shard_secs = sw.elapsed_s();
    let solved: Result<(PathResult, Option<DualHandoff>), String> = match solved {
        Err(payload) => Err(panic_message(payload)),
        Ok(outcome) => outcome,
    };

    // -- integrate the outcome. A job cancelled while its shard was
    // dispatched is discarded here — the fleet slot (if any) was already
    // released inside `solve_shard`, so cancellation never leaks it.
    let mut s = inner.state.lock().unwrap();
    let Some(job) = s.jobs.get_mut(&id) else { return };
    if job.state.is_terminal() {
        return; // cancelled mid-solve: discard the work
    }
    match solved {
        Err(msg) => {
            finish(inner, &mut s, id, Err(msg));
        }
        Ok((part, carried)) => {
            inner.metrics.incr("service_shards_solved", 1);
            inner.metrics.observe_secs("service_shard_solve_s", shard_secs);
            let progress = job.progress.as_mut().expect("job was planned");
            progress.parts.push(part);
            progress.carried = carried;
            if progress.parts.len() == progress.grids.len() {
                let parts = job.progress.take().expect("job was planned").parts;
                finish(inner, &mut s, id, Ok(Arc::new(stitch(parts))));
            } else {
                // Pipeline the next shard: back of its priority class.
                let priority = job.req.priority;
                let seq = s.next_seq;
                s.next_seq += 1;
                s.queue.push(QueueItem { priority, seq, id });
                inner.metrics.set("service_queue_depth", s.queue.len() as f64);
                inner.work.notify_one();
            }
        }
    }
}

/// Mark a non-terminal job terminal, publish its result (caching on
/// success), and wake waiters. Caller holds the lock and has verified the
/// job exists and is not terminal.
fn finish(inner: &Inner, s: &mut Shared, id: JobId, outcome: Result<Arc<PathResult>, String>) {
    let job = s.jobs.get_mut(&id).expect("caller verified the job exists");
    let latency = job.sw.elapsed_s();
    let cache_insert = match outcome {
        Ok(result) => {
            job.state = JobState::Done(result.clone());
            inner.metrics.incr("service_completed", 1);
            inner.metrics.observe_secs("service_job_latency_s", latency);
            Some((job.req.cache_key(), job.req.pb.clone(), result))
        }
        Err(msg) => {
            job.state = JobState::Failed(msg);
            inner.metrics.incr("service_failed", 1);
            None
        }
    };
    if let Some((key, pb, result)) = cache_insert {
        let evicted = s.cache.insert(key, CacheEntry { _pb: pb, result });
        if evicted > 0 {
            inner.metrics.incr("service_cache_evictions", evicted as u64);
        }
    }
    s.outstanding -= 1;
    s.completions.push_back(id);
    s.terminal.push_back(id);
    reap_excess(inner, s);
    inner.metrics.set("service_outstanding", s.outstanding as f64);
    inner.done.notify_all();
}

/// Trim the result store to `result_capacity` terminal jobs, oldest
/// *retrieved* jobs first. Undelivered results are never evicted — a
/// caller holding a `JobId` it has not consumed keeps that result alive,
/// so the store can transiently exceed the capacity until the caller
/// drains its completions; every retrieval re-runs this trim, so with
/// any consumer at all the store settles at the bound. A reaped id polls
/// as unknown and is dropped from the completion stream rather than
/// handed out dangling.
fn reap_excess(inner: &Inner, s: &mut Shared) {
    while s.terminal.len() > s.result_capacity {
        let Some(idx) = s
            .terminal
            .iter()
            .position(|id| s.jobs.get(id).is_none_or(|j| j.retrieved))
        else {
            break; // everything over capacity is still undelivered
        };
        let id = s.terminal.remove(idx).expect("index from a live scan");
        if s.jobs.remove(&id).is_some() {
            inner.metrics.incr("service_jobs_reaped", 1);
        }
        s.completions.retain(|c| *c != id);
    }
}

/// Derive the geometric grid of a request (used when `lambdas` is `None`).
fn lambda_max_grid(req: &SolveRequest) -> Vec<f64> {
    lambda_grid(req.pb.lambda_max(), req.opts.delta, req.opts.t_count)
}

pub(crate) fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<String>()
        .cloned()
        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "worker panicked".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::cd::SolveOptions;

    fn small_problem(seed: u64) -> Arc<SglProblem> {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 8,
            group_size: 3,
            gamma1: 3,
            gamma2: 2,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3))
    }

    fn cfg2x8() -> ServiceConfig {
        ServiceConfig { workers: 2, queue_depth: 8, ..Default::default() }
    }

    fn cfg1x4() -> ServiceConfig {
        ServiceConfig { workers: 1, queue_depth: 4, ..Default::default() }
    }

    fn req(pb: &Arc<SglProblem>, tol: f64) -> SolveRequest {
        SolveRequest {
            label: format!("t{tol:.0e}"),
            ..SolveRequest::new(
                AnyProblem::Dense(pb.clone()),
                PathOptions {
                    delta: 1.5,
                    t_count: 5,
                    solve: SolveOptions { tol, record_history: false, ..Default::default() },
                },
            )
        }
    }

    #[test]
    fn submit_wait_poll_lifecycle() {
        let pb = small_problem(1);
        let svc = SolveService::start(cfg2x8());
        let id = svc.submit(req(&pb, 1e-6)).unwrap();
        let res = svc.wait(id).unwrap();
        assert!(res.all_converged());
        assert_eq!(res.lambdas.len(), 5);
        assert_eq!(svc.poll(id), Some(JobStatus::Done));
        assert_eq!(svc.label(id), "t1e-6");
        assert!(!svc.was_cached(id));
        assert!(svc.result(id).is_some());
        assert!(svc.poll(JobId(999)).is_none());
        assert!(svc.wait(JobId(999)).is_err());
    }

    #[test]
    fn fingerprints_separate_configs_and_instances() {
        let pb = small_problem(2);
        let a = req(&pb, 1e-6);
        let b = req(&pb, 1e-6);
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), req(&pb, 1e-8).fingerprint());
        let mut c = req(&pb, 1e-6);
        c.shards = 4;
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Same data, different instance: different identity.
        let pb2 = Arc::new(SglProblem::clone(&pb));
        assert_ne!(a.fingerprint(), req(&pb2, 1e-6).fingerprint());
        // Label and priority are not part of the fingerprint.
        let mut d = req(&pb, 1e-6);
        d.label = "other".into();
        d.priority = 9;
        assert_eq!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn wait_next_drains_to_none() {
        let pb = small_problem(3);
        let svc = SolveService::start(cfg2x8());
        let ids: Vec<JobId> =
            (0..3).map(|k| svc.submit(req(&pb, 10f64.powi(-4 - k))).unwrap()).collect();
        let mut seen = Vec::new();
        while let Some(id) = svc.wait_next() {
            seen.push(id);
        }
        seen.sort();
        assert_eq!(seen, ids);
        // Drained: an immediate second call returns None, not a hang.
        assert_eq!(svc.wait_next(), None);
    }

    #[test]
    fn failed_solve_is_reported_not_propagated() {
        let pb = small_problem(4);
        let svc = SolveService::start(cfg1x4());
        // An increasing grid trips the path engine's assertion: the panic
        // must surface as a Failed job, and the worker must survive it.
        let mut bad = req(&pb, 1e-6);
        bad.lambdas = Some(vec![1.0, 2.0]);
        let bad_id = svc.submit(bad).unwrap();
        let err = svc.wait(bad_id).unwrap_err();
        assert!(format!("{err}").contains("failed"), "{err}");
        assert_eq!(svc.poll(bad_id), Some(JobStatus::Failed));
        // The worker is still alive and serves the next job.
        let ok_id = svc.submit(req(&pb, 1e-6)).unwrap();
        assert!(svc.wait(ok_id).unwrap().all_converged());
        assert_eq!(svc.metrics().counter("service_failed"), 1);
    }

    #[test]
    fn caches_are_bounded_with_lru_eviction_and_reaping() {
        let pb = small_problem(6);
        let svc = SolveService::start(ServiceConfig {
            workers: 2,
            queue_depth: 16,
            result_capacity: 4,
            cache_capacity: 3,
        });
        // Six distinct configs: more than either capacity.
        let tols: Vec<f64> = (0..6).map(|k| 10f64.powi(-(3 + k))).collect();
        let mut ids = Vec::new();
        for &tol in &tols {
            let id = svc.submit(req(&pb, tol)).unwrap();
            svc.wait(id).unwrap(); // retrieval marks the job reapable
            ids.push(id);
        }
        // Result store trimmed to capacity; the oldest retrieved jobs
        // were reaped and now poll as unknown.
        assert_eq!(svc.job_count(), 4);
        assert!(svc.poll(ids[0]).is_none());
        assert_eq!(svc.poll(ids[5]), Some(JobStatus::Done));
        assert!(svc.metrics().counter("service_jobs_reaped") >= 2);
        // Fingerprint cache trimmed with LRU order: the newest config
        // still hits, the oldest was evicted and must re-solve.
        assert_eq!(svc.cache_len(), 3);
        assert!(svc.metrics().counter("service_cache_evictions") >= 3);
        let hit = svc.submit(req(&pb, tols[5])).unwrap();
        assert!(svc.was_cached(hit));
        let miss = svc.submit(req(&pb, tols[0])).unwrap();
        assert!(!svc.was_cached(miss));
        assert!(svc.wait(miss).unwrap().all_converged());
        // The duplicate kept its entry warm; the store stays bounded.
        assert!(svc.cache_len() <= 3);
        assert!(svc.job_count() <= 4 + 1);
    }

    #[test]
    fn shutdown_rejects_new_submissions() {
        let pb = small_problem(5);
        let svc = SolveService::start(cfg1x4());
        svc.signal_shutdown();
        assert!(svc.submit(req(&pb, 1e-6)).is_err());
    }
}
