//! λ-range sharding: split one long warm-started path into `k` contiguous
//! λ-ranges solved as pipelined jobs, each shard resuming from its
//! predecessor's terminal β and dual point ([`DualHandoff`]).
//!
//! The sequential GAP-safe rule (paper Alg. 2 and the journal follow-up,
//! arXiv:1611.05780) screens each λ_t from the dual point carried out of
//! λ_{t−1}; warm starts make that gap small, which is what makes path
//! solving cheap. A shard boundary must preserve exactly that contract,
//! and [`crate::solver::path::solve_path_with_handoff`] does: the carried
//! dual point is replayed into the next shard's rule via
//! `on_solve_complete`, so screening fires across the boundary exactly as
//! it does mid-path and the sharded solve is bit-identical to the
//! monolithic one. Within one machine the shards of a single path run
//! sequentially (each needs its predecessor's handoff) — the point of the
//! split is that a boundary costs nothing, so a huge path can be spread
//! across workers or machines with only the small `DualHandoff` (β plus a
//! dual snapshot, `O(n + p)` floats) on the wire.
//!
//! [`solve_batch_interleaved`] is the cross-path scheduler on top: a
//! batch of sharded paths shares one pool of executor slots (local
//! threads, or a [`RemoteFleet`](super::remote::RemoteFleet) via a
//! closure over `solve_shard`), with the handoff dependency expressed as
//! a ready queue rather than a barrier, so *different* paths' shards
//! interleave — a k-shard path no longer serializes the fleet while each
//! of its shards runs.

use super::service::AnyProblem;
use crate::linalg::Design;
use crate::solver::datafit::Datafit;
use crate::solver::path::{
    solve_path_with_handoff, DualHandoff, PathOptions, PathResult,
};
use crate::solver::problem::SglProblem;
use crate::solver::SolverKind;
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex};

/// Split `0..n` into `min(k, n)` contiguous half-open ranges whose sizes
/// differ by at most one (earlier shards take the extra grid points —
/// they also carry the cheap high-λ end of the path).
pub fn plan_shards(n: usize, k: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.max(1).min(n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Concatenate shard results (already in λ order) back into one path
/// result. `total_s` sums the shards' solver wall-clock — queue time
/// between pipelined shards is deliberately excluded (the service reports
/// end-to-end latency separately).
pub fn stitch(parts: Vec<PathResult>) -> PathResult {
    let mut lambdas = Vec::new();
    let mut results = Vec::new();
    let mut total_s = 0.0;
    for p in parts {
        lambdas.extend(p.lambdas);
        results.extend(p.results);
        total_s += p.total_s;
    }
    PathResult { lambdas, results, total_s }
}

/// Single-machine reference for the sharded pipeline: plan the ranges,
/// solve each shard with the dual-point handoff, stitch. Produces the
/// same coefficient path as the monolithic engine (the equivalence the
/// service's pipelined execution relies on).
pub fn solve_path_sharded<D: Design, F: Datafit>(
    pb: &SglProblem<D, F>,
    lambdas: &[f64],
    opts: &PathOptions,
    solver: SolverKind,
    k: usize,
) -> PathResult {
    let mut parts = Vec::new();
    let mut carried: Option<DualHandoff> = None;
    for (a, b) in plan_shards(lambdas.len(), k) {
        let (part, h) =
            solve_path_with_handoff(pb, &lambdas[a..b], opts, solver, carried.as_ref());
        carried = h;
        parts.push(part);
    }
    stitch(parts)
}

/// One path job for the cross-path scheduler: backend-heterogeneous (the
/// fleet serves dense and CSC problems side by side), split into
/// `shards` contiguous λ-ranges.
pub struct InterleavedJob {
    pub pb: AnyProblem,
    /// Explicit non-increasing λ grid for the whole path.
    pub lambdas: Vec<f64>,
    pub opts: PathOptions,
    pub solver: SolverKind,
    /// λ-range shard count (≤ 1 = monolithic).
    pub shards: usize,
    /// Free-form tag for reports.
    pub label: String,
}

/// A shard executor: solve one λ-range of one job, resuming from the
/// predecessor shard's handoff. [`local_exec`] is the in-process
/// instantiation; `RemoteFleet::solve_shard` (wrapped in a closure) is
/// the distributed one.
pub type ShardOutcome = Result<(PathResult, Option<DualHandoff>)>;

/// In-process executor for [`solve_batch_interleaved`]: the reference
/// the fleet path is tested against.
pub fn local_exec(
    job: &InterleavedJob,
    grid: &[f64],
    handoff: Option<&DualHandoff>,
) -> ShardOutcome {
    Ok(job.pb.solve_range(grid, &job.opts, job.solver, handoff))
}

/// Cross-path shard scheduler: run a batch of sharded paths over `slots`
/// executor slots (fleet capacity, or local threads), interleaving
/// *different paths'* shards so a k-shard path never serializes the
/// fleet.
///
/// The predecessor-handoff dependency is expressed as a **ready queue**,
/// not a barrier: a job enters the queue when its next shard is
/// dispatchable (path head, or predecessor just completed), and
/// re-enters at the back after each shard — FIFO order round-robins the
/// fleet across paths. Within one path the shards still run strictly in
/// sequence with the handoff threaded through, so every path's result is
/// bit-identical to [`solve_path_sharded`] run locally; only the
/// *cross-path* schedule changes, and that was always embarrassingly
/// parallel.
///
/// A failing (or panicking) shard fails only its own job — the other
/// paths complete normally; `stitch` reassembles each path unchanged.
pub fn solve_batch_interleaved<E>(
    jobs: &[InterleavedJob],
    slots: usize,
    exec: E,
) -> Vec<Result<PathResult>>
where
    E: Fn(&InterleavedJob, &[f64], Option<&DualHandoff>) -> ShardOutcome + Sync,
{
    struct PathState {
        plan: Vec<(usize, usize)>,
        /// Next shard index to dispatch (parts.len() once in sync).
        parts: Vec<PathResult>,
        carried: Option<DualHandoff>,
        failed: Option<String>,
    }
    struct Sched {
        states: Vec<PathState>,
        /// Jobs whose next shard is dispatchable right now.
        ready: VecDeque<usize>,
        /// Jobs not yet fully solved (or failed).
        pending: usize,
    }

    let states: Vec<PathState> = jobs
        .iter()
        .map(|j| PathState {
            plan: plan_shards(j.lambdas.len(), j.shards.max(1)),
            parts: Vec::new(),
            carried: None,
            failed: None,
        })
        .collect();
    let ready: VecDeque<usize> =
        (0..jobs.len()).filter(|&i| !states[i].plan.is_empty()).collect();
    let pending = ready.len();
    let shared = Mutex::new(Sched { states, ready, pending });
    let work = Condvar::new();

    std::thread::scope(|scope| {
        for _ in 0..slots.max(1) {
            scope.spawn(|| loop {
                // -- claim the next ready shard (or retire this slot).
                let (ji, range, carried) = {
                    let mut sch = shared.lock().unwrap();
                    loop {
                        if sch.pending == 0 {
                            return;
                        }
                        if let Some(ji) = sch.ready.pop_front() {
                            let st = &mut sch.states[ji];
                            let range = st.plan[st.parts.len()];
                            // `take`, not `clone`: the handoff is
                            // consumed by exactly this successor shard,
                            // and an O(n+p) copy under the scheduler
                            // mutex would serialize other slots' claims.
                            break (ji, range, st.carried.take());
                        }
                        sch = work.wait(sch).unwrap();
                    }
                };
                // -- solve it outside the lock; a panic fails one job,
                // not the scheduler.
                let job = &jobs[ji];
                let grid = &job.lambdas[range.0..range.1];
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    exec(job, grid, carried.as_ref())
                }));
                // -- integrate and (maybe) make the successor ready.
                let mut sch = shared.lock().unwrap();
                match outcome {
                    Err(payload) => {
                        sch.states[ji].failed =
                            Some(super::service::panic_message(payload));
                        sch.pending -= 1;
                    }
                    Ok(Err(e)) => {
                        sch.states[ji].failed = Some(format!("{e:#}"));
                        sch.pending -= 1;
                    }
                    Ok(Ok((part, handoff))) => {
                        let st = &mut sch.states[ji];
                        st.parts.push(part);
                        st.carried = handoff;
                        if sch.states[ji].parts.len() == sch.states[ji].plan.len() {
                            sch.pending -= 1;
                        } else {
                            // Back of the queue: round-robin across paths.
                            sch.ready.push_back(ji);
                        }
                    }
                }
                work.notify_all();
            });
        }
    });

    shared
        .into_inner()
        .unwrap()
        .states
        .into_iter()
        .map(|st| match st.failed {
            Some(e) => Err(anyhow::anyhow!(e)),
            None => Ok(stitch(st.parts)),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::cd::SolveOptions;
    use crate::solver::path::solve_path_on_grid;
    use crate::solver::problem::lambda_grid;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn plan_covers_everything_exactly_once() {
        for (n, k) in [(10, 3), (7, 7), (100, 4), (5, 1), (6, 2)] {
            let plan = plan_shards(n, k);
            assert_eq!(plan.len(), k.min(n));
            assert_eq!(plan[0].0, 0);
            assert_eq!(plan.last().unwrap().1, n);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            let sizes: Vec<usize> = plan.iter().map(|(a, b)| b - a).collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1, "near-equal sizes: {sizes:?}");
            assert!(min >= 1);
        }
    }

    #[test]
    fn plan_edge_cases() {
        assert!(plan_shards(0, 4).is_empty());
        assert_eq!(plan_shards(3, 0), vec![(0, 3)]);
        assert_eq!(plan_shards(2, 5), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn sharded_small_path_matches_monolithic() {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 10,
            group_size: 3,
            gamma1: 3,
            gamma2: 2,
            seed: 9,
            ..Default::default()
        };
        let d = generate(&cfg);
        let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3);
        let lambdas = lambda_grid(pb.lambda_max(), 1.5, 7);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 7,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                record_history: false,
                ..Default::default()
            },
        };
        let mono = solve_path_on_grid(&pb, &lambdas, &opts);
        let sharded = solve_path_sharded(&pb, &lambdas, &opts, SolverKind::Cd, 3);
        assert_eq!(sharded.lambdas, mono.lambdas);
        assert_eq!(sharded.results.len(), mono.results.len());
        for (a, b) in mono.results.iter().zip(&sharded.results) {
            assert_eq!(a.beta, b.beta);
            assert_eq!(a.epochs, b.epochs);
        }
    }

    fn planted_any(seed: u64) -> (Arc<SglProblem>, AnyProblem) {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 10,
            group_size: 3,
            gamma1: 3,
            gamma2: 2,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        let pb =
            Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3));
        let any = AnyProblem::Dense(pb.clone());
        (pb, any)
    }

    fn seq_opts(t_count: usize) -> PathOptions {
        PathOptions {
            delta: 1.2,
            t_count,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                record_history: false,
                ..Default::default()
            },
        }
    }

    #[test]
    fn interleaved_batch_matches_solve_path_sharded_per_job() {
        let jobs: Vec<InterleavedJob> = (0..3)
            .map(|i| {
                let (pb, any) = planted_any(20 + i as u64);
                let lambdas = lambda_grid(pb.lambda_max(), 1.2, 7);
                InterleavedJob {
                    pb: any,
                    lambdas,
                    opts: seq_opts(7),
                    solver: SolverKind::Cd,
                    shards: 2 + i,
                    label: format!("job{i}"),
                }
            })
            .collect();
        for slots in [1usize, 3] {
            let out = solve_batch_interleaved(&jobs, slots, local_exec);
            for (job, got) in jobs.iter().zip(&out) {
                let got = got.as_ref().expect("job succeeds");
                let AnyProblem::Dense(pb) = &job.pb else { unreachable!() };
                let want = solve_path_sharded(
                    pb.as_ref(),
                    &job.lambdas,
                    &job.opts,
                    job.solver,
                    job.shards,
                );
                assert_eq!(got.lambdas, want.lambdas, "{} slots={slots}", job.label);
                for (a, b) in want.results.iter().zip(&got.results) {
                    assert_eq!(a.beta, b.beta, "{} slots={slots}", job.label);
                    assert_eq!(a.epochs, b.epochs, "{} slots={slots}", job.label);
                }
            }
        }
    }

    #[test]
    fn different_paths_interleave_but_one_path_stays_sequential() {
        let (pb, _) = planted_any(30);
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 4);
        let make = |k: usize| InterleavedJob {
            pb: AnyProblem::Dense(pb.clone()),
            lambdas: lambdas.clone(),
            opts: seq_opts(4),
            solver: SolverKind::Cd,
            shards: k,
            label: String::new(),
        };
        // Fake executor that only tracks concurrency (results are
        // dummies). When `rendezvous` is set, the *first* shard of each
        // path (recognizable by its grid head) waits — bounded — for the
        // sibling path's first shard, so the overlap assertion is
        // deterministic rather than resting on sleep-length vs
        // CI-scheduler luck.
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let rendezvous = std::sync::atomic::AtomicBool::new(false);
        let exec = |job: &InterleavedJob, grid: &[f64], _: Option<&DualHandoff>| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            if rendezvous.load(Ordering::SeqCst) && grid[0] == job.lambdas[0] {
                let t0 = std::time::Instant::now();
                while live.load(Ordering::SeqCst) < 2
                    && t0.elapsed() < std::time::Duration::from_secs(30)
                {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                peak.fetch_max(live.load(Ordering::SeqCst), Ordering::SeqCst);
            }
            live.fetch_sub(1, Ordering::SeqCst);
            Ok((
                PathResult { lambdas: grid.to_vec(), results: vec![], total_s: 0.0 },
                None,
            ))
        };
        // One 4-shard path on 2 slots: the handoff dependency serializes
        // it, so concurrency can never exceed 1.
        let out = solve_batch_interleaved(&[make(4)], 2, exec);
        assert!(out[0].is_ok());
        assert_eq!(peak.load(Ordering::SeqCst), 1, "one path must stay sequential");
        // Two 4-shard paths on 2 slots: both head shards are ready at
        // once, so both slots must claim them concurrently (the ready
        // queue holds both before either exec returns).
        peak.store(0, Ordering::SeqCst);
        rendezvous.store(true, Ordering::SeqCst);
        let out = solve_batch_interleaved(&[make(4), make(4)], 2, exec);
        assert!(out.iter().all(|r| r.is_ok()));
        assert_eq!(
            peak.load(Ordering::SeqCst),
            2,
            "two paths must interleave on two slots"
        );
    }

    #[test]
    fn one_failing_job_does_not_poison_the_batch() {
        let (pb, any) = planted_any(31);
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 4);
        let good = InterleavedJob {
            pb: any.clone(),
            lambdas: lambdas.clone(),
            opts: seq_opts(4),
            solver: SolverKind::Cd,
            shards: 2,
            label: "good".into(),
        };
        let bad = InterleavedJob {
            pb: any,
            // Increasing grid: the path engine panics on it; the
            // scheduler must convert that into this job's error.
            lambdas: vec![1.0, 2.0],
            opts: seq_opts(2),
            solver: SolverKind::Cd,
            shards: 1,
            label: "bad".into(),
        };
        let out = solve_batch_interleaved(&[good, bad], 2, local_exec);
        assert!(out[0].is_ok());
        let err = out[1].as_ref().expect_err("increasing grid must fail its job");
        assert!(format!("{err:#}").contains("non-increasing"), "{err:#}");
    }
}
