//! λ-range sharding: split one long warm-started path into `k` contiguous
//! λ-ranges solved as pipelined jobs, each shard resuming from its
//! predecessor's terminal β and dual point ([`DualHandoff`]).
//!
//! The sequential GAP-safe rule (paper Alg. 2 and the journal follow-up,
//! arXiv:1611.05780) screens each λ_t from the dual point carried out of
//! λ_{t−1}; warm starts make that gap small, which is what makes path
//! solving cheap. A shard boundary must preserve exactly that contract,
//! and [`crate::solver::path::solve_path_with_handoff`] does: the carried
//! dual point is replayed into the next shard's rule via
//! `on_solve_complete`, so screening fires across the boundary exactly as
//! it does mid-path and the sharded solve is bit-identical to the
//! monolithic one. Within one machine the shards of a single path run
//! sequentially (each needs its predecessor's handoff) — the point of the
//! split is that a boundary costs nothing, so a huge path can be spread
//! across workers or machines with only the small `DualHandoff` (β plus a
//! dual snapshot, `O(n + p)` floats) on the wire.

use crate::linalg::Design;
use crate::solver::path::{
    solve_path_with_handoff, DualHandoff, PathOptions, PathResult,
};
use crate::solver::problem::SglProblem;
use crate::solver::SolverKind;

/// Split `0..n` into `min(k, n)` contiguous half-open ranges whose sizes
/// differ by at most one (earlier shards take the extra grid points —
/// they also carry the cheap high-λ end of the path).
pub fn plan_shards(n: usize, k: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.max(1).min(n);
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Concatenate shard results (already in λ order) back into one path
/// result. `total_s` sums the shards' solver wall-clock — queue time
/// between pipelined shards is deliberately excluded (the service reports
/// end-to-end latency separately).
pub fn stitch(parts: Vec<PathResult>) -> PathResult {
    let mut lambdas = Vec::new();
    let mut results = Vec::new();
    let mut total_s = 0.0;
    for p in parts {
        lambdas.extend(p.lambdas);
        results.extend(p.results);
        total_s += p.total_s;
    }
    PathResult { lambdas, results, total_s }
}

/// Single-machine reference for the sharded pipeline: plan the ranges,
/// solve each shard with the dual-point handoff, stitch. Produces the
/// same coefficient path as the monolithic engine (the equivalence the
/// service's pipelined execution relies on).
pub fn solve_path_sharded<D: Design>(
    pb: &SglProblem<D>,
    lambdas: &[f64],
    opts: &PathOptions,
    solver: SolverKind,
    k: usize,
) -> PathResult {
    let mut parts = Vec::new();
    let mut carried: Option<DualHandoff> = None;
    for (a, b) in plan_shards(lambdas.len(), k) {
        let (part, h) =
            solve_path_with_handoff(pb, &lambdas[a..b], opts, solver, carried.as_ref());
        carried = h;
        parts.push(part);
    }
    stitch(parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::screening::RuleKind;
    use crate::solver::cd::SolveOptions;
    use crate::solver::path::solve_path_on_grid;
    use crate::solver::problem::lambda_grid;

    #[test]
    fn plan_covers_everything_exactly_once() {
        for (n, k) in [(10, 3), (7, 7), (100, 4), (5, 1), (6, 2)] {
            let plan = plan_shards(n, k);
            assert_eq!(plan.len(), k.min(n));
            assert_eq!(plan[0].0, 0);
            assert_eq!(plan.last().unwrap().1, n);
            for w in plan.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            let sizes: Vec<usize> = plan.iter().map(|(a, b)| b - a).collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1, "near-equal sizes: {sizes:?}");
            assert!(min >= 1);
        }
    }

    #[test]
    fn plan_edge_cases() {
        assert!(plan_shards(0, 4).is_empty());
        assert_eq!(plan_shards(3, 0), vec![(0, 3)]);
        assert_eq!(plan_shards(2, 5), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn sharded_small_path_matches_monolithic() {
        let cfg = SyntheticConfig {
            n: 30,
            n_groups: 10,
            group_size: 3,
            gamma1: 3,
            gamma2: 2,
            seed: 9,
            ..Default::default()
        };
        let d = generate(&cfg);
        let pb = SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3);
        let lambdas = lambda_grid(pb.lambda_max(), 1.5, 7);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 7,
            solve: SolveOptions {
                rule: RuleKind::GapSafeSeq,
                tol: 1e-8,
                record_history: false,
                ..Default::default()
            },
        };
        let mono = solve_path_on_grid(&pb, &lambdas, &opts);
        let sharded = solve_path_sharded(&pb, &lambdas, &opts, SolverKind::Cd, 3);
        assert_eq!(sharded.lambdas, mono.lambdas);
        assert_eq!(sharded.results.len(), mono.results.len());
        for (a, b) in mono.results.iter().zip(&sharded.results) {
            assert_eq!(a.beta, b.beta);
            assert_eq!(a.epochs, b.epochs);
        }
    }
}
