//! Solve-job orchestration: fan path solves and screening-rule comparisons
//! across worker threads. This is the L3 "service" surface the experiment
//! drivers and the CLI sit on.

use super::metrics::Metrics;
use crate::linalg::Design;
use crate::screening::RuleKind;
use crate::solver::cd::SolveOptions;
use crate::solver::path::{PathBatch, PathBatchJob, PathOptions, PathResult};
use crate::solver::problem::{lambda_grid, SglProblem};
use std::sync::Arc;

/// A rule-comparison job: one full λ-path per screening rule at a given
/// target accuracy (the unit of work behind Fig. 2c / 3b).
#[derive(Clone, Debug)]
pub struct RuleComparisonJob {
    pub rules: Vec<RuleKind>,
    pub tolerances: Vec<f64>,
    pub delta: f64,
    pub t_count: usize,
    pub fce: usize,
    pub max_epochs: usize,
    /// Timing mode: run the jobs one at a time on a single worker,
    /// ignoring `threads`. Per-job `PathResult::total_s` under a
    /// contended parallel run is not timing-grade (cores are shared), so
    /// benches that publish per-rule seconds set this instead of
    /// threading a `threads = 1` override through their plumbing.
    pub serial_timing: bool,
}

impl Default for RuleComparisonJob {
    fn default() -> Self {
        RuleComparisonJob {
            rules: RuleKind::all().to_vec(),
            tolerances: vec![1e-2, 1e-4, 1e-6, 1e-8],
            delta: 3.0,
            t_count: 100,
            fce: 10,
            max_epochs: 20_000,
            serial_timing: false,
        }
    }
}

/// One (rule, tolerance) measurement.
#[derive(Clone, Debug)]
pub struct RuleTiming {
    pub rule: RuleKind,
    pub tol: f64,
    pub seconds: f64,
    pub total_epochs: usize,
    pub converged: bool,
}

/// Run the comparison through the batched path engine: each (rule, tol)
/// pair is one [`PathBatchJob`] solving the whole warm-started path on its
/// own worker, all jobs sharing the one `Arc`'d problem instance (no copy
/// of `X` is ever made). Returns results in (tol-major, rule-minor) order.
pub fn run_rule_comparison<D: Design>(
    pb: Arc<SglProblem<D>>,
    job: &RuleComparisonJob,
    threads: usize,
    metrics: Option<Arc<Metrics>>,
) -> Vec<RuleTiming> {
    let lambda_max = pb.lambda_max();
    let lambdas = lambda_grid(lambda_max, job.delta, job.t_count);
    let mut cases: Vec<(RuleKind, f64)> = Vec::new();
    let mut batch = PathBatch::new();
    for &tol in &job.tolerances {
        for &rule in &job.rules {
            cases.push((rule, tol));
            batch.push(PathBatchJob {
                pb: pb.clone(),
                lambdas: Some(lambdas.clone()),
                opts: PathOptions {
                    delta: job.delta,
                    t_count: job.t_count,
                    solve: SolveOptions {
                        tol,
                        fce: job.fce,
                        max_epochs: job.max_epochs,
                        rule,
                        record_history: false,
                        ..Default::default()
                    },
                },
                tau_override: None,
                label: format!("{}@{tol:.0e}", rule.name()),
            });
        }
    }
    // Timing mode solves each job uncontended (everything else about the
    // engine is deterministic, so only the clocks depend on the choice).
    let paths: Vec<PathResult> =
        batch.run(if job.serial_timing { 1 } else { threads });
    cases
        .into_iter()
        .zip(paths)
        .map(|((rule, tol), path)| {
            if let Some(m) = &metrics {
                m.incr("paths_solved", 1);
                m.incr("epochs_total", path.total_epochs() as u64);
            }
            RuleTiming {
                rule,
                tol,
                seconds: path.total_s,
                total_epochs: path.total_epochs(),
                converged: path.all_converged(),
            }
        })
        .collect()
}

/// A whole-path job with per-check history (Fig. 2a/2b data).
#[derive(Clone, Debug)]
pub struct PathJob {
    pub rule: RuleKind,
    pub delta: f64,
    pub t_count: usize,
    pub tol: f64,
    pub fce: usize,
    pub max_epochs: usize,
}

impl Default for PathJob {
    fn default() -> Self {
        PathJob {
            rule: RuleKind::GapSafe,
            delta: 3.0,
            t_count: 100,
            tol: 1e-8,
            fce: 10,
            max_epochs: 20_000,
        }
    }
}

pub fn run_path<D: Design>(pb: &SglProblem<D>, job: &PathJob) -> PathResult {
    let opts = PathOptions {
        delta: job.delta,
        t_count: job.t_count,
        solve: SolveOptions {
            tol: job.tol,
            fce: job.fce,
            max_epochs: job.max_epochs,
            rule: job.rule,
            record_history: true,
            ..Default::default()
        },
    };
    crate::solver::path::solve_path(pb, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};

    fn small_problem() -> SglProblem {
        let cfg = SyntheticConfig {
            n: 40,
            n_groups: 12,
            group_size: 4,
            gamma1: 3,
            gamma2: 2,
            seed: 5,
            ..Default::default()
        };
        let d = generate(&cfg);
        SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3)
    }

    #[test]
    fn comparison_runs_all_cases() {
        let pb = small_problem();
        let job = RuleComparisonJob {
            rules: vec![RuleKind::None, RuleKind::GapSafe],
            tolerances: vec![1e-4, 1e-6],
            t_count: 8,
            delta: 2.0,
            ..Default::default()
        };
        let metrics = Arc::new(Metrics::new());
        let out = run_rule_comparison(Arc::new(pb), &job, 2, Some(metrics.clone()));
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|t| t.converged));
        assert_eq!(metrics.counter("paths_solved"), 4);
        // GAP safe should use no more epochs than no-screening at 1e-6.
        let gap = out
            .iter()
            .find(|t| t.rule == RuleKind::GapSafe && t.tol == 1e-6)
            .unwrap();
        let none = out
            .iter()
            .find(|t| t.rule == RuleKind::None && t.tol == 1e-6)
            .unwrap();
        assert!(gap.total_epochs <= none.total_epochs);
    }

    #[test]
    fn serial_timing_mode_reports_identical_results() {
        let pb = Arc::new(small_problem());
        let base = RuleComparisonJob {
            rules: vec![RuleKind::None, RuleKind::GapSafeSeq],
            tolerances: vec![1e-4],
            t_count: 6,
            delta: 2.0,
            ..Default::default()
        };
        let timed = RuleComparisonJob { serial_timing: true, ..base.clone() };
        let a = run_rule_comparison(pb.clone(), &base, 2, None);
        let b = run_rule_comparison(pb, &timed, 2, None);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.rule, y.rule);
            assert_eq!(x.tol, y.tol);
            // The timing mode only changes the clocks, not the arithmetic.
            assert_eq!(x.total_epochs, y.total_epochs);
            assert_eq!(x.converged, y.converged);
            assert!(y.seconds >= 0.0);
        }
    }

    #[test]
    fn path_job_records_history() {
        let pb = small_problem();
        let job = PathJob { t_count: 5, delta: 2.0, ..Default::default() };
        let res = run_path(&pb, &job);
        assert_eq!(res.results.len(), 5);
        assert!(res.results.iter().all(|r| !r.history.is_empty()));
    }
}
