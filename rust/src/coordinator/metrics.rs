//! Lightweight metrics registry for the solver service: thread-safe
//! counters, gauges and monotonic timers (min/max/mean histograms),
//! rendered to text or JSON for run reports.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Aggregated observations of one named timer: enough to report count,
/// min, max and mean without storing individual samples (the service
/// records one observation per job/shard, unbounded over its lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimerStats {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl TimerStats {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }
}

/// Process-wide metrics for a coordinator run.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    timers: Mutex<BTreeMap<String, TimerStats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    /// Set a named gauge.
    pub fn set(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Record one observation (in seconds — the unit is a convention, not
    /// enforced) into the named timer. The service uses this for job
    /// latency and queue wait; min/max/mean aggregate monotonically.
    pub fn observe_secs(&self, name: &str, secs: f64) {
        let mut map = self.timers.lock().unwrap();
        map.entry(name.to_string()).or_default().observe(secs);
    }

    /// Aggregated stats of a named timer, if it has any observations.
    pub fn timer(&self, name: &str) -> Option<TimerStats> {
        self.timers.lock().unwrap().get(name).copied()
    }

    /// Render all metrics as JSON.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            obj = obj.with(k, v.load(Ordering::Relaxed) as f64);
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            obj = obj.with(k, *v);
        }
        for (k, t) in self.timers.lock().unwrap().iter() {
            obj = obj
                .with(&format!("{k}_count"), t.count as f64)
                .with(&format!("{k}_sum"), t.sum)
                .with(&format!("{k}_min"), t.min)
                .with(&format!("{k}_max"), t.max)
                .with(&format!("{k}_mean"), t.mean());
        }
        obj
    }

    /// Render as `key value` lines (sorted).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, t) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k}_count {}\n{k}_sum {}\n{k}_min {}\n{k}_max {}\n{k}_mean {}\n",
                t.count,
                t.sum,
                t.min,
                t.max,
                t.mean()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves", 1);
        m.incr("solves", 2);
        assert_eq!(m.counter("solves"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("gap", 1e-3);
        m.set("gap", 1e-8);
        assert_eq!(m.gauge("gap"), Some(1e-8));
    }

    #[test]
    fn renders() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.set("b", 2.5);
        let text = m.render_text();
        assert!(text.contains("a 1"));
        assert!(text.contains("b 2.5"));
        assert!(m.to_json().dump().contains("\"a\":1"));
    }

    #[test]
    fn timers_aggregate_min_max_mean() {
        let m = Metrics::new();
        assert!(m.timer("lat").is_none());
        for v in [0.2, 0.1, 0.4] {
            m.observe_secs("lat", v);
        }
        let t = m.timer("lat").unwrap();
        assert_eq!(t.count, 3);
        assert!((t.min - 0.1).abs() < 1e-12);
        assert!((t.max - 0.4).abs() < 1e-12);
        assert!((t.sum - 0.7).abs() < 1e-12);
        assert!((t.mean() - 0.7 / 3.0).abs() < 1e-12);
        // A single observation pins min == max == mean.
        m.observe_secs("once", 2.5);
        let o = m.timer("once").unwrap();
        assert_eq!(o.min, 2.5);
        assert_eq!(o.max, 2.5);
        assert_eq!(o.mean(), 2.5);
        assert_eq!(TimerStats::default().mean(), 0.0);
    }

    #[test]
    fn timers_render_in_text_and_json() {
        let m = Metrics::new();
        m.observe_secs("lat", 0.5);
        m.observe_secs("lat", 1.5);
        let text = m.render_text();
        assert!(text.contains("lat_count 2"));
        assert!(text.contains("lat_min 0.5"));
        assert!(text.contains("lat_max 1.5"));
        assert!(text.contains("lat_mean 1"));
        let json = m.to_json().dump();
        assert!(json.contains("\"lat_count\":2"));
        assert!(json.contains("\"lat_mean\":1"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 4000);
    }
}
