//! Lightweight metrics registry for the solver service: thread-safe
//! counters and gauges, rendered to text or JSON for run reports.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Process-wide metrics for a coordinator run.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter by `delta`.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(delta, Ordering::Relaxed);
    }

    /// Set a named gauge.
    pub fn set(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Render all metrics as JSON.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            obj = obj.with(k, v.load(Ordering::Relaxed) as f64);
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            obj = obj.with(k, *v);
        }
        obj
    }

    /// Render as `key value` lines (sorted).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves", 1);
        m.incr("solves", 2);
        assert_eq!(m.counter("solves"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("gap", 1e-3);
        m.set("gap", 1e-8);
        assert_eq!(m.gauge("gap"), Some(1e-8));
    }

    #[test]
    fn renders() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.set("b", 2.5);
        let text = m.render_text();
        assert!(text.contains("a 1"));
        assert!(text.contains("b 2.5"));
        assert!(m.to_json().dump().contains("\"a\":1"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 4000);
    }
}
