//! Lightweight metrics registry for the solver service: thread-safe
//! counters, gauges and monotonic timers (count/sum/min/max plus bounded
//! log-bucket histograms with p50/p95/p99), rendered to text (Prometheus
//! exposition compatible) or JSON for run reports, and snapshot/merge
//! hooks so a coordinator can fold scraped remote-worker registries into
//! its own under a per-worker prefix.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Aggregated observations of one named timer: enough to report count,
/// min, max and mean without storing individual samples (the service
/// records one observation per job/shard, unbounded over its lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimerStats {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl TimerStats {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    fn observe(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }
}

/// Bounded log-bucket histogram: exponential buckets with
/// [`Histogram::SUB`] sub-buckets per doubling spanning
/// [`Histogram::LO`] ≤ v ≲ 1.7e4 (seconds, by the registry convention).
/// Memory is a fixed [`Histogram::BUCKETS`]-slot table per timer —
/// observations outside the span clamp to the edge buckets, so an
/// unbounded stream of samples never grows the registry.
///
/// Quantiles are bucket-resolved: [`Histogram::quantile`] returns the
/// upper bound of the bucket holding the requested rank, so the answer
/// overestimates the true order statistic by at most one bucket width
/// (a factor of `2^(1/SUB)` ≈ 19%).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// Lower edge of the first bucket (1 ns).
    pub const LO: f64 = 1e-9;
    /// Sub-buckets per doubling (`2^(1/4)` ≈ 1.19 growth per bucket).
    pub const SUB: usize = 4;
    /// Fixed bucket count: 44 doublings × [`Self::SUB`] covers
    /// 1 ns .. ~1.7e4 s.
    pub const BUCKETS: usize = 176;

    pub fn new() -> Self {
        Histogram { counts: vec![0; Self::BUCKETS], total: 0 }
    }

    /// Bucket index of `v` (clamped; NaN and non-positive map to 0).
    pub fn bucket_index(v: f64) -> usize {
        if v.is_nan() || v <= Self::LO {
            return 0;
        }
        let idx = ((v.log2() - Self::LO.log2()) * Self::SUB as f64).floor();
        (idx as usize).min(Self::BUCKETS - 1)
    }

    /// Upper bound of bucket `i`: `LO · 2^((i+1)/SUB)`.
    pub fn bucket_bound(i: usize) -> f64 {
        Self::LO * ((i + 1) as f64 / Self::SUB as f64).exp2()
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[Self::bucket_index(v)] += 1;
        self.total += 1;
    }

    /// Total observations recorded.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Bucket-resolved quantile `q ∈ [0, 1]`: the upper bound of the
    /// bucket containing rank `⌈q·total⌉` (0.0 when empty). Monotone in
    /// `q` by construction.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_bound(i);
            }
        }
        Self::bucket_bound(Self::BUCKETS - 1)
    }

    /// Non-empty buckets as `(index, count)` pairs — the wire/export
    /// representation ([`MetricsSnapshot`]).
    pub fn sparse(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i as u64, c))
            .collect()
    }

    /// Rebuild from [`Histogram::sparse`] pairs; out-of-range indices
    /// clamp to the last bucket (a newer peer may have a wider table).
    pub fn from_sparse(pairs: &[(u64, u64)]) -> Self {
        let mut h = Histogram::new();
        for &(i, c) in pairs {
            h.counts[(i as usize).min(Self::BUCKETS - 1)] += c;
            h.total += c;
        }
        h
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// One named timer: the scalar aggregate plus its histogram.
#[derive(Clone, Debug)]
struct TimerEntry {
    stats: TimerStats,
    hist: Histogram,
}

impl TimerEntry {
    fn new() -> Self {
        TimerEntry { stats: TimerStats::default(), hist: Histogram::new() }
    }

    fn observe(&mut self, v: f64) {
        self.stats.observe(v);
        self.hist.observe(v);
    }
}

/// A point-in-time copy of a whole registry — what a worker serializes
/// into a `StatsReply` and a coordinator merges back under a
/// `worker_<id>_` prefix ([`Metrics::merge_snapshot`]).
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// `(name, value)` counters, sorted by name.
    pub counters: Vec<(String, u64)>,
    /// `(name, value)` gauges, sorted by name.
    pub gauges: Vec<(String, f64)>,
    /// `(name, scalar stats, sparse histogram buckets)` timers.
    pub timers: Vec<(String, TimerStats, Vec<(u64, u64)>)>,
}

/// Process-wide metrics for a coordinator run.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    timers: Mutex<BTreeMap<String, TimerEntry>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter by `delta`. Get-then-entry: the steady
    /// state (counter already registered) takes the lock, bumps in
    /// place, and never allocates — `name.to_string()` only runs on the
    /// first observation of a name.
    pub fn incr(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().unwrap();
        match map.get_mut(name) {
            Some(c) => *c += delta,
            None => {
                map.insert(name.to_string(), delta);
            }
        }
    }

    /// Set a named counter to an absolute value (scrape-merge ingests
    /// remote totals, which must overwrite, not accumulate).
    pub fn set_counter(&self, name: &str, value: u64) {
        let mut map = self.counters.lock().unwrap();
        match map.get_mut(name) {
            Some(c) => *c = value,
            None => {
                map.insert(name.to_string(), value);
            }
        }
    }

    /// Set a named gauge.
    pub fn set(&self, name: &str, value: f64) {
        let mut map = self.gauges.lock().unwrap();
        match map.get_mut(name) {
            Some(g) => *g = value,
            None => {
                map.insert(name.to_string(), value);
            }
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Record one observation (in seconds — the unit is a convention, not
    /// enforced) into the named timer: scalar aggregate + histogram.
    pub fn observe_secs(&self, name: &str, secs: f64) {
        let mut map = self.timers.lock().unwrap();
        match map.get_mut(name) {
            Some(e) => e.observe(secs),
            None => {
                let mut e = TimerEntry::new();
                e.observe(secs);
                map.insert(name.to_string(), e);
            }
        }
    }

    /// Aggregated stats of a named timer, if it has any observations.
    pub fn timer(&self, name: &str) -> Option<TimerStats> {
        self.timers.lock().unwrap().get(name).map(|e| e.stats)
    }

    /// Bucket-resolved quantile of a named timer's histogram.
    pub fn timer_quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.timers.lock().unwrap().get(name).map(|e| e.hist.quantile(q))
    }

    /// Copy the whole registry out (wire export / tests).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self.counters.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect(),
            gauges: self.gauges.lock().unwrap().iter().map(|(k, v)| (k.clone(), *v)).collect(),
            timers: self
                .timers
                .lock()
                .unwrap()
                .iter()
                .map(|(k, e)| (k.clone(), e.stats, e.hist.sparse()))
                .collect(),
        }
    }

    /// Fold a scraped snapshot into this registry, prefixing every name
    /// with `prefix`. Entries **overwrite** (scrapes carry absolute
    /// worker totals — re-scraping must not double-count).
    pub fn merge_snapshot(&self, prefix: &str, snap: &MetricsSnapshot) {
        for (k, v) in &snap.counters {
            self.set_counter(&format!("{prefix}{k}"), *v);
        }
        for (k, v) in &snap.gauges {
            self.set(&format!("{prefix}{k}"), *v);
        }
        let mut map = self.timers.lock().unwrap();
        for (k, stats, sparse) in &snap.timers {
            let entry = TimerEntry { stats: *stats, hist: Histogram::from_sparse(sparse) };
            map.insert(format!("{prefix}{k}"), entry);
        }
    }

    /// Render all metrics as JSON.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            obj = obj.with(k, *v as f64);
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            obj = obj.with(k, *v);
        }
        for (k, e) in self.timers.lock().unwrap().iter() {
            let t = &e.stats;
            obj = obj
                .with(&format!("{k}_count"), t.count as f64)
                .with(&format!("{k}_sum"), t.sum)
                .with(&format!("{k}_min"), t.min)
                .with(&format!("{k}_max"), t.max)
                .with(&format!("{k}_mean"), t.mean())
                .with(&format!("{k}_p50"), e.hist.quantile(0.50))
                .with(&format!("{k}_p95"), e.hist.quantile(0.95))
                .with(&format!("{k}_p99"), e.hist.quantile(0.99));
        }
        obj
    }

    /// Render as `key value` lines (sorted), Prometheus exposition
    /// compatible: each metric is preceded by a `# TYPE` comment and
    /// names are sanitized to `[a-zA-Z0-9_:]`. Plain `key value`
    /// consumers are unaffected (comment lines start with `#`; names
    /// already in the valid charset render unchanged).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            let k = sanitize_metric_name(k);
            out.push_str(&format!("# TYPE {k} counter\n{k} {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            let k = sanitize_metric_name(k);
            out.push_str(&format!("# TYPE {k} gauge\n{k} {v}\n"));
        }
        for (k, e) in self.timers.lock().unwrap().iter() {
            let k = sanitize_metric_name(k);
            let t = &e.stats;
            out.push_str(&format!(
                "# TYPE {k} summary\n{k}_count {}\n{k}_sum {}\n{k}_min {}\n{k}_max {}\n{k}_mean {}\n{k}_p50 {}\n{k}_p95 {}\n{k}_p99 {}\n",
                t.count,
                t.sum,
                t.min,
                t.max,
                t.mean(),
                e.hist.quantile(0.50),
                e.hist.quantile(0.95),
                e.hist.quantile(0.99)
            ));
        }
        out
    }
}

/// Map a metric name into the Prometheus charset `[a-zA-Z0-9_:]`,
/// replacing invalid characters with `_` and prefixing a `_` when the
/// name would start with a digit. Names already valid pass through
/// unchanged (no allocation beyond the output string).
pub fn sanitize_metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let valid = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        if i == 0 && c.is_ascii_digit() {
            out.push('_');
        }
        out.push(if valid { c } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("solves", 1);
        m.incr("solves", 2);
        assert_eq!(m.counter("solves"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.set_counter("solves", 7);
        assert_eq!(m.counter("solves"), 7);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.set("gap", 1e-3);
        m.set("gap", 1e-8);
        assert_eq!(m.gauge("gap"), Some(1e-8));
    }

    #[test]
    fn renders() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.set("b", 2.5);
        let text = m.render_text();
        assert!(text.contains("a 1"));
        assert!(text.contains("b 2.5"));
        assert!(text.contains("# TYPE a counter"));
        assert!(text.contains("# TYPE b gauge"));
        assert!(m.to_json().dump().contains("\"a\":1"));
    }

    #[test]
    fn timers_aggregate_min_max_mean() {
        let m = Metrics::new();
        assert!(m.timer("lat").is_none());
        for v in [0.2, 0.1, 0.4] {
            m.observe_secs("lat", v);
        }
        let t = m.timer("lat").unwrap();
        assert_eq!(t.count, 3);
        assert!((t.min - 0.1).abs() < 1e-12);
        assert!((t.max - 0.4).abs() < 1e-12);
        assert!((t.sum - 0.7).abs() < 1e-12);
        assert!((t.mean() - 0.7 / 3.0).abs() < 1e-12);
        // A single observation pins min == max == mean.
        m.observe_secs("once", 2.5);
        let o = m.timer("once").unwrap();
        assert_eq!(o.min, 2.5);
        assert_eq!(o.max, 2.5);
        assert_eq!(o.mean(), 2.5);
        assert_eq!(TimerStats::default().mean(), 0.0);
    }

    #[test]
    fn timers_render_in_text_and_json() {
        let m = Metrics::new();
        m.observe_secs("lat", 0.5);
        m.observe_secs("lat", 1.5);
        let text = m.render_text();
        assert!(text.contains("lat_count 2"));
        assert!(text.contains("lat_min 0.5"));
        assert!(text.contains("lat_max 1.5"));
        assert!(text.contains("lat_mean 1"));
        assert!(text.contains("lat_p50 "));
        assert!(text.contains("lat_p99 "));
        assert!(text.contains("# TYPE lat summary"));
        let json = m.to_json().dump();
        assert!(json.contains("\"lat_count\":2"));
        assert!(json.contains("\"lat_mean\":1"));
        assert!(json.contains("\"lat_p95\":"));
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.incr("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 4000);
    }

    /// One bucket spans a factor of 2^(1/SUB); the quantile answer is
    /// the bucket's upper bound, so it may exceed the true order
    /// statistic by at most that factor (and never undershoots).
    fn assert_bucket_close(got: f64, truth: f64) {
        let factor = (1.0 / Histogram::SUB as f64).exp2();
        assert!(got >= truth * 0.999999, "quantile {got} undershoots {truth}");
        assert!(got <= truth * factor * 1.000001, "quantile {got} overshoots {truth}");
    }

    #[test]
    fn histogram_quantiles_on_known_distribution() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.observe(i as f64);
        }
        assert_eq!(h.total(), 100);
        assert_bucket_close(h.quantile(0.50), 50.0);
        assert_bucket_close(h.quantile(0.95), 95.0);
        assert_bucket_close(h.quantile(0.99), 99.0);
        assert_bucket_close(h.quantile(1.0), 100.0);
        // Monotone by construction.
        let (p50, p95, p99) = (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // Degenerate distribution: every quantile lands in one bucket.
        let mut one = Histogram::new();
        for _ in 0..10 {
            one.observe(3e-3);
        }
        assert_eq!(one.quantile(0.5), one.quantile(0.99));
        assert_bucket_close(one.quantile(0.5), 3e-3);
        // Empty histogram.
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_clamps_edges() {
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(-1.0), 0);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(1e-12), 0);
        assert_eq!(Histogram::bucket_index(1e30), Histogram::BUCKETS - 1);
        // Bounds are monotone across the table.
        for i in 1..Histogram::BUCKETS {
            assert!(Histogram::bucket_bound(i) > Histogram::bucket_bound(i - 1));
        }
    }

    #[test]
    fn histogram_sparse_roundtrip() {
        let mut h = Histogram::new();
        for v in [1e-6, 3e-4, 3e-4, 0.12, 7.0, 7.0, 7.0] {
            h.observe(v);
        }
        let back = Histogram::from_sparse(&h.sparse());
        assert_eq!(back, h);
        // Out-of-range index clamps instead of panicking.
        let clamped = Histogram::from_sparse(&[(u64::MAX, 2)]);
        assert_eq!(clamped.total(), 2);
    }

    #[test]
    fn snapshot_merge_prefixes_and_overwrites() {
        let w = Metrics::new();
        w.incr("solves", 5);
        w.set("in_flight", 2.0);
        w.observe_secs("solve_s", 0.25);
        w.observe_secs("solve_s", 0.75);
        let coord = Metrics::new();
        coord.incr("solves", 100); // must not collide with the prefixed copy
        coord.merge_snapshot("worker_0_", &w.snapshot());
        assert_eq!(coord.counter("solves"), 100);
        assert_eq!(coord.counter("worker_0_solves"), 5);
        assert_eq!(coord.gauge("worker_0_in_flight"), Some(2.0));
        let t = coord.timer("worker_0_solve_s").unwrap();
        assert_eq!(t.count, 2);
        assert!((t.sum - 1.0).abs() < 1e-12);
        let p50 = coord.timer_quantile("worker_0_solve_s", 0.5).unwrap();
        assert!(p50 > 0.0);
        // Re-scrape with updated totals overwrites, never accumulates.
        w.incr("solves", 1);
        coord.merge_snapshot("worker_0_", &w.snapshot());
        assert_eq!(coord.counter("worker_0_solves"), 6);
        let t2 = coord.timer("worker_0_solve_s").unwrap();
        assert_eq!(t2.count, 2);
    }

    #[test]
    fn metric_name_sanitization() {
        assert_eq!(sanitize_metric_name("ok_name:total"), "ok_name:total");
        assert_eq!(sanitize_metric_name("queue wait-ms"), "queue_wait_ms");
        assert_eq!(sanitize_metric_name("9lives"), "_9lives");
        assert_eq!(sanitize_metric_name(""), "_");
        let m = Metrics::new();
        m.incr("bad name", 1);
        assert!(m.render_text().contains("bad_name 1"));
    }
}
