//! L5: distributed λ-shard serving — TCP workers and the client fleet.
//!
//! PR 3 established that the *only* state crossing a λ-shard boundary is
//! a [`DualHandoff`] (terminal β + dual snapshot, `O(n + p)` floats), and
//! that replaying it through `on_solve_complete` makes a resumed shard
//! bit-identical to an uninterrupted path. This module takes that
//! boundary across machines:
//!
//! - [`WorkerServer`] — `sgl worker --listen host:port`: a
//!   `std::net::TcpListener` accept loop, one thread per connection,
//!   speaking the framed request/response protocol of
//!   [`crate::util::wire`]. Datasets arrive once
//!   ([`Message::ShipDataset`]), are held in an in-memory store keyed by
//!   content fingerprint, and every subsequent
//!   [`Message::SolveShard`] addresses them by hash. Solves run through
//!   the same [`AnyProblem::solve_range`] entry point as local workers,
//!   so a remote shard runs the identical arithmetic; a panicking solve
//!   becomes a typed [`RemoteErrorKind::SolveFailed`] frame, never a dead
//!   worker.
//! - [`RemoteFleet`] — the coordinator-side client pool: one or more
//!   persistent connections per worker, leased per shard with per-worker
//!   in-flight accounting. A worker disconnect (socket error mid-exchange)
//!   marks it dead and **requeues the shard onto the surviving workers**
//!   — every shard input (grid, options, handoff) lives on the
//!   coordinator, so nothing is ever lost with a worker; solves are
//!   deterministic, so re-running one is harmless. Heartbeats
//!   ([`RemoteFleet::heartbeat`]) probe liveness out of band — each
//!   `Pong` carries a compact [`WorkerSummary`] — and
//!   [`RemoteFleet::scrape`] pulls every worker's full metrics registry
//!   into the coordinator's under a `worker_<i>_` prefix.
//!
//! The fleet is **elastic and self-healing** (wire v6):
//!
//! - *Worker-initiated registration* — [`RemoteFleet::serve_registrations`]
//!   opens an accept loop; a (re)started worker announces itself with a
//!   [`Message::Register`] frame ([`WorkerServer::register`] retries until
//!   acked) and is admitted: a known address is revived with a bumped
//!   generation (stale leases from the dead incarnation are dropped on
//!   release, never mis-accounted) and a cleared shipped-set; a new
//!   address grows the fleet.
//! - *Progress-ping liveness* — while a shard solves, the worker pushes
//!   unsolicited [`Message::Progress`] frames (epoch + duality gap from
//!   the solver's gap checks, via [`crate::util::progress`]). With
//!   [`FleetConfig::progress_deadline`] set, the coordinator requeues a
//!   shard whose worker goes *silent* past the deadline — long solves are
//!   legitimate and keep pinging, so no socket read deadline ever bounds
//!   solve time itself.
//! - *Chunked dataset streaming* — datasets whose canonical encoding
//!   exceeds [`FleetConfig::ship_chunk_bytes`] ship as a
//!   [`Message::ShipBegin`] / [`Message::ShipChunk`]… /
//!   [`Message::ShipEnd`] stream of column ranges, reassembled and
//!   fingerprint-verified worker-side ([`ChunkAssembler`]) — datasets
//!   beyond the 2 GiB frame cap (or a worker's comfortable single
//!   allocation) travel incrementally, for one round trip total.
//!
//! Shipped-set entries commit only on the worker's ack and are cleared
//! whole on rejoin, so a connection lost mid-ship can never leave the
//! coordinator believing a worker holds a dataset it doesn't.
//!
//! The solve service drains into a fleet via
//! [`SolveService::with_fleet`](super::service::SolveService::with_fleet),
//! and [`super::shard::solve_batch_interleaved`] schedules *different
//! paths'* shards over one fleet so a k-shard path never serializes it.

use super::metrics::Metrics;
use super::service::{panic_message, AnyProblem};
use crate::solver::path::{DualHandoff, PathOptions, PathResult};
use crate::solver::sweep::SweepMode;
use crate::solver::SolverKind;
use crate::util::lru::LruCache;
use crate::util::pool::resolve_threads;
use crate::util::progress::{self, ProgressCell};
use crate::util::trace;
use crate::util::wire::{
    ChunkAssembler, ChunkBegin, ChunkPart, Message, ProblemPayload, RemoteError,
    RemoteErrorKind, ShardRequest, WireDatafit, WireDataset, WireError, WorkerSummary,
};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// How many datasets a worker retains (LRU beyond it). Eviction is
/// always safe: a coordinator referencing an evicted fingerprint gets a
/// typed `UnknownDataset` and reships transparently — the same path that
/// covers a restarted worker.
const WORKER_DATASET_CAPACITY: usize = 64;

/// Worker-side dataset store: fingerprint → problem, least-recently-used
/// bounded (the shared [`LruCache`]) so a long-lived worker (or a hostile
/// peer shipping datasets in a loop) cannot grow it without limit.
type DatasetStore = LruCache<u64, AnyProblem>;

/// Worker tuning knobs (`sgl worker --store-capacity --progress-ms`).
#[derive(Clone, Copy, Debug)]
pub struct WorkerOptions {
    /// Datasets the store retains before LRU eviction (min 1).
    pub dataset_capacity: usize,
    /// How often an in-flight solve pushes a [`Message::Progress`] frame
    /// to its coordinator; zero disables the pinger entirely.
    pub progress_interval: Duration,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        WorkerOptions {
            dataset_capacity: WORKER_DATASET_CAPACITY,
            progress_interval: Duration::from_millis(500),
        }
    }
}

/// Shared worker-side state every serve thread reports into: the full
/// metrics registry a [`Message::StatsRequest`] snapshots, plus the
/// atomics behind the compact [`WorkerSummary`] every `Pong` and
/// `Progress` frame carries (cheap enough to answer from the heartbeat
/// path without a scrape).
struct WorkerShared {
    metrics: Metrics,
    start: Instant,
    in_flight: AtomicU64,
    solves: AtomicU64,
    /// Progress pair of the most recently checked in-flight λ (epoch and
    /// duality-gap bits; NaN bits while nothing was observed). Written by
    /// each solve's pinger, so concurrent solves interleave — "most
    /// recent" is exactly the liveness semantics.
    epoch: AtomicU64,
    gap_bits: AtomicU64,
}

impl WorkerShared {
    fn new() -> Self {
        WorkerShared {
            metrics: Metrics::new(),
            start: Instant::now(),
            in_flight: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            gap_bits: AtomicU64::new(f64::NAN.to_bits()),
        }
    }

    fn summary(&self) -> WorkerSummary {
        WorkerSummary {
            in_flight: self.in_flight.load(Ordering::Relaxed),
            solves: self.solves.load(Ordering::Relaxed),
            uptime_ticks: self.start.elapsed().as_secs(),
            epoch: self.epoch.load(Ordering::Relaxed),
            gap_bits: self.gap_bits.load(Ordering::Relaxed),
        }
    }
}

/// A remote solve worker: accept loop + per-connection serve threads over
/// a shared fingerprint-keyed, LRU-bounded dataset store. In-process
/// instances back the loopback tests and benches; `sgl worker` wraps one
/// for real deployments.
pub struct WorkerServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<(u64, TcpStream)>>>,
    accept: Option<thread::JoinHandle<()>>,
    shared: Arc<WorkerShared>,
}

impl WorkerServer {
    /// Bind and start accepting (`"host:0"` picks a free port — read it
    /// back with [`local_addr`](Self::local_addr)).
    pub fn bind(addr: &str) -> Result<WorkerServer> {
        Self::bind_with(addr, WorkerOptions::default())
    }

    /// [`bind`](Self::bind) with explicit [`WorkerOptions`].
    pub fn bind_with(addr: &str, opts: WorkerOptions) -> Result<WorkerServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding worker listener on {addr}"))?;
        let local = listener.local_addr().context("reading bound address")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::default();
        let store = Arc::new(Mutex::new(DatasetStore::new(opts.dataset_capacity.max(1))));
        let shared = Arc::new(WorkerShared::new());
        let accept = {
            let shutdown = shutdown.clone();
            let conns = conns.clone();
            let shared = shared.clone();
            thread::spawn(move || {
                let mut next_id: u64 = 0;
                for stream in listener.incoming() {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else {
                        // Transient accept failure (e.g. EMFILE): back
                        // off instead of spinning the accept loop hot.
                        thread::sleep(Duration::from_millis(20));
                        continue;
                    };
                    stream.set_nodelay(true).ok();
                    let id = next_id;
                    next_id += 1;
                    // Track a dup of the fd so kill() can hard-close
                    // live connections; the serve thread untracks it on
                    // exit so a long-lived worker doesn't leak one fd
                    // per connection it ever served.
                    if let Ok(clone) = stream.try_clone() {
                        conns.lock().unwrap().push((id, clone));
                    }
                    let store = store.clone();
                    let conns = conns.clone();
                    let shared = shared.clone();
                    thread::spawn(move || {
                        serve_conn(stream, &store, &shared, opts);
                        conns.lock().unwrap().retain(|(cid, _)| *cid != id);
                    });
                }
            })
        };
        Ok(WorkerServer { addr: local, shutdown, conns, accept: Some(accept), shared })
    }

    /// Announce this worker to a coordinator's registration listener
    /// ([`RemoteFleet::serve_registrations`]) from a background thread,
    /// retrying until the coordinator acks with
    /// [`Message::Registered`] or the worker shuts down. This is how a
    /// restarted worker rejoins a fleet instead of staying marked dead:
    /// `sgl worker --register coord:port` calls it right after binding.
    pub fn register(&self, coordinator: &str) {
        let coordinator = coordinator.to_string();
        let addr = self.addr.to_string();
        let shutdown = self.shutdown.clone();
        thread::spawn(move || {
            while !shutdown.load(Ordering::SeqCst) {
                if try_register(&coordinator, &addr) {
                    return;
                }
                thread::sleep(Duration::from_millis(200));
            }
        });
    }

    /// The actually bound address (resolves a `:0` port request).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Abrupt stop: stop accepting and hard-close every live connection
    /// (clients see an immediate socket error, mid-frame if one is in
    /// flight). This is the fault-injection lever the requeue tests use —
    /// equivalent to the worker process dying.
    pub fn kill(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop (it re-checks the flag per connection).
        let _ = TcpStream::connect(self.addr);
        for (_, s) in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
    }

    /// Block on the accept loop (the `sgl worker` foreground mode; runs
    /// until the process is killed).
    pub fn serve_forever(mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.kill();
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

/// One registration attempt: dial, announce, await the ack.
fn try_register(coordinator: &str, addr: &str) -> bool {
    let Ok(mut s) = TcpStream::connect(coordinator) else { return false };
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(Duration::from_secs(5))).ok();
    if Message::Register { addr: addr.to_string() }.write_to(&mut s).is_err() {
        return false;
    }
    matches!(Message::read_from(&mut s), Ok(Message::Registered { .. }))
}

/// Blocking entry behind `sgl worker --listen addr`: bind, announce the
/// bound address on stdout (supervisors and the process-spawning tests
/// parse this line), serve until killed.
pub fn run_worker(addr: &str) -> Result<()> {
    run_worker_with(addr, WorkerOptions::default(), None)
}

/// [`run_worker`] with explicit [`WorkerOptions`] and an optional
/// coordinator registration address (`sgl worker --register`).
pub fn run_worker_with(
    addr: &str,
    opts: WorkerOptions,
    register: Option<&str>,
) -> Result<()> {
    let server = WorkerServer::bind_with(addr, opts)?;
    println!("worker listening on {}", server.local_addr());
    std::io::stdout().flush().ok();
    if let Some(coordinator) = register {
        server.register(coordinator);
    }
    server.serve_forever();
    Ok(())
}

/// Per-connection state of a chunked dataset ship. Begin/Chunk frames
/// are unacked (the transfer costs one round trip); errors latch here
/// and the worker keeps draining chunks until the sealing `ShipEnd`,
/// whose single reply carries the verdict — replying early would
/// write-write deadlock against a coordinator still streaming chunks.
enum ShipState {
    Idle,
    Assembling(Box<ChunkAssembler>),
    Failed(RemoteError),
}

fn open_ship(state: &mut ShipState, begin: ChunkBegin, shared: &WorkerShared) {
    shared.metrics.incr("worker_chunked_ships_opened", 1);
    // A Begin always starts fresh: an interrupted earlier ship on this
    // connection is abandoned, never spliced into.
    *state = match ChunkAssembler::new(begin) {
        Ok(asm) => ShipState::Assembling(Box::new(asm)),
        Err(e) => ShipState::Failed(RemoteError {
            kind: RemoteErrorKind::BadRequest,
            detail: format!("invalid chunked ship: {e}"),
        }),
    };
}

fn add_chunk(state: &mut ShipState, part: ChunkPart, shared: &WorkerShared) {
    shared.metrics.incr("worker_chunks_received", 1);
    match state {
        ShipState::Assembling(asm) => {
            if let Err(e) = asm.chunk(part) {
                *state = ShipState::Failed(RemoteError {
                    kind: RemoteErrorKind::BadRequest,
                    detail: format!("invalid chunk: {e}"),
                });
            }
        }
        ShipState::Idle => {
            *state = ShipState::Failed(RemoteError {
                kind: RemoteErrorKind::BadRequest,
                detail: "chunk arrived without an open ship".to_string(),
            });
        }
        // Already failed: drain the rest of the stream quietly; the
        // verdict goes out with the ShipEnd reply.
        ShipState::Failed(_) => {}
    }
}

fn finish_ship(
    state: &mut ShipState,
    fingerprint: u64,
    store: &Mutex<DatasetStore>,
    shared: &WorkerShared,
) -> Message {
    match std::mem::replace(state, ShipState::Idle) {
        ShipState::Assembling(asm) => match asm.finish(fingerprint) {
            Ok(ds) => {
                shared.metrics.incr("worker_chunked_ships_completed", 1);
                store_dataset(fingerprint, ds, store, shared)
            }
            Err(e) => Message::Error(RemoteError {
                kind: RemoteErrorKind::BadRequest,
                detail: format!("chunked ship failed: {e}"),
            }),
        },
        ShipState::Failed(err) => Message::Error(err),
        ShipState::Idle => Message::Error(RemoteError {
            kind: RemoteErrorKind::BadRequest,
            detail: "ship-end arrived without an open ship".to_string(),
        }),
    }
}

/// Validate and store an arrived dataset under `fingerprint`, counting
/// LRU evictions (an evicted fingerprint is safe: the coordinator
/// reships transparently on `UnknownDataset`).
fn store_dataset(
    fingerprint: u64,
    ds: WireDataset,
    store: &Mutex<DatasetStore>,
    shared: &WorkerShared,
) -> Message {
    match ds.into_problem() {
        Ok(payload) => {
            let pb = match payload {
                ProblemPayload::Dense(p) => AnyProblem::Dense(Arc::new(p)),
                ProblemPayload::Csc(p) => AnyProblem::Csc(Arc::new(p)),
                ProblemPayload::DenseLogistic(p) => AnyProblem::DenseLogistic(Arc::new(p)),
                ProblemPayload::CscLogistic(p) => AnyProblem::CscLogistic(Arc::new(p)),
                ProblemPayload::DenseMultiTask(p) => {
                    AnyProblem::DenseMultiTask(Arc::new(p))
                }
                ProblemPayload::CscMultiTask(p) => AnyProblem::CscMultiTask(Arc::new(p)),
            };
            let evicted = store.lock().unwrap().insert(fingerprint, pb);
            if evicted > 0 {
                shared.metrics.incr("worker_dataset_evictions", evicted as u64);
            }
            shared.metrics.incr("worker_datasets_stored", 1);
            Message::DatasetKnown { fingerprint, known: true }
        }
        Err(e) => Message::Error(RemoteError {
            kind: RemoteErrorKind::BadRequest,
            detail: format!("invalid dataset: {e}"),
        }),
    }
}

fn serve_conn(
    mut stream: TcpStream,
    store: &Arc<Mutex<DatasetStore>>,
    shared: &Arc<WorkerShared>,
    opts: WorkerOptions,
) {
    // All writes to this connection — replies here, Progress frames from
    // a solve's pinger thread — serialize through one mutex so frames
    // can never interleave mid-frame on the wire.
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut ship = ShipState::Idle;
    loop {
        let (msg, body) = match Message::read_opt_with_body(&mut stream) {
            Ok(Some(m)) => m,
            // Clean close between frames, or the peer vanished: done.
            Ok(None) | Err(WireError::Io(_)) => return,
            // Undecodable bytes: answer with a typed error frame (the
            // peer may log it), then drop the connection — framing can
            // no longer be trusted.
            Err(e) => {
                let mut w = writer.lock().unwrap();
                let _ = Message::Error(RemoteError {
                    kind: RemoteErrorKind::BadRequest,
                    detail: format!("undecodable frame: {e}"),
                })
                .write_to(&mut *w);
                return;
            }
        };
        let reply = match msg {
            // The chunked-ship frames are the protocol's only unacked
            // requests (see ShipState); everything else is one reply per
            // request.
            Message::ShipBegin(begin) => {
                open_ship(&mut ship, begin, shared);
                continue;
            }
            Message::ShipChunk(part) => {
                add_chunk(&mut ship, part, shared);
                continue;
            }
            Message::ShipEnd { fingerprint } => {
                finish_ship(&mut ship, fingerprint, store, shared)
            }
            msg => handle_request(msg, &body, store, shared, &writer, opts),
        };
        drop(body);
        // An unframeable reply (e.g. a PathResult beyond the 2 GiB frame
        // cap) must become a typed error, not a panicked serve thread —
        // a closed socket would read as a worker death and make the
        // coordinator requeue the identical shard onto the next worker,
        // cascading the failure across the fleet.
        let frame = reply.try_encode().unwrap_or_else(|e| {
            Message::Error(RemoteError {
                kind: RemoteErrorKind::SolveFailed,
                detail: format!("result cannot be framed: {e}"),
            })
            .encode()
        });
        let mut w = writer.lock().unwrap();
        if w.write_all(&frame).and_then(|()| w.flush()).is_err() {
            return;
        }
    }
}

/// Spawn the progress pinger for one in-flight solve: every interval it
/// folds the solve's [`ProgressCell`] into the shared summary and pushes
/// a [`Message::Progress`] frame through the connection's write mutex.
/// The caller stops it (flag + unpark + join) *before* writing the reply,
/// so the stream is always `Progress* · reply` — never interleaved.
fn spawn_pinger(
    writer: Arc<Mutex<TcpStream>>,
    shared: Arc<WorkerShared>,
    cell: Arc<ProgressCell>,
    stop: Arc<AtomicBool>,
    interval: Duration,
) -> thread::JoinHandle<()> {
    thread::spawn(move || loop {
        thread::park_timeout(interval);
        if stop.load(Ordering::SeqCst) {
            return;
        }
        shared.epoch.store(cell.epoch(), Ordering::Relaxed);
        shared.gap_bits.store(cell.gap_bits(), Ordering::Relaxed);
        let frame = Message::Progress { summary: shared.summary() }.encode();
        let mut w = writer.lock().unwrap();
        if w.write_all(&frame).and_then(|()| w.flush()).is_err() {
            // The coordinator is gone; the solve itself discovers this
            // when its reply write fails.
            return;
        }
    })
}

/// One request frame → exactly one reply frame. `body` is the raw frame
/// body the request was decoded from (`version ∥ tag ∥ payload`).
fn handle_request(
    msg: Message,
    body: &[u8],
    store: &Arc<Mutex<DatasetStore>>,
    shared: &Arc<WorkerShared>,
    writer: &Arc<Mutex<TcpStream>>,
    opts: WorkerOptions,
) -> Message {
    match msg {
        Message::Ping { seq } => Message::Pong { seq, summary: shared.summary() },
        Message::StatsRequest => {
            // Fold the summary atomics into the registry right before the
            // snapshot so a scrape and a heartbeat can never disagree.
            let s = shared.summary();
            shared.metrics.set("worker_in_flight", s.in_flight as f64);
            shared.metrics.set("worker_uptime_ticks", s.uptime_ticks as f64);
            Message::StatsReply(shared.metrics.snapshot())
        }
        Message::HasDataset { fingerprint } => Message::DatasetKnown {
            fingerprint,
            known: store.lock().unwrap().contains(&fingerprint),
        },
        Message::ShipDataset(ds) => {
            // The payload bytes are the canonical encoding, so hashing
            // them directly gives the sender's fingerprint without
            // re-encoding the (potentially huge) dataset we just parsed
            // (`wire::tests::dataset_fingerprint_is_content_addressed`
            // pins this equality).
            let fingerprint = crate::util::wire::fnv1a64(&body[2..]);
            store_dataset(fingerprint, ds, store, shared)
        }
        Message::SolveShard(req) => {
            // Clone the `Arc` out and solve off-lock: connections solve
            // concurrently against the shared read-only store.
            let pb = store.lock().unwrap().get(&req.dataset).cloned();
            match pb {
                None => Message::Error(RemoteError {
                    kind: RemoteErrorKind::UnknownDataset,
                    detail: format!(
                        "dataset {:016x} has not been shipped to this worker",
                        req.dataset
                    ),
                }),
                // The request names the datafit it expects to solve
                // under; a mismatch against the stored dataset means a
                // stale store or a fingerprint collision — answer typed
                // rather than silently solving the wrong loss.
                Some(pb) if req.datafit != wire_datafit(&pb) => {
                    Message::Error(RemoteError {
                        kind: RemoteErrorKind::BadRequest,
                        detail: format!(
                            "datafit mismatch: request expects {}, dataset {:016x} holds {}",
                            req.datafit.name(),
                            req.dataset,
                            wire_datafit(&pb).name()
                        ),
                    })
                }
                Some(pb) => {
                    let ShardRequest { lambdas, solver, opts: path_opts, handoff, .. } = req;
                    shared.in_flight.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    // Liveness: the solver publishes (epoch, gap) into
                    // the cell at every gap check; the pinger streams it
                    // to the coordinator. Observation-only — the solve's
                    // arithmetic is bit-identical with or without it.
                    let cell = ProgressCell::new();
                    let stop = Arc::new(AtomicBool::new(false));
                    let pinger = (!opts.progress_interval.is_zero()).then(|| {
                        spawn_pinger(
                            writer.clone(),
                            shared.clone(),
                            cell.clone(),
                            stop.clone(),
                            opts.progress_interval,
                        )
                    });
                    let prev_cell = progress::set_current(Some(cell));
                    let sp = trace::span_with("worker_shard", || {
                        vec![("lambdas", lambdas.len().into())]
                    });
                    let solved = catch_unwind(AssertUnwindSafe(|| {
                        pb.solve_range(&lambdas, &path_opts, solver, handoff.as_ref())
                    }));
                    drop(sp);
                    progress::set_current(prev_cell);
                    if let Some(pinger) = pinger {
                        // Stop + join BEFORE the reply goes out: the last
                        // frame a coordinator reads for this exchange is
                        // the reply, with any Progress strictly before it.
                        stop.store(true, Ordering::SeqCst);
                        pinger.thread().unpark();
                        let _ = pinger.join();
                    }
                    shared.metrics.observe_secs("worker_shard_solve_s", t0.elapsed().as_secs_f64());
                    shared.in_flight.fetch_sub(1, Ordering::Relaxed);
                    match solved {
                        Ok((result, handoff)) => {
                            shared.solves.fetch_add(1, Ordering::Relaxed);
                            shared.metrics.incr("worker_shards_solved", 1);
                            shared
                                .metrics
                                .incr("worker_lambdas_solved", lambdas.len() as u64);
                            Message::ShardDone { result, handoff }
                        }
                        Err(p) => {
                            shared.metrics.incr("worker_shards_failed", 1);
                            Message::Error(RemoteError {
                                kind: RemoteErrorKind::SolveFailed,
                                detail: panic_message(p),
                            })
                        }
                    }
                }
            }
        }
        // Replies, coordinator-bound frames, and ship frames (handled in
        // `serve_conn` before this dispatch) are all out of protocol in a
        // request position.
        Message::Pong { .. }
        | Message::StatsReply(_)
        | Message::DatasetKnown { .. }
        | Message::ShardDone { .. }
        | Message::Error(_)
        | Message::Register { .. }
        | Message::Registered { .. }
        | Message::Progress { .. }
        | Message::ShipBegin(_)
        | Message::ShipChunk(_)
        | Message::ShipEnd { .. } => Message::Error(RemoteError {
            kind: RemoteErrorKind::BadRequest,
            detail: "frame out of protocol in a request position".to_string(),
        }),
    }
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

/// Fleet sizing and elasticity knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Persistent connections opened to each worker — the worker's
    /// in-flight shard capacity from this coordinator's point of view.
    pub conns_per_worker: usize,
    /// Datasets whose canonical encoding exceeds this many bytes ship as
    /// a `ShipBegin · ShipChunk* · ShipEnd` sequence of column-range
    /// frames instead of one monolithic `ShipDataset` frame, so a
    /// dataset larger than [`MAX_FRAME`](crate::util::wire::MAX_FRAME)
    /// (or a worker's memory headroom) still ships. Each chunk's frame
    /// stays under roughly this budget.
    pub ship_chunk_bytes: usize,
    /// When non-zero, every reply read during an exchange is bounded by
    /// this deadline *between frames*: a worker mid-solve keeps the
    /// exchange alive by pushing [`Message::Progress`] pings, so a long
    /// solve is never misclassified — only a worker that stops pinging
    /// (killed -9, wedged kernel, partitioned) trips the deadline and
    /// gets its shard requeued. Zero (the default) disables the
    /// deadline: a silent-dead worker then hangs the exchange until the
    /// OS gives up on the socket.
    pub progress_deadline: Duration,
    /// When non-zero, `acquire` with zero surviving workers waits this
    /// long for a worker to rejoin through the registration listener
    /// (see [`RemoteFleet::serve_registrations`]) before failing the
    /// shard. Zero (the default) fails immediately — the pre-elastic
    /// contract the dead-fleet tests pin.
    pub rejoin_grace: Duration,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            conns_per_worker: 1,
            ship_chunk_bytes: 1 << 30,
            progress_deadline: Duration::ZERO,
            rejoin_grace: Duration::ZERO,
        }
    }
}

struct WorkerState {
    addr: String,
    alive: bool,
    /// Channels currently leased to an in-flight exchange.
    busy: usize,
    /// Bumped every time this address (re)joins through `admit`. A lease
    /// carries the generation it was minted under; a release whose
    /// generation no longer matches belongs to a dead incarnation and
    /// must not touch the new one's accounting.
    generation: u64,
    /// Dataset fingerprints this worker has *acknowledged* (committed on
    /// `DatasetKnown` only — never optimistically, so a worker that dies
    /// between ship and ack is never believed to hold the dataset).
    shipped: HashSet<u64>,
    /// Fingerprints currently being shipped on some lease: elects one
    /// concurrent lease as the shipper without pre-committing `shipped`.
    shipping: HashSet<u64>,
    /// Parked connections (`None` while leased or after death). Living
    /// inside the state mutex lets `admit` grow/replace them at runtime.
    chans: Vec<Option<TcpStream>>,
}

impl WorkerState {
    fn fresh(addr: String, chans: Vec<Option<TcpStream>>) -> WorkerState {
        WorkerState {
            addr,
            alive: true,
            busy: 0,
            generation: 0,
            shipped: HashSet::new(),
            shipping: HashSet::new(),
            chans,
        }
    }
}

struct FleetShared {
    workers: Vec<WorkerState>,
}

fn total_busy(st: &FleetShared) -> usize {
    st.workers.iter().map(|w| w.busy).sum()
}

/// Snapshot a problem into its transferable form on the matching backend
/// (the datafit rides along inside the [`WireDataset`]).
fn wire_dataset(pb: &AnyProblem) -> WireDataset {
    match pb {
        AnyProblem::Dense(p) => WireDataset::from_dense(p),
        AnyProblem::Csc(p) => WireDataset::from_csc(p),
        AnyProblem::DenseLogistic(p) => WireDataset::from_dense(p),
        AnyProblem::CscLogistic(p) => WireDataset::from_csc(p),
        AnyProblem::DenseMultiTask(p) => WireDataset::from_dense(p),
        AnyProblem::CscMultiTask(p) => WireDataset::from_csc(p),
    }
}

/// The problem's datafit in transferable form, for the request-side tag
/// the worker cross-checks against its stored dataset.
fn wire_datafit(pb: &AnyProblem) -> WireDatafit {
    match pb {
        AnyProblem::Dense(p) => WireDatafit::of(&p.datafit),
        AnyProblem::Csc(p) => WireDatafit::of(&p.datafit),
        AnyProblem::DenseLogistic(p) => WireDatafit::of(&p.datafit),
        AnyProblem::CscLogistic(p) => WireDatafit::of(&p.datafit),
        AnyProblem::DenseMultiTask(p) => WireDatafit::of(&p.datafit),
        AnyProblem::CscMultiTask(p) => WireDatafit::of(&p.datafit),
    }
}

/// How many problem-instance fingerprints the fleet caches (LRU beyond
/// it). Purely a cost cache — an evicted instance just re-fingerprints
/// (one dataset encode) on its next shard — so the bound cannot affect
/// correctness, and the coordinator no longer pins every dataset it ever
/// served for the fleet's lifetime.
const FLEET_FINGERPRINT_CAPACITY: usize = 256;

struct FingerprintEntry {
    fp: u64,
    /// Pins the identity pointer for the entry's lifetime (an `Arc`
    /// clone — evicting the entry drops the pin together with the key it
    /// guards, so a recycled pointer can never alias a stale mapping).
    _pb: AnyProblem,
}

/// Problem-instance identity → content fingerprint, LRU-bounded by
/// [`FLEET_FINGERPRINT_CAPACITY`].
type DatasetRegistry = LruCache<(u8, usize), FingerprintEntry>;

/// One worker's heartbeat outcome: dead, alive-but-busy (every channel
/// was mid-exchange, so nothing was probed and no summary is available),
/// or alive with the [`WorkerSummary`] its `Pong` carried.
#[derive(Clone, Copy, Debug)]
pub enum Liveness {
    /// The worker is marked dead (or the probe just killed it).
    Dead,
    /// Every channel was leased to an in-flight exchange: busy implies
    /// reachable, but there is no summary without a probe.
    Busy,
    /// The probe round-tripped; the worker reported this summary.
    Alive(WorkerSummary),
}

impl Liveness {
    /// `true` for [`Busy`](Liveness::Busy) and
    /// [`Alive`](Liveness::Alive) — anything but a dead worker.
    pub fn is_alive(&self) -> bool {
        !matches!(self, Liveness::Dead)
    }

    /// The probe's summary, when one was obtained.
    pub fn summary(&self) -> Option<WorkerSummary> {
        match self {
            Liveness::Alive(s) => Some(*s),
            _ => None,
        }
    }
}

/// A leased exchange channel: exclusive use of one worker connection,
/// valid only for the worker generation it was minted under.
struct Lease {
    worker: usize,
    generation: u64,
    chan: usize,
    stream: TcpStream,
}

/// Client pool over a set of remote workers. See the module docs for the
/// requeue-on-disconnect contract; all bookkeeping (slot accounting,
/// parked channels, shipped-dataset sets, liveness, generations) lives
/// behind one mutex, and streams are moved out of their parking slots
/// while leased so an exchange never blocks another.
pub struct RemoteFleet {
    state: Mutex<FleetShared>,
    /// Signals a released slot, a worker death, or a (re)join.
    slot_free: Condvar,
    conns_per_worker: usize,
    ship_chunk_bytes: usize,
    progress_deadline: Duration,
    rejoin_grace: Duration,
    metrics: Arc<Metrics>,
    datasets: Mutex<DatasetRegistry>,
    ping_seq: AtomicU64,
    /// Registration listener state: `(local_addr, stop_flag)` once
    /// [`serve_registrations`](RemoteFleet::serve_registrations) runs.
    reg: Mutex<Option<(SocketAddr, Arc<AtomicBool>)>>,
}

impl RemoteFleet {
    /// Connect to every worker (fails fast if any is unreachable — a
    /// fleet that starts degraded is a config error, unlike one that
    /// degrades later).
    pub fn connect(addrs: &[String], cfg: FleetConfig, metrics: Arc<Metrics>) -> Result<Self> {
        ensure!(!addrs.is_empty(), "fleet needs at least one worker address");
        let conns_per_worker = cfg.conns_per_worker.max(1);
        let mut workers = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let mut chans = Vec::with_capacity(conns_per_worker);
            for _ in 0..conns_per_worker {
                let stream = TcpStream::connect(addr)
                    .with_context(|| format!("connecting to worker {addr}"))?;
                stream.set_nodelay(true).ok();
                chans.push(Some(stream));
            }
            workers.push(WorkerState::fresh(addr.clone(), chans));
        }
        metrics.set("fleet_workers_alive", addrs.len() as f64);
        metrics.set("fleet_in_flight", 0.0);
        Ok(RemoteFleet {
            state: Mutex::new(FleetShared { workers }),
            slot_free: Condvar::new(),
            conns_per_worker,
            ship_chunk_bytes: cfg.ship_chunk_bytes.max(1),
            progress_deadline: cfg.progress_deadline,
            rejoin_grace: cfg.rejoin_grace,
            metrics,
            datasets: Mutex::new(DatasetRegistry::new(FLEET_FINGERPRINT_CAPACITY)),
            ping_seq: AtomicU64::new(0),
            reg: Mutex::new(None),
        })
    }

    /// Concurrent-shard capacity across the *surviving* workers.
    pub fn capacity(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.workers.iter().filter(|w| w.alive).count() * self.conns_per_worker
    }

    /// Shards currently leased to workers (returns to 0 when idle — the
    /// cancel-path tests pin this).
    pub fn in_flight(&self) -> usize {
        total_busy(&self.state.lock().unwrap())
    }

    pub fn workers_alive(&self) -> usize {
        self.state.lock().unwrap().workers.iter().filter(|w| w.alive).count()
    }

    /// Known worker addresses, including dead and rejoined ones (cloned
    /// out: the roster can grow at runtime through registration).
    pub fn addrs(&self) -> Vec<String> {
        self.state.lock().unwrap().workers.iter().map(|w| w.addr.clone()).collect()
    }

    fn worker_addr(&self, wi: usize) -> String {
        self.state.lock().unwrap().workers[wi].addr.clone()
    }

    /// Run `f` against the lease's worker state — but only if the worker
    /// is still the same incarnation the lease was minted under.
    fn with_worker<R>(&self, lease: &Lease, f: impl FnOnce(&mut WorkerState) -> R) -> Option<R> {
        let mut st = self.state.lock().unwrap();
        let w = &mut st.workers[lease.worker];
        (w.generation == lease.generation).then(|| f(w))
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Start the worker-initiated registration listener: restarted or
    /// brand-new `sgl worker --register` processes dial this address,
    /// send [`Message::Register`] with their own serving address, and
    /// are admitted into the roster (see [`admit`](RemoteFleet::admit)).
    /// Returns the bound address. The listener thread holds only a
    /// [`Weak`] reference and exits when the fleet drops.
    pub fn serve_registrations(self: &Arc<Self>, addr: &str) -> Result<SocketAddr> {
        let mut reg = self.reg.lock().unwrap();
        ensure!(reg.is_none(), "registration listener is already running");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding registration {addr}"))?;
        let local = listener.local_addr().context("registration local addr")?;
        let stop = Arc::new(AtomicBool::new(false));
        *reg = Some((local, stop.clone()));
        drop(reg);
        let fleet = Arc::downgrade(self);
        thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                let Some(fleet) = fleet.upgrade() else { return };
                let Ok(mut stream) = conn else { continue };
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
                // A malformed or failed registration is dropped silently:
                // the worker's register loop retries until acknowledged.
                let Ok(Message::Register { addr }) = Message::read_from(&mut stream) else {
                    continue;
                };
                let Ok(worker) = fleet.admit(&addr) else { continue };
                let _ = Message::Registered { worker: worker as u64 }.write_to(&mut stream);
            }
        });
        Ok(local)
    }

    /// Admit a worker address into the roster: dial its channels, then —
    /// under the state lock — either replace the existing entry for that
    /// address (a restart: bump the generation so stale leases can't
    /// corrupt accounting, clear the shipped set so datasets reship, drop
    /// the dead incarnation's channels) or append a brand-new worker.
    pub fn admit(&self, addr: &str) -> Result<usize> {
        // Dial outside the lock: a slow handshake must not stall solves.
        let mut chans = Vec::with_capacity(self.conns_per_worker);
        for _ in 0..self.conns_per_worker {
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("dialing registering worker {addr}"))?;
            stream.set_nodelay(true).ok();
            chans.push(Some(stream));
        }
        let mut st = self.state.lock().unwrap();
        let wi = match st.workers.iter().position(|w| w.addr == addr) {
            Some(wi) => {
                let w = &mut st.workers[wi];
                for c in &mut w.chans {
                    if let Some(s) = c.take() {
                        let _ = s.shutdown(Shutdown::Both);
                    }
                }
                w.generation += 1;
                w.busy = 0;
                // The restarted process has an empty (or at best stale)
                // store: forget everything so datasets reship on demand.
                w.shipped.clear();
                w.shipping.clear();
                w.alive = true;
                w.chans = chans;
                self.metrics.incr("fleet_rejoins", 1);
                wi
            }
            None => {
                st.workers.push(WorkerState::fresh(addr.to_string(), chans));
                self.metrics.incr("fleet_workers_joined", 1);
                st.workers.len() - 1
            }
        };
        self.metrics
            .set("fleet_workers_alive", st.workers.iter().filter(|w| w.alive).count() as f64);
        self.metrics.set("fleet_in_flight", total_busy(&st) as f64);
        self.slot_free.notify_all();
        Ok(wi)
    }

    /// Solve one λ-range shard on the fleet: lease a channel from the
    /// least-loaded surviving worker, ship the dataset if this worker has
    /// not seen it, exchange the shard, release the slot. On a
    /// disconnect the worker is marked dead, its parked connections are
    /// dropped, and the shard is requeued onto the survivors; the call
    /// fails only when no worker survives or the fleet answers with a
    /// typed error (bad request / remote solve panic — retrying those
    /// elsewhere would fail identically, since solves are deterministic).
    pub fn solve_shard(
        &self,
        pb: &AnyProblem,
        lambdas: &[f64],
        opts: &PathOptions,
        solver: SolverKind,
        handoff: Option<&DualHandoff>,
    ) -> Result<(PathResult, Option<DualHandoff>)> {
        let fp = self.register(pb);
        // Pin `sweep_threads = 0` (auto) to a concrete count *here*, on
        // the coordinator: the parallel-CD round shape depends on the
        // crew size, so letting each worker resolve "auto" against its
        // own core count would make results machine-dependent — a
        // requeued shard could re-solve with different arithmetic and
        // the stitched path would mix two numerically different chains.
        let mut opts = opts.clone();
        if opts.solve.sweep == SweepMode::Parallel {
            opts.solve.sweep_threads = resolve_threads(opts.solve.sweep_threads);
        }
        let req_frame = Message::SolveShard(ShardRequest {
            dataset: fp,
            datafit: wire_datafit(pb),
            lambdas: lambdas.to_vec(),
            solver,
            opts,
            handoff: handoff.cloned(),
        })
        .encode();
        loop {
            let mut lease = self.acquire()?;
            match self.exchange(&mut lease, fp, pb, &req_frame) {
                Ok(Message::ShardDone { result, handoff }) => {
                    self.release(lease);
                    self.metrics.incr("fleet_shards_solved", 1);
                    return Ok((result, handoff));
                }
                Ok(Message::Error(err)) => {
                    let addr = self.worker_addr(lease.worker);
                    self.release(lease);
                    bail!("worker {addr} rejected the shard: {err}");
                }
                // An intact frame that is out of protocol, or a
                // disconnect mid-exchange: stop trusting this worker and
                // requeue the shard onto the survivors (all shard inputs
                // live here, so nothing was lost with the worker).
                Ok(_) | Err(_) => {
                    self.metrics.incr("fleet_shards_requeued", 1);
                    self.release_dead(lease);
                }
            }
        }
    }

    /// Probe every worker with a `Ping` (bounded by `timeout` per
    /// worker). A worker whose channels are all mid-exchange counts as
    /// alive without being probed; a failed probe marks the worker dead
    /// exactly like a mid-shard disconnect. The v4 `Pong` carries a
    /// [`WorkerSummary`], so a successful probe also reports what the
    /// worker is doing.
    pub fn heartbeat(&self, timeout: Duration) -> Vec<(String, Liveness)> {
        let n = self.state.lock().unwrap().workers.len();
        (0..n).map(|wi| (self.worker_addr(wi), self.probe(wi, timeout))).collect()
    }

    /// Scrape every surviving worker's metrics registry
    /// ([`Message::StatsRequest`] → [`Message::StatsReply`]) and fold
    /// each snapshot into this fleet's own registry under a
    /// `worker_<i>_` prefix (absolute-value overwrite via
    /// [`Metrics::merge_snapshot`], so periodic re-scrapes never
    /// double-count). Workers whose channels are all mid-exchange are
    /// skipped this round; a transport failure marks the worker dead
    /// exactly like a failed probe. Returns how many workers answered.
    pub fn scrape(&self, timeout: Duration) -> usize {
        let mut answered = 0;
        let n = self.state.lock().unwrap().workers.len();
        for wi in 0..n {
            let Some(mut lease) = self.try_lease_worker(wi) else { continue };
            lease.stream.set_read_timeout(Some(timeout)).ok();
            let reply = match Message::StatsRequest.write_to(&mut lease.stream) {
                Ok(()) => Message::read_from(&mut lease.stream),
                Err(e) => Err(WireError::Io(e.to_string())),
            };
            lease.stream.set_read_timeout(None).ok();
            match reply {
                Ok(Message::StatsReply(snap)) => {
                    self.metrics.merge_snapshot(&format!("worker_{wi}_"), &snap);
                    self.metrics.incr("fleet_scrapes", 1);
                    answered += 1;
                    self.release(lease);
                }
                // An intact but out-of-protocol reply or a transport
                // failure: stop trusting the worker, same as a probe.
                Ok(_) | Err(_) => self.release_dead(lease),
            }
        }
        answered
    }

    /// Pre-ship a dataset to every surviving worker whose channels are
    /// idle, returning how many workers were newly shipped. Useful
    /// before a latency-sensitive batch (the bench warms the fleet this
    /// way so neither timed schedule pays the one-time transfer);
    /// workers that are busy or already hold the dataset are skipped —
    /// `solve_shard`'s ship-on-first-use covers them.
    pub fn warm(&self, pb: &AnyProblem) -> Result<usize> {
        let fp = self.register(pb);
        let mut newly = 0;
        let n = self.state.lock().unwrap().workers.len();
        for wi in 0..n {
            let Some(mut lease) = self.try_lease_worker(wi) else { continue };
            let need = self
                .with_worker(&lease, |w| !w.shipped.contains(&fp) && w.shipping.insert(fp))
                .unwrap_or(false);
            if !need {
                self.release(lease);
                continue;
            }
            match self.ship(&mut lease, fp, pb) {
                Ok(None) => {
                    newly += 1;
                    self.release(lease);
                }
                Ok(Some(err)) => {
                    let addr = self.worker_addr(wi);
                    self.release(lease);
                    bail!("worker {addr} rejected the dataset: {err}");
                }
                Err(_) => self.release_dead(lease),
            }
        }
        Ok(newly)
    }

    /// Fingerprint a problem instance, served from the bounded LRU cache
    /// when the instance was seen before (a miss costs one dataset
    /// encode).
    fn register(&self, pb: &AnyProblem) -> u64 {
        let key = pb.identity();
        if let Some(e) = self.datasets.lock().unwrap().get(&key) {
            return e.fp;
        }
        // Fingerprinting encodes the dataset once; done off-lock so a
        // huge registration doesn't stall concurrent exchanges.
        let fp = wire_dataset(pb).fingerprint();
        let evicted = self
            .datasets
            .lock()
            .unwrap()
            .insert(key, FingerprintEntry { fp, _pb: pb.clone() });
        if evicted > 0 {
            self.metrics.incr("fleet_fingerprint_evictions", evicted as u64);
        }
        fp
    }

    fn acquire(&self) -> Result<Lease> {
        let mut st = self.state.lock().unwrap();
        // Arms only while zero workers survive; any survivor disarms it.
        let mut grace_deadline: Option<Instant> = None;
        loop {
            // Least-loaded surviving worker with a free channel.
            let mut best: Option<(usize, usize)> = None;
            for (wi, w) in st.workers.iter().enumerate() {
                if w.alive
                    && w.busy < self.conns_per_worker
                    && best.is_none_or(|(_, b)| w.busy < b)
                {
                    best = Some((wi, w.busy));
                }
            }
            if let Some((wi, _)) = best {
                let w = &mut st.workers[wi];
                if let Some(ci) = w.chans.iter().position(|c| c.is_some()) {
                    let stream = w.chans[ci].take().expect("slot checked");
                    let generation = w.generation;
                    w.busy += 1;
                    self.metrics.set("fleet_in_flight", total_busy(&st) as f64);
                    return Ok(Lease { worker: wi, generation, chan: ci, stream });
                }
            }
            if !st.workers.iter().any(|w| w.alive) {
                // With a grace window and a registration listener, a
                // restarted worker may rejoin before the deadline — the
                // admit notifies `slot_free` and the loop retries.
                if self.rejoin_grace.is_zero() {
                    bail!("remote fleet has no surviving workers");
                }
                let deadline =
                    *grace_deadline.get_or_insert_with(|| Instant::now() + self.rejoin_grace);
                let now = Instant::now();
                if now >= deadline {
                    bail!(
                        "remote fleet has no surviving workers (none rejoined within {:?})",
                        self.rejoin_grace
                    );
                }
                st = self.slot_free.wait_timeout(st, deadline - now).unwrap().0;
                continue;
            }
            grace_deadline = None;
            st = self.slot_free.wait(st).unwrap();
        }
    }

    /// Park the channel again after a successful exchange. A stale lease
    /// (its worker rejoined since it was minted) is dropped without
    /// touching the new incarnation's accounting.
    fn release(&self, lease: Lease) {
        let mut st = self.state.lock().unwrap();
        let w = &mut st.workers[lease.worker];
        if w.generation != lease.generation {
            let _ = lease.stream.shutdown(Shutdown::Both);
            return;
        }
        w.busy -= 1;
        w.chans[lease.chan] = Some(lease.stream);
        self.metrics.set("fleet_in_flight", total_busy(&st) as f64);
        self.slot_free.notify_all();
    }

    /// The exchange failed at the transport level: mark the worker dead
    /// and drop every connection to it (other in-flight exchanges on the
    /// same worker will fail on their own sockets and land here too). A
    /// stale lease's death belongs to the previous incarnation and must
    /// not mark the rejoined worker dead.
    fn release_dead(&self, lease: Lease) {
        let _ = lease.stream.shutdown(Shutdown::Both);
        let mut st = self.state.lock().unwrap();
        let w = &mut st.workers[lease.worker];
        if w.generation != lease.generation {
            return;
        }
        w.busy -= 1;
        if w.alive {
            w.alive = false;
            self.metrics.incr("fleet_worker_disconnects", 1);
        }
        for c in &mut w.chans {
            if let Some(s) = c.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        self.metrics
            .set("fleet_workers_alive", st.workers.iter().filter(|w| w.alive).count() as f64);
        self.metrics.set("fleet_in_flight", total_busy(&st) as f64);
        self.slot_free.notify_all();
    }

    /// Read one *reply* frame on a lease, treating interleaved
    /// [`Message::Progress`] pings as keep-alives: each ping re-arms the
    /// `progress_deadline` read timeout (when configured), so a worker
    /// mid-solve can take arbitrarily long as long as it keeps pinging,
    /// while a silently dead one times out and is written off.
    fn read_reply(&self, lease: &mut Lease) -> Result<Message, WireError> {
        let bounded = !self.progress_deadline.is_zero();
        if bounded {
            lease.stream.set_read_timeout(Some(self.progress_deadline)).ok();
        }
        let reply = loop {
            match Message::read_from(&mut lease.stream) {
                Ok(Message::Progress { .. }) => {
                    self.metrics.incr("fleet_progress_pings", 1);
                }
                other => break other,
            }
        };
        if bounded {
            lease.stream.set_read_timeout(None).ok();
        }
        reply
    }

    /// One shard exchange on a leased channel (ship-on-first-use, one
    /// transparent reship if the worker lost its store). `Err` means the
    /// transport failed and the worker should be written off.
    fn exchange(
        &self,
        lease: &mut Lease,
        fp: u64,
        pb: &AnyProblem,
        req_frame: &[u8],
    ) -> Result<Message, WireError> {
        let io = |e: std::io::Error| WireError::Io(e.to_string());
        // Elect one concurrent lease as the shipper via `shipping` —
        // without pre-committing `shipped`, which is only written on the
        // worker's ack (see `ship`). A racing sibling lease proceeds
        // straight to its solve; if it outruns the in-flight ship it
        // gets UnknownDataset and reships below — so with
        // `conns_per_worker > 1` up to conns−1 redundant transfers are
        // possible in that race window (bounded churn, not a
        // correctness issue; the common 1-conn fleet never reships).
        let need_ship = self
            .with_worker(lease, |w| !w.shipped.contains(&fp) && w.shipping.insert(fp))
            .unwrap_or(false);
        if need_ship {
            if let Some(err) = self.ship(lease, fp, pb)? {
                return Ok(Message::Error(err));
            }
        }
        lease.stream.write_all(req_frame).map_err(io)?;
        let reply = self.read_reply(lease)?;
        if let Message::Error(e) = &reply {
            if e.kind == RemoteErrorKind::UnknownDataset {
                // The worker lost its store (restarted behind the same
                // address, or the LRU evicted this fingerprint), or our
                // ship is still in flight on a sibling channel: reship
                // here and retry the same shard.
                self.metrics.incr("fleet_reships", 1);
                self.with_worker(lease, |w| {
                    w.shipped.remove(&fp);
                    w.shipping.insert(fp);
                });
                if let Some(err) = self.ship(lease, fp, pb)? {
                    return Ok(Message::Error(err));
                }
                lease.stream.write_all(req_frame).map_err(io)?;
                return self.read_reply(lease);
            }
        }
        Ok(reply)
    }

    /// Ship a dataset on a leased channel — monolithic
    /// [`Message::ShipDataset`] when it fits the `ship_chunk_bytes`
    /// budget, otherwise the chunked `ShipBegin · ShipChunk* · ShipEnd`
    /// sequence (one ack either way). The worker's `shipped` entry is
    /// committed only on its `DatasetKnown` ack. `Ok(Some(err))` is a
    /// typed worker-side rejection (do not retry elsewhere); `Err` is
    /// transport failure.
    fn ship(
        &self,
        lease: &mut Lease,
        fp: u64,
        pb: &AnyProblem,
    ) -> Result<Option<RemoteError>, WireError> {
        let io = |e: std::io::Error| WireError::Io(e.to_string());
        // Built per actual ship (rare) and dropped right after: the
        // fleet never retains an encoded frame.
        let ds = wire_dataset(pb);
        if ds.wire_len() > self.ship_chunk_bytes {
            // Chunked path: no per-chunk acks (both sides streaming
            // writes at once would deadlock on full TCP buffers), one
            // DatasetKnown/Error after ShipEnd.
            let (begin, parts) = ds.to_chunks(self.ship_chunk_bytes);
            let n_parts = parts.len() as u64;
            lease.stream.write_all(&Message::ShipBegin(begin).encode()).map_err(io)?;
            for part in parts {
                lease.stream.write_all(&Message::ShipChunk(part).encode()).map_err(io)?;
            }
            lease.stream.write_all(&Message::ShipEnd { fingerprint: fp }.encode()).map_err(io)?;
            self.metrics.incr("fleet_dataset_chunks_shipped", n_parts);
        } else {
            // An unframeable dataset is a typed rejection — panicking
            // here would leak the held lease's busy slot (nothing
            // unwinds the fleet accounting).
            let frame = match Message::ShipDataset(ds).try_encode() {
                Ok(f) => f,
                Err(e) => {
                    self.with_worker(lease, |w| w.shipping.remove(&fp));
                    return Ok(Some(RemoteError {
                        kind: RemoteErrorKind::BadRequest,
                        detail: format!("dataset cannot be framed: {e}"),
                    }));
                }
            };
            lease.stream.write_all(&frame).map_err(io)?;
        }
        match self.read_reply(lease)? {
            Message::DatasetKnown { .. } => {
                // Commit on ack — the only writer of `shipped`.
                self.with_worker(lease, |w| {
                    w.shipping.remove(&fp);
                    w.shipped.insert(fp);
                });
                self.metrics.incr("fleet_datasets_shipped", 1);
                Ok(None)
            }
            Message::Error(e) => {
                // Typed rejection: clear the election so the error is
                // reproducible rather than masked on the next call.
                self.with_worker(lease, |w| {
                    w.shipping.remove(&fp);
                    w.shipped.remove(&fp);
                });
                Ok(Some(e))
            }
            _ => Err(WireError::Malformed("unexpected reply to a dataset ship")),
        }
    }

    /// Non-blocking lease of a parked channel on one specific worker
    /// (`None`: dead, or every channel is mid-exchange).
    fn try_lease_worker(&self, wi: usize) -> Option<Lease> {
        let mut st = self.state.lock().unwrap();
        let w = &mut st.workers[wi];
        if !w.alive {
            return None;
        }
        let ci = w.chans.iter().position(|c| c.is_some())?;
        let stream = w.chans[ci].take().expect("slot checked");
        let generation = w.generation;
        w.busy += 1;
        self.metrics.set("fleet_in_flight", total_busy(&st) as f64);
        Some(Lease { worker: wi, generation, chan: ci, stream })
    }

    fn probe(&self, wi: usize, timeout: Duration) -> Liveness {
        if !self.state.lock().unwrap().workers[wi].alive {
            return Liveness::Dead;
        }
        // Every channel mid-exchange: busy implies reachable.
        let Some(mut lease) = self.try_lease_worker(wi) else { return Liveness::Busy };
        let seq = self.ping_seq.fetch_add(1, Ordering::Relaxed);
        lease.stream.set_read_timeout(Some(timeout)).ok();
        let pong = match Message::Ping { seq }.write_to(&mut lease.stream) {
            Ok(()) => Message::read_from(&mut lease.stream),
            Err(e) => Err(WireError::Io(e.to_string())),
        };
        lease.stream.set_read_timeout(None).ok();
        self.metrics.incr("fleet_heartbeats", 1);
        match pong {
            Ok(Message::Pong { seq: got, summary }) if got == seq => {
                self.release(lease);
                Liveness::Alive(summary)
            }
            _ => {
                self.release_dead(lease);
                Liveness::Dead
            }
        }
    }
}

impl Drop for RemoteFleet {
    fn drop(&mut self) {
        // Stop the registration listener: set the flag, then poke the
        // accept loop with a throwaway connection so it observes it
        // (its `Weak` upgrade would also fail, but only on the *next*
        // connection — this unblocks it now).
        if let Some((addr, stop)) = self.reg.lock().unwrap().take() {
            stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(addr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate, SyntheticConfig};
    use crate::solver::cd::SolveOptions;
    use crate::solver::path::solve_path_with_handoff;
    use crate::solver::problem::{lambda_grid, SglProblem};

    fn small_problem(seed: u64) -> Arc<SglProblem> {
        let cfg = SyntheticConfig {
            n: 24,
            n_groups: 6,
            group_size: 3,
            gamma1: 3,
            gamma2: 2,
            seed,
            ..Default::default()
        };
        let d = generate(&cfg);
        Arc::new(SglProblem::new(d.dataset.x, d.dataset.y, d.dataset.groups, 0.3))
    }

    fn one_worker_fleet() -> (WorkerServer, RemoteFleet) {
        let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
        let addrs = vec![server.local_addr().to_string()];
        let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), Arc::new(Metrics::new()))
            .expect("connect");
        (server, fleet)
    }

    #[test]
    fn remote_shard_matches_local_and_ships_dataset_once() {
        let (_server, fleet) = one_worker_fleet();
        let pb = small_problem(1);
        let lambdas = lambda_grid(pb.lambda_max(), 1.5, 5);
        let opts = PathOptions {
            delta: 1.5,
            t_count: 5,
            solve: SolveOptions { tol: 1e-8, record_history: false, ..Default::default() },
        };
        let any = AnyProblem::Dense(pb.clone());
        // Head shard remotely, tail shard remotely from the wire handoff.
        let (head, rh) = fleet
            .solve_shard(&any, &lambdas[..3], &opts, SolverKind::Cd, None)
            .expect("remote head shard");
        let rh = rh.expect("non-empty shard yields a handoff");
        let (tail, _) = fleet
            .solve_shard(&any, &lambdas[3..], &opts, SolverKind::Cd, Some(&rh))
            .expect("remote tail shard");
        // Bit-identical to the uninterrupted local path: the handoff made
        // two TCP round trips in between.
        let (local, lh) = solve_path_with_handoff(&pb, &lambdas, &opts, SolverKind::Cd, None);
        for (t, (a, b)) in
            head.results.iter().chain(tail.results.iter()).zip(&local.results).enumerate()
        {
            assert_eq!(a.beta, b.beta, "t={t}: remote must be bit-identical to local");
            assert_eq!(a.epochs, b.epochs, "t={t}");
        }
        let lh = lh.expect("handoff");
        assert_eq!(rh.lambda, lambdas[2]);
        assert!(lh.lambda < rh.lambda);
        assert_eq!(fleet.metrics().counter("fleet_datasets_shipped"), 1);
        assert_eq!(fleet.metrics().counter("fleet_shards_solved"), 2);
        assert_eq!(fleet.in_flight(), 0);
    }

    #[test]
    fn remote_solve_panic_is_a_typed_failure_not_a_dead_worker() {
        let (_server, fleet) = one_worker_fleet();
        let pb = small_problem(2);
        let any = AnyProblem::Dense(pb.clone());
        let opts = PathOptions::default();
        // An increasing grid trips the path engine's assertion remotely.
        let err = fleet
            .solve_shard(&any, &[1.0, 2.0], &opts, SolverKind::Cd, None)
            .expect_err("increasing grid must fail");
        assert!(format!("{err:#}").contains("non-increasing"), "{err:#}");
        // The worker survived and still serves.
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
        assert!(fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).is_ok());
        assert_eq!(fleet.workers_alive(), 1);
    }

    #[test]
    fn raw_protocol_unknown_dataset_and_undecodable_frames() {
        let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
        // Unknown fingerprint → typed UnknownDataset error frame.
        let mut s = TcpStream::connect(server.local_addr()).expect("connect");
        Message::SolveShard(ShardRequest {
            dataset: 0xdead_beef,
            datafit: WireDatafit::Quadratic { ridge: 0.0 },
            lambdas: vec![1.0],
            solver: SolverKind::Cd,
            opts: PathOptions::default(),
            handoff: None,
        })
        .write_to(&mut s)
        .expect("write");
        let reply = Message::read_from(&mut s).expect("reply");
        let Message::Error(e) = reply else { panic!("expected error frame, got {reply:?}") };
        assert_eq!(e.kind, RemoteErrorKind::UnknownDataset);
        // A bad version byte → BadRequest error frame, then close.
        let mut s2 = TcpStream::connect(server.local_addr()).expect("connect");
        let mut frame = Message::Ping { seq: 1 }.encode();
        frame[4] = 99; // version byte
        s2.write_all(&frame).expect("write");
        let reply = Message::read_from(&mut s2).expect("reply");
        let Message::Error(e) = reply else { panic!("expected error frame") };
        assert_eq!(e.kind, RemoteErrorKind::BadRequest);
        assert!(e.detail.contains("version"), "{}", e.detail);
    }

    #[test]
    fn datafit_mismatch_is_a_typed_bad_request() {
        let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
        let pb = small_problem(9);
        let any = AnyProblem::Dense(pb.clone());
        let ds = wire_dataset(&any);
        let fp = ds.fingerprint();
        let mut s = TcpStream::connect(server.local_addr()).expect("connect");
        Message::ShipDataset(ds).write_to(&mut s).expect("ship");
        let ack = Message::read_from(&mut s).expect("ack");
        assert!(matches!(ack, Message::DatasetKnown { known: true, .. }), "{ack:?}");
        // The stored dataset is quadratic; a logistic-tagged request for
        // the same fingerprint must be rejected, not silently solved.
        Message::SolveShard(ShardRequest {
            dataset: fp,
            datafit: WireDatafit::Logistic,
            lambdas: vec![pb.lambda_max() * 0.5],
            solver: SolverKind::Cd,
            opts: PathOptions::default(),
            handoff: None,
        })
        .write_to(&mut s)
        .expect("write");
        let reply = Message::read_from(&mut s).expect("reply");
        let Message::Error(e) = reply else { panic!("expected error frame, got {reply:?}") };
        assert_eq!(e.kind, RemoteErrorKind::BadRequest);
        assert!(e.detail.contains("datafit mismatch"), "{}", e.detail);
    }

    #[test]
    fn warm_preships_to_every_worker_exactly_once() {
        let (_server, fleet) = one_worker_fleet();
        let pb = small_problem(3);
        let any = AnyProblem::Dense(pb.clone());
        assert_eq!(fleet.warm(&any).expect("warm"), 1);
        assert_eq!(fleet.warm(&any).expect("already warm"), 0);
        assert_eq!(fleet.metrics().counter("fleet_datasets_shipped"), 1);
        // The subsequent solve skips the ship entirely.
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 3,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        assert_eq!(fleet.metrics().counter("fleet_datasets_shipped"), 1);
        assert_eq!(fleet.in_flight(), 0);
    }

    #[test]
    fn heartbeat_tracks_liveness_and_carries_worker_summaries() {
        let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
        let addrs = vec![server.local_addr().to_string()];
        let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), Arc::new(Metrics::new()))
            .expect("connect");
        let up = fleet.heartbeat(Duration::from_secs(5));
        assert!(up.iter().all(|(_, l)| l.is_alive()), "{up:?}");
        let s = up[0].1.summary().expect("an idle probe carries a summary");
        assert_eq!(s.in_flight, 0);
        assert_eq!(s.solves, 0);
        // A solve shows up in the next heartbeat's summary.
        let pb = small_problem(11);
        let any = AnyProblem::Dense(pb.clone());
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 3,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        let up = fleet.heartbeat(Duration::from_secs(5));
        let s = up[0].1.summary().expect("summary");
        assert_eq!(s.solves, 1);
        assert_eq!(s.in_flight, 0);
        server.kill();
        let down = fleet.heartbeat(Duration::from_secs(5));
        assert!(down.iter().all(|(_, l)| !l.is_alive()), "{down:?}");
        assert_eq!(fleet.workers_alive(), 0);
        assert_eq!(fleet.capacity(), 0);
    }

    #[test]
    fn scrape_merges_worker_registries_under_prefixes() {
        let (server, fleet) = one_worker_fleet();
        let pb = small_problem(12);
        let any = AnyProblem::Dense(pb.clone());
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 4);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 4,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        assert_eq!(fleet.scrape(Duration::from_secs(5)), 1);
        let m = fleet.metrics();
        assert_eq!(m.counter("worker_0_worker_shards_solved"), 1);
        assert_eq!(m.counter("worker_0_worker_datasets_stored"), 1);
        let t = m.timer("worker_0_worker_shard_solve_s").expect("scraped timer");
        assert_eq!(t.count, 1);
        let p95 = m.timer_quantile("worker_0_worker_shard_solve_s", 0.95).expect("p95");
        assert!(p95 > 0.0, "histogram rode along with the scrape: {p95}");
        // Worker-side truth matches what was merged.
        assert_eq!(server.shared.summary().solves, 1);
        // Re-scraping overwrites the same keys — totals stay absolute.
        assert_eq!(fleet.scrape(Duration::from_secs(5)), 1);
        assert_eq!(m.counter("worker_0_worker_shards_solved"), 1);
    }

    #[test]
    fn evicted_dataset_is_reshipped_transparently() {
        // A 1-dataset store: the second problem evicts the first, so
        // re-solving the first trips UnknownDataset → transparent reship.
        let server = WorkerServer::bind_with(
            "127.0.0.1:0",
            WorkerOptions { dataset_capacity: 1, ..Default::default() },
        )
        .expect("bind");
        let addrs = vec![server.local_addr().to_string()];
        let fleet = RemoteFleet::connect(&addrs, FleetConfig::default(), Arc::new(Metrics::new()))
            .expect("connect");
        let pb1 = small_problem(21);
        let pb2 = small_problem(22);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 3,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        for pb in [&pb1, &pb2, &pb1] {
            let any = AnyProblem::Dense((*pb).clone());
            let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
            fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        }
        let m = fleet.metrics();
        assert_eq!(m.counter("fleet_datasets_shipped"), 3, "ship, ship, reship");
        assert_eq!(m.counter("fleet_reships"), 1);
        assert_eq!(m.counter("fleet_shards_solved"), 3);
        fleet.scrape(Duration::from_secs(5));
        assert_eq!(m.counter("worker_0_worker_dataset_evictions"), 2);
        assert_eq!(fleet.workers_alive(), 1, "eviction is not a failure");
    }

    #[test]
    fn restarted_worker_rejoins_through_registration() {
        let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
        let addrs = vec![server.local_addr().to_string()];
        let fleet = Arc::new(
            RemoteFleet::connect(&addrs, FleetConfig::default(), Arc::new(Metrics::new()))
                .expect("connect"),
        );
        let reg = fleet.serve_registrations("127.0.0.1:0").expect("registration listener");
        server.kill();
        drop(server);
        let down = fleet.heartbeat(Duration::from_secs(5));
        assert!(down.iter().all(|(_, l)| !l.is_alive()), "{down:?}");
        assert_eq!(fleet.workers_alive(), 0);
        // A replacement worker (fresh address) announces itself and joins.
        let server2 = WorkerServer::bind("127.0.0.1:0").expect("bind replacement");
        server2.register(&reg.to_string());
        let deadline = Instant::now() + Duration::from_secs(10);
        while fleet.workers_alive() == 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(fleet.workers_alive(), 1, "replacement joined the roster");
        assert_eq!(fleet.metrics().counter("fleet_workers_joined"), 1);
        // The fleet solves on the replacement; its store is empty, so the
        // dataset ships fresh.
        let pb = small_problem(23);
        let any = AnyProblem::Dense(pb.clone());
        let lambdas = lambda_grid(pb.lambda_max(), 1.0, 3);
        let opts = PathOptions {
            delta: 1.0,
            t_count: 3,
            solve: SolveOptions { tol: 1e-6, record_history: false, ..Default::default() },
        };
        fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        assert_eq!(fleet.metrics().counter("fleet_datasets_shipped"), 1);
        // Re-registering the SAME address counts as a rejoin: generation
        // bumps and the shipped set clears, so the next solve reships.
        server2.register(&reg.to_string());
        let deadline = Instant::now() + Duration::from_secs(10);
        while fleet.metrics().counter("fleet_rejoins") == 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(fleet.metrics().counter("fleet_rejoins"), 1);
        fleet.solve_shard(&any, &lambdas, &opts, SolverKind::Cd, None).expect("solve");
        assert_eq!(
            fleet.metrics().counter("fleet_datasets_shipped"),
            2,
            "rejoin cleared the shipped set"
        );
        assert_eq!(fleet.in_flight(), 0);
    }
}
