//! L3 coordination: job scheduling across worker threads, metrics, and
//! figure-series reporting.

pub mod jobs;
pub mod metrics;
pub mod report;
