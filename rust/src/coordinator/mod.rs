//! L3/L4/L5 coordination: batched job scheduling across worker threads
//! ([`jobs`]), the async solve service with its queue, result store and
//! fingerprint cache ([`service`]), λ-range sharding with dual-point
//! handoff plus the cross-path fleet scheduler ([`shard`]), distributed
//! serving over TCP workers ([`remote`]), metrics ([`metrics`]), and
//! figure-series reporting ([`report`]).

pub mod jobs;
pub mod metrics;
pub mod remote;
pub mod report;
pub mod service;
pub mod shard;
