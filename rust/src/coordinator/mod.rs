//! L3/L4 coordination: batched job scheduling across worker threads
//! ([`jobs`]), the async solve service with its queue, result store and
//! fingerprint cache ([`service`]), λ-range sharding with dual-point
//! handoff ([`shard`]), metrics ([`metrics`]), and figure-series
//! reporting ([`report`]).

pub mod jobs;
pub mod metrics;
pub mod report;
pub mod service;
pub mod shard;
