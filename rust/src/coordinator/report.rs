//! Figure-series reporting: CSV emitters and terminal-friendly markdown /
//! ASCII renderings of the paper's figures.

use super::jobs::RuleTiming;
use crate::data::csvio::write_csv;
use anyhow::Result;
use std::path::Path;

/// Write the Fig. 2c / 3b series: `rule, tol, seconds, epochs`.
pub fn write_rule_timings(path: &Path, timings: &[RuleTiming]) -> Result<()> {
    let rows: Vec<Vec<f64>> = timings
        .iter()
        .map(|t| {
            vec![
                rule_index(t) as f64,
                t.tol,
                t.seconds,
                t.total_epochs as f64,
                if t.converged { 1.0 } else { 0.0 },
            ]
        })
        .collect();
    write_csv(path, &["rule_id", "tol", "seconds", "epochs", "converged"], &rows)
}

fn rule_index(t: &RuleTiming) -> usize {
    crate::screening::RuleKind::all().iter().position(|&r| r == t.rule).unwrap_or(99)
}

/// Markdown table of rule timings grouped by tolerance, with the speed-up
/// of GAP safe over each baseline (the paper's headline numbers).
pub fn render_rule_timings(timings: &[RuleTiming]) -> String {
    use crate::screening::RuleKind;
    let mut out = String::new();
    let mut tols: Vec<f64> = timings.iter().map(|t| t.tol).collect();
    tols.sort_by(|a, b| b.partial_cmp(a).unwrap());
    tols.dedup();
    out.push_str("| tol | ");
    for r in RuleKind::all() {
        out.push_str(&format!("{} (s) | ", r.name()));
    }
    out.push_str("speedup vs none |\n|---|");
    for _ in RuleKind::all() {
        out.push_str("---|");
    }
    out.push_str("---|\n");
    for &tol in &tols {
        out.push_str(&format!("| {tol:.0e} | "));
        let mut none_s = None;
        let mut gap_s = None;
        for r in RuleKind::all() {
            if let Some(t) = timings.iter().find(|t| t.tol == tol && t.rule == r) {
                out.push_str(&format!("{:.3} | ", t.seconds));
                if r == RuleKind::None {
                    none_s = Some(t.seconds);
                }
                if r == RuleKind::GapSafe {
                    gap_s = Some(t.seconds);
                }
            } else {
                out.push_str("- | ");
            }
        }
        match (none_s, gap_s) {
            (Some(n), Some(g)) if g > 0.0 => out.push_str(&format!("{:.2}x |\n", n / g)),
            _ => out.push_str("- |\n"),
        }
    }
    out
}

/// ASCII heat map for Fig. 4: per-location values rendered on the grid.
/// `values` is indexed by location (lat-major like `data::climate`), and
/// `target` marks the prediction cell.
pub fn render_support_map(
    values: &[f64],
    grid_lon: usize,
    grid_lat: usize,
    target: usize,
) -> String {
    let vmax = values.iter().cloned().fold(0.0_f64, f64::max).max(1e-300);
    let shades = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut out = String::new();
    for lat in 0..grid_lat {
        for lon in 0..grid_lon {
            let loc = lat * grid_lon + lon;
            if loc == target {
                out.push('X');
                continue;
            }
            let v = values[loc] / vmax;
            let idx = ((v * (shades.len() - 1) as f64).round() as usize).min(shades.len() - 1);
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

/// CSV for Fig. 4: `lon, lat, value, is_target`.
pub fn write_support_map(
    path: &Path,
    values: &[f64],
    grid_lon: usize,
    grid_lat: usize,
    target: usize,
) -> Result<()> {
    let mut rows = Vec::with_capacity(values.len());
    for lat in 0..grid_lat {
        for lon in 0..grid_lon {
            let loc = lat * grid_lon + lon;
            rows.push(vec![
                lon as f64,
                lat as f64,
                values[loc],
                if loc == target { 1.0 } else { 0.0 },
            ]);
        }
    }
    write_csv(path, &["lon", "lat", "value", "is_target"], &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::RuleKind;

    fn timing(rule: RuleKind, tol: f64, s: f64) -> RuleTiming {
        RuleTiming { rule, tol, seconds: s, total_epochs: 100, converged: true }
    }

    #[test]
    fn markdown_table_has_speedup() {
        let timings = vec![
            timing(RuleKind::None, 1e-8, 2.0),
            timing(RuleKind::GapSafe, 1e-8, 0.5),
        ];
        let md = render_rule_timings(&timings);
        assert!(md.contains("4.00x"), "{md}");
        assert!(md.contains("1e-8"));
    }

    #[test]
    fn support_map_marks_target_and_peaks() {
        let mut values = vec![0.0; 12];
        values[5] = 1.0;
        let map = render_support_map(&values, 4, 3, 0);
        let lines: Vec<&str> = map.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(&lines[0][0..1], "X");
        assert_eq!(&lines[1][1..2], "@"); // loc 5 = lat1,lon1
    }

    #[test]
    fn csv_writers_work() {
        let dir = std::env::temp_dir().join(format!("sgl-report-{}", std::process::id()));
        let timings = vec![timing(RuleKind::Static, 1e-4, 1.0)];
        write_rule_timings(&dir.join("t.csv"), &timings).unwrap();
        write_support_map(&dir.join("m.csv"), &[0.0, 1.0], 2, 1, 0).unwrap();
        assert!(dir.join("t.csv").exists());
        assert!(std::fs::read_to_string(dir.join("m.csv")).unwrap().contains("is_target"));
    }
}
