//! Data-parallel helpers built on `std::thread` (tokio/rayon are not
//! available offline). The coordinator uses these to fan path/CV solves and
//! rule comparisons across cores; the intra-path sweep layer
//! ([`crate::solver::sweep`]) uses the persistent [`WorkCrew`] plus the
//! [`SpinBarrier`]/[`WorkQueue`]/[`SharedSlice`] primitives to parallelize
//! *inside* a single solve.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Parse a thread-count environment value. `Some(n)` for a positive
/// integer, `None` for anything else — including `0`, which follows the
/// same 0-means-auto convention as the `threads` config key (see
/// [`resolve_threads`]), and malformed text, which falls back to auto
/// rather than silently serializing the run.
fn threads_from_env(value: Option<&str>) -> Option<usize> {
    value.and_then(|v| v.trim().parse::<usize>().ok()).filter(|&n| n > 0)
}

/// Number of worker threads to use: `SGL_THREADS` env override, else the
/// machine's available parallelism, else 1. `SGL_THREADS=0` means "auto"
/// (identical to an unset variable), matching the `threads = 0` config
/// convention of [`resolve_threads`].
pub fn default_threads() -> usize {
    if let Some(n) = threads_from_env(std::env::var("SGL_THREADS").ok().as_deref()) {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Normalize a thread-count setting: `0` means "auto" (the
/// `SGL_THREADS` / available-parallelism default of [`default_threads`]),
/// anything else is taken literally. Shared by the CLI, `PathBatch::run`
/// and the solve service so a `threads = 0` config can never produce a
/// zero-worker pool.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        default_threads()
    } else {
        threads
    }
}

/// A persistent worker pool: `n` named OS threads all running the same
/// drain loop until it returns. Unlike [`parallel_map`] (scoped, one
/// batch, joins before returning) the pool outlives any single work item —
/// the solve service keeps one alive for its whole lifetime and feeds it
/// through a shared queue.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (at least one), each running
    /// `f(worker_index)` to completion. `f` is expected to loop over a
    /// shared queue and return when its owner signals shutdown.
    pub fn spawn<F>(threads: usize, f: F) -> Self
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = std::sync::Arc::new(f);
        let handles = (0..threads.max(1))
            .map(|i| {
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("sgl-worker-{i}"))
                    .spawn(move || f(i))
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Join every worker. The owner must already have signalled its drain
    /// loops to return, or this blocks forever. A worker that died to an
    /// *uncaught* panic is reported on stderr rather than re-raised (the
    /// service catches per-job panics itself, and join_all runs from Drop
    /// where unwinding again would abort).
    pub fn join_all(&mut self) {
        for h in self.handles.drain(..) {
            let name = h.thread().name().unwrap_or("sgl-worker").to_string();
            if h.join().is_err() {
                eprintln!("warning: worker thread {name} panicked outside a job");
            }
        }
    }
}

/// One result slot: the item's value or, if the worker closure panicked on
/// it, the caught panic payload.
type Slot<T> = Option<std::thread::Result<T>>;

/// Apply `f` to every index in `0..n` on up to `threads` workers and collect
/// the results in order. Work is distributed dynamically (atomic counter),
/// so uneven item costs (e.g. small vs large lambda solves) balance well.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    // Catching worker panics (instead of letting the scoped thread die)
    // keeps the per-slot mutexes unpoisoned and lets the join path re-raise
    // the *original* panic rather than a misleading "worker panicked before
    // producing a result" unwrap failure.
    let out: Vec<Mutex<Slot<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let abort = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break; // a sibling already failed: stop taking work
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = catch_unwind(AssertUnwindSafe(|| f(i)));
                if val.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                *out[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(val);
            });
        }
    });
    let mut values: Vec<Option<T>> = Vec::with_capacity(n);
    let mut panic_payload: Option<Box<dyn std::any::Any + Send>> = None;
    for m in out {
        match m.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(Ok(v)) => values.push(Some(v)),
            Some(Err(p)) => {
                if panic_payload.is_none() {
                    panic_payload = Some(p);
                }
                values.push(None);
            }
            // Unfilled slot: only possible when a sibling panicked and the
            // pool aborted early.
            None => values.push(None),
        }
    }
    if let Some(p) = panic_payload {
        resume_unwind(p);
    }
    values
        .into_iter()
        .map(|v| v.expect("no worker panicked, so every slot is filled"))
        .collect()
}

/// Like [`parallel_map`] over an input slice.
pub fn parallel_map_slice<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    parallel_map(items.len(), threads, |i| f(&items[i]))
}

// ---------------------------------------------------------------------------
// Intra-solve parallel primitives
// ---------------------------------------------------------------------------

/// Erased pointer to the closure a [`WorkCrew`] run executes. The pointer
/// is only dereferenced while the owning `run` call is blocked waiting for
/// the helpers, which keeps the borrowed closure alive.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and `run` guarantees it outlives every dereference.
unsafe impl Send for JobPtr {}

struct CrewState {
    /// Monotone run counter; each helper executes every run exactly once.
    run_id: u64,
    job: Option<JobPtr>,
    /// Helpers still executing the current run.
    running: usize,
    /// First helper panic payload of the current run.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct CrewShared {
    state: Mutex<CrewState>,
    /// Helpers wait here for a new run (or shutdown).
    start: Condvar,
    /// The owner waits here for the current run to drain.
    done: Condvar,
    /// Set when any worker (helper or caller) panics mid-run; cooperative
    /// kernels poll it (e.g. through [`SpinBarrier::wait_or`]) so sibling
    /// workers bail out instead of deadlocking on a barrier.
    abort: AtomicBool,
}

/// A persistent crew of helper threads for *repeated* fine-grained
/// parallel regions. [`parallel_map`] spawns scoped threads per batch —
/// fine for second-long path jobs, ruinous for per-epoch solver kernels.
/// The crew spawns its helpers once and re-broadcasts a borrowed closure
/// per [`run`](WorkCrew::run): the caller participates as worker `0`,
/// helpers are workers `1..threads`, and `run` returns only when every
/// worker finished, so the closure may borrow from the caller's stack.
pub struct WorkCrew {
    shared: Arc<CrewShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkCrew {
    /// Crew with `threads` workers total (the caller plus
    /// `threads − 1` spawned helpers). `threads <= 1` spawns nothing and
    /// makes [`run`](WorkCrew::run) a plain call.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(CrewShared {
            state: Mutex::new(CrewState {
                run_id: 0,
                job: None,
                running: 0,
                panic: None,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
            abort: AtomicBool::new(false),
        });
        let handles = (1..threads.max(1))
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sgl-crew-{w}"))
                    .spawn(move || crew_worker(&shared, w))
                    .expect("spawning crew thread")
            })
            .collect();
        WorkCrew { shared, handles }
    }

    /// Total worker count (caller + helpers).
    #[inline]
    pub fn threads(&self) -> usize {
        self.handles.len() + 1
    }

    /// The cooperative abort flag of the *current* run: set as soon as any
    /// worker panics, cleared at the start of the next run. Kernels that
    /// synchronize workers mid-run must poll it (via
    /// [`SpinBarrier::wait_or`]) so a panic on one worker cannot strand
    /// its siblings.
    #[inline]
    pub fn abort_flag(&self) -> &AtomicBool {
        &self.shared.abort
    }

    /// Execute `f(worker_index)` once on every worker (`0` = the calling
    /// thread) and return when all are done. Panics on any worker are
    /// re-raised here, after every worker has stopped touching `f`'s
    /// borrows. Not reentrant: `f` must not call `run` on the same crew.
    pub fn run<F>(&self, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        if self.handles.is_empty() {
            f(0);
            return;
        }
        self.shared.abort.store(false, Ordering::SeqCst);
        // Erase the closure's lifetime; sound because this function blocks
        // until every helper finished running it.
        let short: &(dyn Fn(usize) + Sync) = f;
        let long: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(short) };
        {
            let mut s = self.shared.state.lock().unwrap();
            debug_assert_eq!(s.running, 0, "WorkCrew::run is not reentrant");
            s.job = Some(JobPtr(long as *const _));
            s.running = self.handles.len();
            s.panic = None;
            s.run_id += 1;
        }
        self.shared.start.notify_all();
        // The caller is worker 0.
        let mine = catch_unwind(AssertUnwindSafe(|| f(0)));
        if mine.is_err() {
            self.shared.abort.store(true, Ordering::SeqCst);
        }
        let helper_panic = {
            let mut s = self.shared.state.lock().unwrap();
            while s.running > 0 {
                s = self.shared.done.wait(s).unwrap();
            }
            s.job = None;
            s.panic.take()
        };
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if let Some(p) = helper_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkCrew {
    fn drop(&mut self) {
        {
            let mut s = self.shared.state.lock().unwrap();
            s.shutdown = true;
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                eprintln!("warning: crew thread panicked outside a run");
            }
        }
    }
}

fn crew_worker(shared: &CrewShared, w: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut s = shared.state.lock().unwrap();
            loop {
                if s.shutdown {
                    return;
                }
                if s.run_id > seen {
                    seen = s.run_id;
                    break s.job.expect("run_id bumped with a job installed");
                }
                s = shared.start.wait(s).unwrap();
            }
        };
        // SAFETY: the owner's `run` call blocks until `running` drains,
        // so the closure behind `job` is alive for this call.
        let f = unsafe { &*job.0 };
        let outcome = catch_unwind(AssertUnwindSafe(|| f(w)));
        if outcome.is_err() {
            shared.abort.store(true, Ordering::SeqCst);
        }
        let mut s = shared.state.lock().unwrap();
        if let Err(p) = outcome {
            if s.panic.is_none() {
                s.panic = Some(p);
            }
        }
        s.running -= 1;
        if s.running == 0 {
            shared.done.notify_all();
        }
    }
}

/// Reusable spin barrier for the bulk-synchronous rounds inside one
/// [`WorkCrew::run`]. Condvar barriers cost microseconds per crossing;
/// the parallel CD sweep crosses one every few microseconds of work, so
/// waiting spins (with a yield once the wait stretches).
pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicU64,
}

impl SpinBarrier {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "barrier needs at least one participant");
        SpinBarrier { n, count: AtomicUsize::new(0), generation: AtomicU64::new(0) }
    }

    #[inline]
    pub fn participants(&self) -> usize {
        self.n
    }

    /// Wait until all `n` participants arrive, or `abort` becomes true.
    /// Returns `false` on abort — the caller must then unwind out of the
    /// parallel region (the barrier is left unusable, which is fine:
    /// aborts only happen when a sibling worker panicked and the whole
    /// run is being torn down).
    pub fn wait_or(&self, abort: &AtomicBool) -> bool {
        let gen = self.generation.load(Ordering::SeqCst);
        if self.count.fetch_add(1, Ordering::SeqCst) + 1 == self.n {
            self.count.store(0, Ordering::SeqCst);
            self.generation.store(gen.wrapping_add(1), Ordering::SeqCst);
            return true;
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::SeqCst) == gen {
            if abort.load(Ordering::Relaxed) {
                return false;
            }
            spins += 1;
            if spins < 1 << 14 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
        true
    }
}

/// Dynamic chunked index distribution (the work-stealing half of the
/// sweep layer): workers pull disjoint `[start, end)` ranges of `0..n`
/// until the queue is dry. Chunks keep the atomic traffic amortized while
/// the dynamic hand-out balances ragged per-item costs (group sizes,
/// CSC column densities).
pub struct WorkQueue {
    next: AtomicUsize,
    n: usize,
    chunk: usize,
}

impl WorkQueue {
    pub fn new(n: usize, chunk: usize) -> Self {
        WorkQueue { next: AtomicUsize::new(0), n, chunk: chunk.max(1) }
    }

    /// The next unclaimed range, or `None` when all work is handed out.
    #[inline]
    pub fn next(&self) -> Option<(usize, usize)> {
        let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
        if start >= self.n {
            return None;
        }
        Some((start, (start + self.chunk).min(self.n)))
    }
}

/// A mutably-shared slice for parallel kernels whose workers touch
/// **disjoint** index sets (compacted feature columns, row ranges of the
/// residual). The unsafe accessors encode the contract the sweep kernels
/// uphold structurally: every index/range is owned by exactly one worker
/// per synchronization phase.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: access is coordinated by the caller per the disjointness
// contract on the unsafe methods.
unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T: Copy> SharedSlice<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        SharedSlice { ptr: data.as_mut_ptr(), len: data.len(), _borrow: PhantomData }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// No other worker may read or write index `i` concurrently.
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// No other worker may write index `i` concurrently.
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Shared view of `a..b`.
    ///
    /// # Safety
    /// No worker may write inside `a..b` while the view is live.
    #[inline]
    pub unsafe fn slice(&self, a: usize, b: usize) -> &'a [T] {
        debug_assert!(a <= b && b <= self.len);
        std::slice::from_raw_parts(self.ptr.add(a), b - a)
    }

    /// Exclusive view of `a..b`.
    ///
    /// # Safety
    /// Ranges handed to different workers must be disjoint, and no other
    /// worker may read inside `a..b` while the view is live.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, a: usize, b: usize) -> &'a mut [T] {
        debug_assert!(a <= b && b <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(a), b - a)
    }
}

/// Even contiguous split of `0..n` into `parts` ranges: part `k` gets
/// `[k·n/parts, (k+1)·n/parts)` — the static row partition of the
/// residual kernels (deterministic for a fixed thread count).
#[inline]
pub fn even_chunk(n: usize, parts: usize, k: usize) -> (usize, usize) {
    debug_assert!(k < parts.max(1));
    let parts = parts.max(1);
    (k * n / parts, (k + 1) * n / parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn slice_variant() {
        let items = vec!["a", "bb", "ccc"];
        let out = parallel_map_slice(&items, 2, |s| s.len());
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn uneven_work_balances() {
        // Just a smoke test that dynamic scheduling completes with skewed work.
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 1000) {
                acc = acc.wrapping_add(k);
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn env_thread_parsing_follows_zero_means_auto() {
        assert_eq!(threads_from_env(Some("4")), Some(4));
        assert_eq!(threads_from_env(Some(" 2 ")), Some(2));
        // 0 means auto — same convention as `threads = 0` in config.
        assert_eq!(threads_from_env(Some("0")), None);
        // Malformed values fall back to auto instead of serializing.
        assert_eq!(threads_from_env(Some("-3")), None);
        assert_eq!(threads_from_env(Some("lots")), None);
        assert_eq!(threads_from_env(Some("")), None);
        assert_eq!(threads_from_env(None), None);
    }

    #[test]
    fn crew_runs_every_worker_and_is_reusable() {
        let crew = WorkCrew::new(4);
        assert_eq!(crew.threads(), 4);
        for _ in 0..50 {
            let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
            crew.run(&|w| {
                hits[w].fetch_add(1, Ordering::SeqCst);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::SeqCst), 1);
            }
        }
    }

    #[test]
    fn crew_single_thread_is_a_plain_call() {
        let crew = WorkCrew::new(1);
        assert_eq!(crew.threads(), 1);
        let hit = AtomicUsize::new(0);
        crew.run(&|w| {
            assert_eq!(w, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn crew_propagates_helper_panics_and_survives() {
        let crew = WorkCrew::new(3);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            crew.run(&|w| {
                if w == 2 {
                    panic!("helper boom");
                }
            });
        }));
        assert!(caught.is_err());
        // The crew is still usable after a panicked run.
        let count = AtomicUsize::new(0);
        crew.run(&|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn crew_borrows_caller_stack_mutably_through_shared_slice() {
        let crew = WorkCrew::new(4);
        let n = 1000;
        let mut out = vec![0.0f64; n];
        {
            let shared = SharedSlice::new(&mut out);
            let queue = WorkQueue::new(n, 64);
            crew.run(&|_w| {
                while let Some((a, b)) = queue.next() {
                    for i in a..b {
                        // SAFETY: work-queue ranges are disjoint.
                        unsafe { shared.set(i, (i * i) as f64) };
                    }
                }
            });
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as f64);
        }
    }

    #[test]
    fn spin_barrier_synchronizes_rounds() {
        let crew = WorkCrew::new(4);
        let barrier = SpinBarrier::new(4);
        assert_eq!(barrier.participants(), 4);
        let abort = AtomicBool::new(false);
        let n_rounds = 20;
        let mut log = vec![0usize; n_rounds];
        let shared = SharedSlice::new(&mut log);
        let counter = AtomicUsize::new(0);
        crew.run(&|w| {
            for r in 0..n_rounds {
                counter.fetch_add(1, Ordering::SeqCst);
                assert!(barrier.wait_or(&abort));
                if w == 0 {
                    // All 4 increments of round r landed before the barrier.
                    // SAFETY: only worker 0 writes; phase separated by the
                    // trailing barrier.
                    unsafe { shared.set(r, counter.load(Ordering::SeqCst)) };
                }
                assert!(barrier.wait_or(&abort));
            }
        });
        for (r, &v) in log.iter().enumerate() {
            assert_eq!(v, 4 * (r + 1));
        }
    }

    #[test]
    fn spin_barrier_aborts_instead_of_hanging() {
        let barrier = SpinBarrier::new(2);
        let abort = AtomicBool::new(true);
        // Only one participant ever arrives: without the abort flag this
        // would spin forever.
        assert!(!barrier.wait_or(&abort));
    }

    #[test]
    fn work_queue_hands_out_disjoint_cover() {
        let q = WorkQueue::new(103, 10);
        let mut seen = vec![false; 103];
        while let Some((a, b)) = q.next() {
            for s in seen.iter_mut().take(b).skip(a) {
                assert!(!*s);
                *s = true;
            }
        }
        assert!(seen.into_iter().all(|s| s));
        // Empty queue yields nothing.
        assert!(WorkQueue::new(0, 8).next().is_none());
    }

    #[test]
    fn even_chunks_cover_without_overlap() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8] {
                let mut covered = 0;
                for k in 0..parts {
                    let (a, b) = even_chunk(n, parts, k);
                    assert_eq!(a, covered);
                    covered = b;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(0), default_threads());
    }

    #[test]
    fn worker_pool_runs_every_worker_and_joins() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let mut pool = WorkerPool::spawn(4, move |_i| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(pool.len(), 4);
        assert!(!pool.is_empty());
        pool.join_all();
        assert!(pool.is_empty());
        assert_eq!(count.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn worker_pool_spawns_at_least_one() {
        let mut pool = WorkerPool::spawn(0, |_| {});
        assert_eq!(pool.len(), 1);
        pool.join_all();
    }

    #[test]
    fn worker_panic_propagates_original_payload() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(16, 4, |i| {
                if i == 5 {
                    panic!("boom at item {i}");
                }
                i * 2
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at item 5"), "wrong payload: {msg:?}");
    }

    #[test]
    fn single_thread_panic_also_propagates() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(3, 1, |i| {
                if i == 1 {
                    panic!("serial boom");
                }
                i
            })
        }));
        assert!(caught.is_err());
    }
}
