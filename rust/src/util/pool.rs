//! Scoped data-parallel helpers built on `std::thread` (tokio/rayon are not
//! available offline). The coordinator uses these to fan path/CV solves and
//! rule comparisons across cores.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `SGL_THREADS` env override, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SGL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Normalize a thread-count setting: `0` means "auto" (the
/// `SGL_THREADS` / available-parallelism default of [`default_threads`]),
/// anything else is taken literally. Shared by the CLI, `PathBatch::run`
/// and the solve service so a `threads = 0` config can never produce a
/// zero-worker pool.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        default_threads()
    } else {
        threads
    }
}

/// A persistent worker pool: `n` named OS threads all running the same
/// drain loop until it returns. Unlike [`parallel_map`] (scoped, one
/// batch, joins before returning) the pool outlives any single work item —
/// the solve service keeps one alive for its whole lifetime and feeds it
/// through a shared queue.
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (at least one), each running
    /// `f(worker_index)` to completion. `f` is expected to loop over a
    /// shared queue and return when its owner signals shutdown.
    pub fn spawn<F>(threads: usize, f: F) -> Self
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = std::sync::Arc::new(f);
        let handles = (0..threads.max(1))
            .map(|i| {
                let f = f.clone();
                std::thread::Builder::new()
                    .name(format!("sgl-worker-{i}"))
                    .spawn(move || f(i))
                    .expect("spawning worker thread")
            })
            .collect();
        WorkerPool { handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Join every worker. The owner must already have signalled its drain
    /// loops to return, or this blocks forever. A worker that died to an
    /// *uncaught* panic is reported on stderr rather than re-raised (the
    /// service catches per-job panics itself, and join_all runs from Drop
    /// where unwinding again would abort).
    pub fn join_all(&mut self) {
        for h in self.handles.drain(..) {
            let name = h.thread().name().unwrap_or("sgl-worker").to_string();
            if h.join().is_err() {
                eprintln!("warning: worker thread {name} panicked outside a job");
            }
        }
    }
}

/// One result slot: the item's value or, if the worker closure panicked on
/// it, the caught panic payload.
type Slot<T> = Option<std::thread::Result<T>>;

/// Apply `f` to every index in `0..n` on up to `threads` workers and collect
/// the results in order. Work is distributed dynamically (atomic counter),
/// so uneven item costs (e.g. small vs large lambda solves) balance well.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    // Catching worker panics (instead of letting the scoped thread die)
    // keeps the per-slot mutexes unpoisoned and lets the join path re-raise
    // the *original* panic rather than a misleading "worker panicked before
    // producing a result" unwrap failure.
    let out: Vec<Mutex<Slot<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let abort = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break; // a sibling already failed: stop taking work
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = catch_unwind(AssertUnwindSafe(|| f(i)));
                if val.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                *out[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(val);
            });
        }
    });
    let mut values: Vec<Option<T>> = Vec::with_capacity(n);
    let mut panic_payload: Option<Box<dyn std::any::Any + Send>> = None;
    for m in out {
        match m.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(Ok(v)) => values.push(Some(v)),
            Some(Err(p)) => {
                if panic_payload.is_none() {
                    panic_payload = Some(p);
                }
                values.push(None);
            }
            // Unfilled slot: only possible when a sibling panicked and the
            // pool aborted early.
            None => values.push(None),
        }
    }
    if let Some(p) = panic_payload {
        resume_unwind(p);
    }
    values
        .into_iter()
        .map(|v| v.expect("no worker panicked, so every slot is filled"))
        .collect()
}

/// Like [`parallel_map`] over an input slice.
pub fn parallel_map_slice<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    parallel_map(items.len(), threads, |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn slice_variant() {
        let items = vec!["a", "bb", "ccc"];
        let out = parallel_map_slice(&items, 2, |s| s.len());
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn uneven_work_balances() {
        // Just a smoke test that dynamic scheduling completes with skewed work.
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 1000) {
                acc = acc.wrapping_add(k);
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn resolve_threads_zero_means_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(0), default_threads());
    }

    #[test]
    fn worker_pool_runs_every_worker_and_joins() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let mut pool = WorkerPool::spawn(4, move |_i| {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(pool.len(), 4);
        assert!(!pool.is_empty());
        pool.join_all();
        assert!(pool.is_empty());
        assert_eq!(count.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn worker_pool_spawns_at_least_one() {
        let mut pool = WorkerPool::spawn(0, |_| {});
        assert_eq!(pool.len(), 1);
        pool.join_all();
    }

    #[test]
    fn worker_panic_propagates_original_payload() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(16, 4, |i| {
                if i == 5 {
                    panic!("boom at item {i}");
                }
                i * 2
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at item 5"), "wrong payload: {msg:?}");
    }

    #[test]
    fn single_thread_panic_also_propagates() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(3, 1, |i| {
                if i == 1 {
                    panic!("serial boom");
                }
                i
            })
        }));
        assert!(caught.is_err());
    }
}
