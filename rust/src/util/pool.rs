//! Scoped data-parallel helpers built on `std::thread` (tokio/rayon are not
//! available offline). The coordinator uses these to fan path/CV solves and
//! rule comparisons across cores.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `SGL_THREADS` env override, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SGL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One result slot: the item's value or, if the worker closure panicked on
/// it, the caught panic payload.
type Slot<T> = Option<std::thread::Result<T>>;

/// Apply `f` to every index in `0..n` on up to `threads` workers and collect
/// the results in order. Work is distributed dynamically (atomic counter),
/// so uneven item costs (e.g. small vs large lambda solves) balance well.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    // Catching worker panics (instead of letting the scoped thread die)
    // keeps the per-slot mutexes unpoisoned and lets the join path re-raise
    // the *original* panic rather than a misleading "worker panicked before
    // producing a result" unwrap failure.
    let out: Vec<Mutex<Slot<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let abort = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break; // a sibling already failed: stop taking work
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = catch_unwind(AssertUnwindSafe(|| f(i)));
                if val.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                *out[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(val);
            });
        }
    });
    let mut values: Vec<Option<T>> = Vec::with_capacity(n);
    let mut panic_payload: Option<Box<dyn std::any::Any + Send>> = None;
    for m in out {
        match m.into_inner().unwrap_or_else(|p| p.into_inner()) {
            Some(Ok(v)) => values.push(Some(v)),
            Some(Err(p)) => {
                if panic_payload.is_none() {
                    panic_payload = Some(p);
                }
                values.push(None);
            }
            // Unfilled slot: only possible when a sibling panicked and the
            // pool aborted early.
            None => values.push(None),
        }
    }
    if let Some(p) = panic_payload {
        resume_unwind(p);
    }
    values
        .into_iter()
        .map(|v| v.expect("no worker panicked, so every slot is filled"))
        .collect()
}

/// Like [`parallel_map`] over an input slice.
pub fn parallel_map_slice<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    parallel_map(items.len(), threads, |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn slice_variant() {
        let items = vec!["a", "bb", "ccc"];
        let out = parallel_map_slice(&items, 2, |s| s.len());
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn uneven_work_balances() {
        // Just a smoke test that dynamic scheduling completes with skewed work.
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 1000) {
                acc = acc.wrapping_add(k);
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn worker_panic_propagates_original_payload() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(16, 4, |i| {
                if i == 5 {
                    panic!("boom at item {i}");
                }
                i * 2
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom at item 5"), "wrong payload: {msg:?}");
    }

    #[test]
    fn single_thread_panic_also_propagates() {
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_map(3, 1, |i| {
                if i == 1 {
                    panic!("serial boom");
                }
                i
            })
        }));
        assert!(caught.is_err());
    }
}
