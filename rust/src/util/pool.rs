//! Scoped data-parallel helpers built on `std::thread` (tokio/rayon are not
//! available offline). The coordinator uses these to fan path/CV solves and
//! rule comparisons across cores.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: `SGL_THREADS` env override, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SGL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every index in `0..n` on up to `threads` workers and collect
/// the results in order. Work is distributed dynamically (atomic counter),
/// so uneven item costs (e.g. small vs large lambda solves) balance well.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                *out[i].lock().unwrap() = Some(val);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked before producing a result"))
        .collect()
}

/// Like [`parallel_map`] over an input slice.
pub fn parallel_map_slice<I, T, F>(items: &[I], threads: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    parallel_map(items.len(), threads, |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn slice_variant() {
        let items = vec!["a", "bb", "ccc"];
        let out = parallel_map_slice(&items, 2, |s| s.len());
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn uneven_work_balances() {
        // Just a smoke test that dynamic scheduling completes with skewed work.
        let out = parallel_map(32, 4, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 * 1000) {
                acc = acc.wrapping_add(k);
            }
            acc
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
