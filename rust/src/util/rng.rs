//! Deterministic pseudo-random number generation.
//!
//! This environment is fully offline, so we implement our own generator
//! instead of depending on the `rand` ecosystem. The generator is
//! PCG-XSH-RR 64/32 (O'Neill, 2014) extended to produce 64-bit outputs by
//! combining two 32-bit draws, plus Box-Muller Gaussian sampling. All
//! experiment workloads in this repository are seeded, so every figure is
//! exactly reproducible.

/// PCG-XSH-RR 64/32 pseudo-random number generator.
///
/// A small, fast, statistically strong generator with a 64-bit state and a
/// 63-bit stream selector. Used everywhere a seeded RNG is needed
/// (synthetic data, property tests, benchmark workloads).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Create a generator from a single seed (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform double in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform double in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let (mut hi, mut lo) = mul_u64(self.next_u64(), n);
        if lo < n {
            // Rejection threshold removes the modulo bias.
            let t = n.wrapping_neg() % n;
            while lo < t {
                let (h, l) = mul_u64(self.next_u64(), n);
                hi = h;
                lo = l;
            }
        }
        hi
    }

    /// Standard normal sample (Box-Muller, one value per call; the spare
    /// value is intentionally discarded to keep the generator stateless
    /// beyond its PCG core — the cost is negligible for our workloads).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal sample with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Vector of iid standard normal samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of iid uniform `[lo, hi)` samples.
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Random sign: -1.0 or +1.0 with equal probability.
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg::seeded(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Pcg::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg::seeded(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg::seeded(19);
        let idx = rng.sample_indices(100, 10);
        assert_eq!(idx.len(), 10);
        for w in idx.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg::seeded(23);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
