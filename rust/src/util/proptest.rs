//! A compact property-based testing harness (`proptest` is unavailable
//! offline). Provides seeded random generators and a `forall` runner with
//! rudimentary shrinking for numeric vectors.
//!
//! Usage:
//! ```ignore
//! use crate::util::proptest::{forall, Gen};
//! forall("prox is non-expansive", 200, |g| {
//!     let x = g.vec_f64(1..50, -10.0..10.0);
//!     // return Err(msg) on violation
//!     Ok(())
//! });
//! ```

use super::rng::Pcg;
use std::ops::Range;

/// Random value source handed to property bodies.
pub struct Gen {
    rng: Pcg,
    /// Case index (0-based), useful for coverage-directed choices.
    pub case: usize,
}

impl Gen {
    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform_in(r.start, r.end)
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below((r.end - r.start) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Uniform in range, with occasional special values (0, bounds) mixed in
    /// to probe edge cases.
    pub fn f64_edgy(&mut self, r: Range<f64>) -> f64 {
        match self.rng.below(10) {
            0 => 0.0,
            1 => r.start,
            2 => r.end,
            _ => self.f64_in(r),
        }
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_edgy(vals.clone())).collect()
    }

    pub fn vec_normal(&mut self, len: Range<usize>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sparse vector: each entry nonzero with probability `density`.
    pub fn vec_sparse(&mut self, len: Range<usize>, density: f64) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| if self.rng.uniform() < density { self.normal() * 3.0 } else { 0.0 })
            .collect()
    }

    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop`; panic with a reproducer message on
/// the first failure. The seed is fixed per property name so failures are
/// deterministic; set `SGL_PROPTEST_SEED` to explore other seeds.
pub fn forall<F: FnMut(&mut Gen) -> CaseResult>(name: &str, cases: usize, mut prop: F) {
    let base_seed = std::env::var("SGL_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for case in 0..cases {
        let mut g = Gen { rng: Pcg::new(base_seed, case as u64 + 1), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {base_seed}):\n  {msg}\n\
                 reproduce with SGL_PROPTEST_SEED={base_seed}"
            );
        }
    }
}

/// Assert helper for property bodies: approximate float equality.
pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> CaseResult {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|diff|={:.3e}, tol={tol:.1e})", (a - b).abs()))
    }
}

/// Assert helper: condition must hold.
pub fn check(cond: bool, what: &str) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(what.to_string())
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |g| {
            let x = g.f64_in(0.0..1.0);
            check((0.0..=1.0).contains(&x), "uniform in range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failure() {
        forall("always-fails", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn vec_generators_respect_bounds() {
        forall("vec-bounds", 50, |g| {
            let v = g.vec_f64(1..20, -2.0..2.0);
            check(v.len() < 20 && !v.is_empty(), "length bounds")?;
            check(v.iter().all(|x| (-2.0..=2.0).contains(x)), "value bounds")
        });
    }

    #[test]
    fn check_close_scales() {
        assert!(check_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(check_close(0.0, 1e-3, 1e-6, "small").is_err());
    }

    #[test]
    fn deterministic_per_name() {
        let mut first: Vec<f64> = vec![];
        forall("det", 5, |g| {
            first.push(g.f64_in(0.0..1.0));
            Ok(())
        });
        let mut second: Vec<f64> = vec![];
        forall("det", 5, |g| {
            second.push(g.f64_in(0.0..1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
