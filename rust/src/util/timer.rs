//! Wall-clock timing and a minimal benchmark runner.
//!
//! `criterion` is not available offline, so `benches/` binaries use this
//! module (with `harness = false` in `Cargo.toml`). The runner does warmup
//! iterations followed by timed iterations and reports a [`Summary`].

use super::stats::Summary;
use std::time::Instant;

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Restart the stopwatch and return the elapsed seconds.
    pub fn lap_s(&mut self) -> f64 {
        let dt = self.elapsed_s();
        self.start = Instant::now();
        dt
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub times: Summary,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12.3} us/iter (median {:>12.3}, sd {:>10.3}) x{}",
            self.name,
            self.times.mean * 1e6,
            self.times.median * 1e6,
            self.times.stddev * 1e6,
            self.times.n
        )
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Hard cap on total measurement time (seconds); the runner stops adding
    /// iterations once exceeded (at least one timed iteration always runs).
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, iters: 20, max_seconds: 30.0 }
    }
}

/// Run `f` repeatedly and collect per-iteration timings.
///
/// `f` receives the iteration index; use [`black_box`] on inputs/outputs to
/// prevent the optimizer from deleting the work.
pub fn bench<F: FnMut(usize)>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for i in 0..cfg.warmup_iters {
        f(i);
    }
    let mut times = Vec::with_capacity(cfg.iters);
    let total = Stopwatch::start();
    for i in 0..cfg.iters {
        let sw = Stopwatch::start();
        f(i);
        times.push(sw.elapsed_s());
        if total.elapsed_s() > cfg.max_seconds && !times.is_empty() {
            break;
        }
    }
    BenchResult { name: name.to_string(), times: Summary::of(&times) }
}

/// Identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0usize;
        let cfg = BenchConfig { warmup_iters: 2, iters: 5, max_seconds: 60.0 };
        let res = bench("noop", cfg, |_| count += 1);
        assert_eq!(count, 7);
        assert_eq!(res.times.n, 5);
    }

    #[test]
    fn bench_respects_time_cap() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 1_000_000, max_seconds: 0.05 };
        let res = bench("sleepy", cfg, |_| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(res.times.n < 1000);
    }
}
