//! Per-solve progress reporting for the elastic fleet's liveness layer.
//!
//! A worker solving a λ-shard needs to tell its coordinator "still
//! converging" without the solver knowing anything about sockets: a
//! legitimate solve may run for minutes, so the coordinator's only
//! alternative — a read deadline — would misclassify long solves as
//! dead workers. The contract here is one [`ProgressCell`] per in-flight
//! solve: the solver thread publishes `(epoch, gap)` at every gap check
//! through a thread-local handle, and the worker's pinger thread reads
//! the cell (relaxed atomics, no locks on the solve path) and pushes
//! [`Progress`](crate::util::wire::Message::Progress) frames.
//!
//! Strictly observation-only: nothing ever reads the cell back into the
//! solve, so solver output is bit-identical with or without a cell
//! installed (the same contract the trace layer pins).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Lock-free `(epoch, gap)` mailbox between a solver thread and the
/// worker's progress pinger. The two words are updated independently
/// (no seqlock): a torn read pairs a fresh epoch with a stale gap at
/// worst, which is fine for liveness — any store at all proves the
/// solve is alive.
#[derive(Debug, Default)]
pub struct ProgressCell {
    epoch: AtomicU64,
    gap_bits: AtomicU64,
}

impl ProgressCell {
    pub fn new() -> Arc<Self> {
        Arc::new(ProgressCell {
            epoch: AtomicU64::new(0),
            // NaN, not 0.0: an unobserved gap must not read as converged.
            gap_bits: AtomicU64::new(f64::NAN.to_bits()),
        })
    }

    /// Publish one gap-check observation (solver side).
    pub fn publish(&self, epoch: usize, gap: f64) {
        self.epoch.store(epoch as u64, Ordering::Relaxed);
        self.gap_bits.store(gap.to_bits(), Ordering::Relaxed);
    }

    /// Epochs completed at the last published check (pinger side).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Last published duality gap as IEEE-754 bits (pinger side) —
    /// bits so the value drops straight into
    /// [`WorkerSummary::gap_bits`](crate::util::wire::WorkerSummary).
    pub fn gap_bits(&self) -> u64 {
        self.gap_bits.load(Ordering::Relaxed)
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<ProgressCell>>> = const { RefCell::new(None) };
}

/// Install (or clear, with `None`) the cell the current thread's solves
/// report into. Returns the previously installed cell so nested scopes
/// can restore it.
pub fn set_current(cell: Option<Arc<ProgressCell>>) -> Option<Arc<ProgressCell>> {
    CURRENT.with(|c| std::mem::replace(&mut *c.borrow_mut(), cell))
}

/// Report one gap-check observation into the current thread's cell;
/// no-op (two thread-local loads) when no cell is installed — solves
/// outside a worker pay nearly nothing.
pub fn report(epoch: usize, gap: f64) {
    CURRENT.with(|c| {
        if let Some(cell) = c.borrow().as_ref() {
            cell.publish(epoch, gap);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_is_a_noop_without_a_cell() {
        set_current(None);
        report(10, 0.5); // must not panic or allocate a cell
    }

    #[test]
    fn cell_receives_reports_and_restores_previous() {
        let a = ProgressCell::new();
        assert!(f64::from_bits(a.gap_bits()).is_nan(), "unobserved gap is NaN");
        let prev = set_current(Some(a.clone()));
        assert!(prev.is_none());
        report(3, 0.25);
        assert_eq!(a.epoch(), 3);
        assert_eq!(a.gap_bits(), 0.25f64.to_bits());
        let b = ProgressCell::new();
        let prev = set_current(Some(b.clone()));
        assert!(Arc::ptr_eq(&prev.unwrap(), &a));
        report(9, 0.125);
        assert_eq!(a.epoch(), 3, "old cell no longer receives");
        assert_eq!(b.epoch(), 9);
        set_current(None);
    }
}
