//! Small descriptive-statistics helpers used by the bench harness and the
//! experiment reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0.0 for fewer than two samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Median (average of the two middle values for even lengths).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Linear-interpolation quantile, `q` in `[0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Minimum; +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum; -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a sample of measurements (times, errors, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            median: median(xs),
            min: min(xs),
            max: max(xs),
            p95: quantile(xs, 0.95),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4e} sd={:.2e} med={:.4e} min={:.4e} p95={:.4e} max={:.4e}",
            self.n, self.mean, self.stddev, self.median, self.min, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[1.0, 3.0, 2.0]), 2.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn quantile_endpoints() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn summary_consistent() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
    }
}
