//! Dependency-free substrates: RNG, CLI parsing, thread pool, timing,
//! statistics, JSON emission, a property-testing harness, and the framed
//! binary wire codec for the distributed serving layer.
//!
//! This build environment is fully offline with only the `xla` and `anyhow`
//! crates available, so the roles normally played by `rand`, `clap`,
//! `rayon`, `criterion`, `serde`, `proptest` and a serialization framework
//! are implemented here from scratch (see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod lru;
pub mod pool;
pub mod progress;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod trace;
pub mod wire;
