//! Dependency-free substrates: RNG, CLI parsing, thread pool, timing,
//! statistics, JSON emission, and a property-testing harness.
//!
//! This build environment is fully offline with only the `xla` and `anyhow`
//! crates available, so the roles normally played by `rand`, `clap`,
//! `rayon`, `criterion`, `serde` and `proptest` are implemented here from
//! scratch (see DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
