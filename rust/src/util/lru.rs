//! A small bounded map with least-recently-used eviction.
//!
//! Three serving-layer caches (the service's solved-path fingerprint
//! cache, each remote worker's dataset store, and the fleet's dataset
//! fingerprint registry) independently grew the same hand-rolled pattern:
//! a `HashMap<K, (V, u64)>` stamped with a logical tick, evicted by a
//! linear min-scan when past capacity. This module is that pattern, once,
//! with the tick bookkeeping kept internal.
//!
//! Deliberately *not* a linked-list LRU: capacities here are small
//! (tens to hundreds), eviction is rare, and the `O(len)` min-scan on
//! insert keeps the structure index-free and trivially correct. Recency is
//! a strict logical clock — `get`/`insert` bump it, `contains`/`peek` do
//! not — so lookups that must not perturb eviction order have a
//! side-effect-free spelling.

use std::collections::HashMap;
use std::hash::Hash;

/// Bounded LRU map. Not thread-safe on its own; the serving layer wraps
/// it in the same `Mutex`es that guarded the hand-rolled versions.
#[derive(Clone, Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, (V, u64)>,
    tick: u64,
    cap: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// An empty cache evicting past `cap` entries (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "LruCache capacity must be at least 1");
        LruCache { map: HashMap::new(), tick: 0, cap }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Membership test. Does **not** refresh recency.
    pub fn contains(&self, k: &K) -> bool {
        self.map.contains_key(k)
    }

    /// Read without refreshing recency (metrics, assertions).
    pub fn peek(&self, k: &K) -> Option<&V> {
        self.map.get(k).map(|(v, _)| v)
    }

    /// Read and mark `k` most-recently-used.
    pub fn get(&mut self, k: &K) -> Option<&V> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(k).map(|(v, t)| {
            *t = tick;
            &*v
        })
    }

    /// Insert (or overwrite) and mark most-recently-used, then evict the
    /// least-recently-used entries until back within capacity. Returns how
    /// many entries were evicted (0 or 1 in steady state).
    pub fn insert(&mut self, k: K, v: V) -> usize {
        self.tick += 1;
        self.map.insert(k, (v, self.tick));
        let mut evicted = 0;
        while self.map.len() > self.cap {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone())
                .expect("non-empty map over capacity");
            self.map.remove(&oldest);
            evicted += 1;
        }
        evicted
    }

    /// Remove an entry, returning its value if present.
    pub fn remove(&mut self, k: &K) -> Option<V> {
        self.map.remove(k).map(|(v, _)| v)
    }

    /// Iterate over entries in arbitrary order (no recency refresh).
    pub fn iter(&self) -> impl Iterator<Item = (&K, &V)> {
        self.map.iter().map(|(k, (v, _))| (k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        assert_eq!(c.insert(1, "a"), 0);
        assert_eq!(c.insert(2, "b"), 0);
        assert_eq!(c.insert(3, "c"), 1); // evicts 1
        assert!(!c.contains(&1));
        assert!(c.contains(&2) && c.contains(&3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn get_refreshes_recency_but_contains_does_not() {
        let mut c = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.get(&1), Some(&"a")); // 1 now newest
        c.insert(3, "c"); // evicts 2, not 1
        assert!(c.contains(&1) && !c.contains(&2));

        let mut d = LruCache::new(2);
        d.insert(1, "a");
        d.insert(2, "b");
        assert!(d.contains(&1)); // no bump
        assert!(d.peek(&1).is_some()); // no bump
        d.insert(3, "c"); // evicts 1: contains/peek left it oldest
        assert!(!d.contains(&1) && d.contains(&2));
    }

    #[test]
    fn overwrite_does_not_evict() {
        let mut c = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.insert(1, "a2"), 0);
        assert_eq!(c.len(), 2);
        assert_eq!(c.peek(&1), Some(&"a2"));
    }

    #[test]
    fn remove_and_iter() {
        let mut c = LruCache::new(3);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.remove(&1), Some(10));
        assert_eq!(c.remove(&1), None);
        let all: Vec<_> = c.iter().map(|(k, v)| (*k, *v)).collect();
        assert_eq!(all, vec![(2, 20)]);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = LruCache::<u32, u32>::new(0);
    }
}
