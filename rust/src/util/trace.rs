//! Dependency-free solve tracing: a process-global collector with
//! per-thread buffers, an explicit span/event API, and export to Chrome
//! trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! # Contract
//!
//! - **Observation only.** Tracing never feeds back into the solver: no
//!   instrumentation site reads trace state into a numeric decision, so
//!   solver output is bit-identical with tracing on, off, or toggled
//!   mid-run. Tests pin this ([`crate::solver`] disabled-tracing
//!   bit-identity suite).
//! - **Near-zero cost when disabled.** Every recording entry point first
//!   loads one relaxed [`AtomicBool`]; argument closures are only invoked
//!   when the collector is enabled, so a disabled trace site costs a
//!   predictable load+branch on the gap-check path (never the per-
//!   coordinate hot loop).
//! - **Per-thread buffers.** Each thread appends to its own buffer
//!   (registered once with the global collector), so concurrent solvers
//!   never contend on a shared lock. [`drain`] collects from *all*
//!   registered buffers — including threads still alive in a pool — and
//!   returns events sorted by timestamp.
//! - **Bounded memory.** A buffer holds at most [`MAX_EVENTS_PER_THREAD`]
//!   events; overflow is dropped and counted ([`dropped`]), never
//!   reallocated without bound.
//!
//! The process-global design mirrors [`crate::linalg::simd`]'s kernel
//! policy: enabling tracing is a runtime switch (`--trace-out`,
//! `[trace]` config, `SGL_TRACE`), not a `SolveOptions` field, so the
//! wire codec and the service cache key are untouched by observability.

use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Hard cap on buffered events per thread; see the module docs.
pub const MAX_EVENTS_PER_THREAD: usize = 1 << 20;

/// Trace-event phase, mapped to Chrome trace-event `ph` codes on export
/// (`B`/`E` span brackets, `i` for instant events).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Span open (`ph: "B"`).
    Begin,
    /// Span close (`ph: "E"`).
    End,
    /// Point event (`ph: "i"`, thread-scoped).
    Instant,
}

/// One typed event argument (rendered under `args` in the export).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgValue {
    /// Unsigned integer argument (epochs, counts).
    U64(u64),
    /// Floating argument (gaps, radii, lambdas).
    F64(f64),
    /// String tag (rule/datafit/kernel names).
    Str(String),
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> ArgValue {
        ArgValue::U64(v)
    }
}
impl From<usize> for ArgValue {
    fn from(v: usize) -> ArgValue {
        ArgValue::U64(v as u64)
    }
}
impl From<f64> for ArgValue {
    fn from(v: f64) -> ArgValue {
        ArgValue::F64(v)
    }
}
impl From<&str> for ArgValue {
    fn from(v: &str) -> ArgValue {
        ArgValue::Str(v.to_string())
    }
}
impl From<String> for ArgValue {
    fn from(v: String) -> ArgValue {
        ArgValue::Str(v)
    }
}

/// Event argument list: static keys, typed values.
pub type Args = Vec<(&'static str, ArgValue)>;

/// One recorded trace event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Event name (a static site label, e.g. `"gap_check"`).
    pub name: &'static str,
    /// Span bracket or instant marker.
    pub phase: Phase,
    /// Microseconds since the collector was first touched.
    pub ts_us: u64,
    /// Stable per-thread id assigned by the collector (1-based).
    pub tid: u64,
    /// Typed arguments recorded at the site.
    pub args: Args,
}

struct Collector {
    start: Instant,
    buffers: Mutex<Vec<Arc<Mutex<Vec<Event>>>>>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SAMPLE: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn collector() -> &'static Collector {
    static C: OnceLock<Collector> = OnceLock::new();
    C.get_or_init(|| Collector { start: Instant::now(), buffers: Mutex::new(Vec::new()) })
}

thread_local! {
    static LOCAL: RefCell<Option<(u64, Arc<Mutex<Vec<Event>>>)>> = const { RefCell::new(None) };
}

/// Whether the collector is currently recording. One relaxed atomic
/// load — the entire cost of a disabled trace site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The sampling divisor for high-frequency sites: a site with a
/// per-solve sequence number records only every `sample_every()`-th
/// occurrence (1 = record all). Span brackets are never sampled.
#[inline]
pub fn sample_every() -> u64 {
    SAMPLE.load(Ordering::Relaxed).max(1)
}

/// `true` iff tracing is enabled *and* occurrence `seq` (0-based within
/// one solve) falls on the sampling grid.
#[inline]
pub fn sampled(seq: u64) -> bool {
    enabled() && seq % sample_every() == 0
}

/// Turn the collector on with the given sampling divisor (clamped to
/// ≥ 1). Safe to call more than once; later calls update the divisor.
pub fn enable(sample: u64) {
    SAMPLE.store(sample.max(1), Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording. Buffered events are kept until [`drain`]/[`clear`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Events dropped because a thread buffer hit [`MAX_EVENTS_PER_THREAD`].
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn push_event(name: &'static str, phase: Phase, args: Args) {
    let c = collector();
    let ts_us = c.start.elapsed().as_micros() as u64;
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let buf = Arc::new(Mutex::new(Vec::new()));
            c.buffers.lock().unwrap().push(buf.clone());
            *slot = Some((tid, buf));
        }
        let (tid, buf) = slot.as_ref().expect("buffer registered above");
        let mut buf = buf.lock().unwrap();
        if buf.len() >= MAX_EVENTS_PER_THREAD {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        } else {
            buf.push(Event { name, phase, ts_us, tid: *tid, args });
        }
    });
}

/// Record a point event. The argument closure is only invoked when the
/// collector is enabled.
pub fn instant<F: FnOnce() -> Args>(name: &'static str, args: F) {
    if enabled() {
        push_event(name, Phase::Instant, args());
    }
}

/// RAII span: records a `Begin` bracket at construction (when enabled)
/// and the matching `End` on drop. A span opened while tracing is
/// enabled always closes, even if tracing is disabled mid-span, so
/// exported brackets stay balanced.
#[must_use = "a span records its duration; bind it to a local"]
pub struct Span {
    name: Option<&'static str>,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(name) = self.name.take() {
            push_event(name, Phase::End, Vec::new());
        }
    }
}

/// Open a span with no arguments.
pub fn span(name: &'static str) -> Span {
    span_with(name, Vec::new)
}

/// Open a span; the argument closure is only invoked when enabled.
pub fn span_with<F: FnOnce() -> Args>(name: &'static str, args: F) -> Span {
    if enabled() {
        push_event(name, Phase::Begin, args());
        Span { name: Some(name) }
    } else {
        Span { name: None }
    }
}

/// Remove and return every buffered event from every registered thread
/// buffer, sorted by timestamp (stable, so same-timestamp events keep
/// their per-thread order).
pub fn drain() -> Vec<Event> {
    let mut events = Vec::new();
    for buf in collector().buffers.lock().unwrap().iter() {
        events.append(&mut buf.lock().unwrap());
    }
    events.sort_by_key(|e| e.ts_us);
    events
}

/// Discard all buffered events and reset the dropped-event counter.
pub fn clear() {
    drop(drain());
    DROPPED.store(0, Ordering::Relaxed);
}

fn args_json(args: &Args) -> Json {
    let mut obj = Json::obj();
    for (k, v) in args {
        obj = match v {
            ArgValue::U64(x) => obj.with(k, *x as f64),
            ArgValue::F64(x) => obj.with(k, *x),
            ArgValue::Str(s) => obj.with(k, s.as_str()),
        };
    }
    obj
}

/// Render events as a Chrome trace-event JSON document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}` with `B`/`E`/`i`
/// phases — the format Perfetto and `chrome://tracing` load directly.
pub fn chrome_trace(events: &[Event]) -> Json {
    let pid = std::process::id() as f64;
    let items: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut obj = Json::obj()
                .with("name", e.name)
                .with(
                    "ph",
                    match e.phase {
                        Phase::Begin => "B",
                        Phase::End => "E",
                        Phase::Instant => "i",
                    },
                )
                .with("ts", e.ts_us as f64)
                .with("pid", pid)
                .with("tid", e.tid as f64);
            if e.phase == Phase::Instant {
                obj = obj.with("s", "t");
            }
            if !e.args.is_empty() {
                obj = obj.with("args", args_json(&e.args));
            }
            obj
        })
        .collect();
    Json::obj().with("traceEvents", Json::Arr(items)).with("displayTimeUnit", "ms")
}

/// Drain every buffered event and write the Chrome trace-event JSON to
/// `path`. Called by the CLI on path/serve/worker completion.
pub fn write_chrome_trace(path: &str) -> std::io::Result<usize> {
    let events = drain();
    std::fs::write(path, chrome_trace(&events).dump())?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; serialize the tests that toggle
    /// it. Other lib tests may run concurrently and hit instrumented
    /// sites while a test here has the collector enabled, so every
    /// assertion below filters drained events to this module's own
    /// event names instead of assuming exclusive ownership.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn named<'a>(events: &'a [Event], names: &[&str]) -> Vec<&'a Event> {
        events.iter().filter(|e| names.contains(&e.name)).collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = lock();
        disable();
        clear();
        let mut called = false;
        instant("ut_off", || {
            called = true;
            vec![]
        });
        let s = span("ut_off_span");
        drop(s);
        assert!(!called, "arg closure must not run when disabled");
        assert!(named(&drain(), &["ut_off", "ut_off_span"]).is_empty());
    }

    #[test]
    fn spans_balance_and_sort() {
        let _g = lock();
        clear();
        enable(1);
        {
            let _outer = span_with("ut_outer", || vec![("k", ArgValue::from(3u64))]);
            instant("ut_tick", || vec![("gap", ArgValue::from(0.5))]);
            let _inner = span("ut_inner");
        }
        disable();
        let events = drain();
        let mine = named(&events, &["ut_outer", "ut_tick", "ut_inner"]);
        let names: Vec<(&str, Phase)> = mine.iter().map(|e| (e.name, e.phase)).collect();
        assert_eq!(
            names,
            vec![
                ("ut_outer", Phase::Begin),
                ("ut_tick", Phase::Instant),
                ("ut_inner", Phase::Begin),
                ("ut_inner", Phase::End),
                ("ut_outer", Phase::End),
            ]
        );
        for w in events.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us);
        }
        let owned: Vec<Event> = mine.into_iter().cloned().collect();
        let doc = chrome_trace(&owned).dump();
        assert!(doc.starts_with("{\"traceEvents\":["));
        assert!(doc.contains("\"ph\":\"B\""));
        assert!(doc.contains("\"ph\":\"E\""));
        assert!(doc.contains("\"ph\":\"i\""));
        assert!(doc.contains("\"gap\":0.5"));
    }

    #[test]
    fn sampling_thins_instants() {
        let _g = lock();
        clear();
        enable(4);
        for seq in 0..10u64 {
            if sampled(seq) {
                instant("ut_sampled", Vec::new);
            }
        }
        disable();
        assert_eq!(named(&drain(), &["ut_sampled"]).len(), 3); // seq 0, 4, 8
    }

    #[test]
    fn cross_thread_events_all_drain() {
        let _g = lock();
        clear();
        enable(1);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| instant("ut_xthread", Vec::new));
            }
        });
        instant("ut_xmain", Vec::new);
        disable();
        let events = drain();
        let mine = named(&events, &["ut_xthread", "ut_xmain"]);
        assert_eq!(mine.len(), 4);
        let tids: std::collections::BTreeSet<u64> = mine.iter().map(|e| e.tid).collect();
        assert!(tids.len() >= 2, "expected events from multiple threads");
    }
}
