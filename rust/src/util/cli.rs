//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Each binary declares its options up front so `--help` output
//! can be generated.

use std::collections::BTreeMap;

/// Declared option for help output and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    program: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding program name
    /// handling: the first item *is* the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(
        raw: I,
        specs: &[OptSpec],
    ) -> Result<Self, String> {
        let mut it = raw.into_iter();
        let program = it.next().unwrap_or_else(|| "prog".to_string());
        let mut args = Args { program, specs: specs.to_vec(), ..Default::default() };
        let take_value = |name: &str, specs: &[OptSpec]| -> Option<bool> {
            specs.iter().find(|s| s.name == name).map(|s| s.takes_value)
        };
        let mut rest = it.peekable();
        while let Some(tok) = rest.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if name == "help" {
                    args.flags.push("help".to_string());
                    continue;
                }
                match take_value(&name, &args.specs) {
                    Some(true) => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => rest
                                .next()
                                .ok_or_else(|| format!("--{name} expects a value"))?,
                        };
                        args.values.insert(name, v);
                    }
                    Some(false) => {
                        if inline_val.is_some() {
                            return Err(format!("--{name} does not take a value"));
                        }
                        args.flags.push(name);
                    }
                    None => return Err(format!("unknown option --{name}")),
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments; print help and exit on `--help` or
    /// parse error.
    pub fn parse_or_exit(specs: &[OptSpec]) -> Self {
        match Self::parse_from(std::env::args(), specs) {
            Ok(args) => {
                if args.flag("help") {
                    eprintln!("{}", args.usage());
                    std::process::exit(0);
                }
                args
            }
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }

    /// Human-readable usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [options] [args...]\n\noptions:\n", self.program);
        for spec in &self.specs {
            let arg = if spec.takes_value { format!("--{} <v>", spec.name) } else { format!("--{}", spec.name) };
            let def = spec.default.map(|d| format!(" (default {d})")).unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", arg, spec.help, def));
        }
        s
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String value with declared default fallback.
    pub fn get(&self, name: &str) -> Option<String> {
        self.values.get(name).cloned().or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default.map(str::to_string))
        })
    }

    pub fn get_or(&self, name: &str, fallback: &str) -> String {
        self.get(name).unwrap_or_else(|| fallback.to_string())
    }

    pub fn get_usize(&self, name: &str, fallback: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(fallback)
    }

    pub fn get_u64(&self, name: &str, fallback: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(fallback)
    }

    pub fn get_f64(&self, name: &str, fallback: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(fallback)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "n", help: "samples", takes_value: true, default: Some("100") },
            OptSpec { name: "verbose", help: "chatty", takes_value: false, default: None },
            OptSpec { name: "out", help: "output file", takes_value: true, default: None },
        ]
    }

    fn parse(toks: &[&str]) -> Result<Args, String> {
        let raw = std::iter::once("prog".to_string()).chain(toks.iter().map(|s| s.to_string()));
        Args::parse_from(raw, &specs())
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = parse(&["--n", "5", "--out=x.csv"]).unwrap();
        assert_eq!(a.get_usize("n", 0), 5);
        assert_eq!(a.get("out").unwrap(), "x.csv");
    }

    #[test]
    fn default_applies() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_usize("n", 0), 100);
        assert!(a.get("out").is_none());
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["--verbose", "cmd", "file.txt"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["cmd".to_string(), "file.txt".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&["--bogus"]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&["--n"]).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&["--verbose=1"]).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let a = parse(&[]).unwrap();
        let u = a.usage();
        assert!(u.contains("--n"));
        assert!(u.contains("samples"));
    }
}
