//! Framed binary wire codec for the distributed λ-shard serving layer
//! (L5, `coordinator::remote`).
//!
//! The GAP Safe structural fact that makes the solve pipeline
//! distributable is that the *only* state crossing a λ-shard boundary is
//! a [`DualHandoff`] — the terminal β plus a dual snapshot, `O(n + p)`
//! floats. This module puts exactly that (plus the shard assignments
//! around it) on the wire with zero dependencies:
//!
//! - **Framing** — every message is one length-prefixed frame:
//!   `u32 LE length` followed by `[version byte][tag byte][body]`. A
//!   decoder can never read past a frame, a truncated stream is a typed
//!   [`WireError::Truncated`] (never a panic), and a peer speaking a
//!   different protocol revision fails fast with
//!   [`WireError::BadVersion`].
//! - **Bit-exact floats** — every `f64` travels as its IEEE-754 bit
//!   pattern in little-endian byte order (`to_bits`/`from_bits`), so a
//!   replayed handoff is *bit-for-bit* the local one: NaN payloads,
//!   signed zeros, infinities and subnormals all survive the trip, which
//!   is what makes a remote shard's result identical to a local solve.
//! - **Dataset shipping** — [`WireDataset`] carries a whole problem
//!   instance (dense column-major or CSC triplets, `y`, group sizes, τ,
//!   weights, and since v2 the [`WireDatafit`]) and is addressed by a
//!   content [`fingerprint`] (64-bit FNV-1a over the canonical encoding):
//!   a fleet ships each dataset to each worker once and refers to it by
//!   hash thereafter. Two problems differing only in datafit hash
//!   differently — a quadratic and a logistic fit of the same `(X, y)`
//!   are different cache entries, never confused.
//! - **Typed error frames** — remote failures come back as
//!   [`RemoteError`] frames ([`RemoteErrorKind::UnknownDataset`] /
//!   `SolveFailed` / `BadRequest`), not closed sockets, so the client
//!   can distinguish "reship the dataset" from "this request is bad".
//!
//! [`fingerprint`]: WireDataset::fingerprint

use crate::coordinator::metrics::{MetricsSnapshot, TimerStats};
use crate::linalg::{CscMatrix, Design, Matrix};
use crate::screening::{ActiveSet, RuleKind};
use crate::solver::cd::{CheckEvent, SolveOptions, SolveResult};
use crate::solver::datafit::{Datafit, FitKind, Logistic, MultiTaskQuadratic, Quadratic};
use crate::solver::duality::DualSnapshot;
use crate::solver::groups::Groups;
use crate::solver::path::{DualHandoff, PathOptions, PathResult};
use crate::solver::problem::SglProblem;
use crate::solver::sweep::{SweepMode, SweepTuning};
use crate::solver::SolverKind;
use std::fmt;
use std::io::{Read, Write};

/// Protocol revision carried in every frame. Bump on any layout change:
/// mismatched peers fail with [`WireError::BadVersion`] instead of
/// misinterpreting bytes.
///
/// **v2** (datafit layer): [`WireDataset`] and [`ShardRequest`] carry a
/// [`WireDatafit`]; [`DualSnapshot`] frames carry `theta_aug_sq`. v1
/// frames are rejected with [`WireError::BadVersion`] — a v1 peer's bytes
/// would otherwise decode into a misaligned problem.
///
/// **v3** (kernel-policy PR): [`SolveOptions`] frames carry the six
/// [`SweepTuning`] knobs. The tuning shapes the parallel-CD round
/// structure (and hence the exact iterate trajectory), so a v2 peer
/// silently defaulting them would compute a *different* path than the
/// coordinator asked for — better to refuse the handshake.
///
/// **v4** (observability PR): [`Pong`](Message::Pong) carries a
/// [`WorkerSummary`] (in-flight shards, completed solves, uptime ticks)
/// and the [`StatsRequest`](Message::StatsRequest) /
/// [`StatsReply`](Message::StatsReply) scrape pair exists. The Pong body
/// grew, so a v3 peer decoding a v4 heartbeat would misread bytes —
/// refuse the handshake instead.
///
/// **v5** (multi-response PR): [`WireDatafit`] grows the
/// [`MultiTask`](WireDatafit::MultiTask) tag (with its task count), and a
/// multi-task [`WireDataset`] carries `y` with `n_rows · tasks` entries
/// (task-major). A v4 peer has no multi-task arm and would reject — or,
/// worse, misvalidate — such a dataset, so v4 frames are refused with
/// [`WireError::BadVersion`].
///
/// **v6** (elastic-fleet PR): [`WorkerSummary`] grows the per-shard
/// progress pair (`epoch`, `gap_bits`) behind the liveness design —
/// workers push unsolicited [`Progress`](Message::Progress) frames while
/// a solve runs, so a coordinator can requeue shards from a worker that
/// lost power without ever imposing a socket read deadline on legitimate
/// long solves. Worker-initiated [`Register`](Message::Register) /
/// [`Registered`](Message::Registered) frames let a restarted worker
/// rejoin a fleet, and the chunked ship triple
/// ([`ShipBegin`](Message::ShipBegin) / [`ShipChunk`](Message::ShipChunk)
/// / [`ShipEnd`](Message::ShipEnd)) streams a dataset as CSC/dense
/// column ranges so instances beyond [`MAX_FRAME`] (or beyond a single
/// allocation the shipper wants to make) travel incrementally. The Pong
/// body grew and six tags are new, so v5 peers are refused.
pub const WIRE_VERSION: u8 = 6;

/// Hard cap on one frame's body (2 GiB): a corrupt length prefix must
/// not become a giant allocation.
pub const MAX_FRAME: usize = 1 << 31;

/// Typed decode/transport failure. Every malformed input maps to one of
/// these — decoding never panics, whatever the bytes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The input ends before the frame does.
    Truncated { needed: usize, have: usize },
    /// The frame's version byte is not [`WIRE_VERSION`].
    BadVersion { got: u8 },
    /// Unknown message tag.
    BadTag { got: u8 },
    /// Length prefix exceeds [`MAX_FRAME`].
    Oversized { len: usize },
    /// Structurally invalid payload (bad counts, invalid UTF-8, a
    /// dataset that cannot form a problem, ...).
    Malformed(&'static str),
    /// Socket-level failure (or clean close mid-frame) while reading or
    /// writing frames.
    Io(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated frame: need {needed} bytes, have {have}")
            }
            WireError::BadVersion { got } => {
                write!(f, "bad wire version {got} (expected {WIRE_VERSION})")
            }
            WireError::BadTag { got } => write!(f, "unknown message tag {got}"),
            WireError::Oversized { len } => {
                write!(f, "frame length {len} exceeds the {MAX_FRAME}-byte cap")
            }
            WireError::Malformed(what) => write!(f, "malformed frame: {what}"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

// ---------------------------------------------------------------------------
// Primitive encoder / decoder
// ---------------------------------------------------------------------------

/// Append-only byte encoder (all integers little-endian, floats by bits).
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        Enc { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usize_(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Explicit little-endian IEEE-754 bits: NaN payloads, −0.0 and
    /// subnormals replay exactly.
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn f64s(&mut self, v: &[f64]) {
        self.usize_(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    fn u64s(&mut self, v: &[u64]) {
        self.usize_(v.len());
        for &x in v {
            self.u64(x);
        }
    }

    fn bools(&mut self, v: &[bool]) {
        self.usize_(v.len());
        for &x in v {
            self.bool(x);
        }
    }

    fn str_(&mut self, s: &str) {
        self.usize_(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Cursor decoder over one frame body. Every read is bounds-checked
/// ([`WireError::Truncated`]) and element counts are validated against
/// the remaining bytes *before* any allocation.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn need(&self, n: usize) -> Result<(), WireError> {
        let needed = self
            .pos
            .checked_add(n)
            .ok_or(WireError::Malformed("length overflow"))?;
        if needed > self.buf.len() {
            Err(WireError::Truncated { needed, have: self.buf.len() })
        } else {
            Ok(())
        }
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bool byte is neither 0 nor 1")),
        }
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        self.need(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    fn usize_(&mut self) -> Result<usize, WireError> {
        usize::try_from(self.u64()?).map_err(|_| WireError::Malformed("usize overflow"))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Element count whose payload occupies `elem_size` bytes apiece:
    /// checked against the remaining input before allocating.
    fn count(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let n = self.usize_()?;
        let bytes = n
            .checked_mul(elem_size)
            .ok_or(WireError::Malformed("length overflow"))?;
        self.need(bytes)?;
        Ok(n)
    }

    fn f64s(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn u64s(&mut self) -> Result<Vec<u64>, WireError> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn bools(&mut self) -> Result<Vec<bool>, WireError> {
        let n = self.count(1)?;
        (0..n).map(|_| self.bool()).collect()
    }

    fn str_(&mut self) -> Result<String, WireError> {
        let n = self.count(1)?;
        let bytes = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("invalid utf-8 in string"))
    }

    /// A frame must be consumed exactly: trailing bytes are a framing bug
    /// on the peer, not padding.
    fn finish(&self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed("trailing bytes in frame"))
        }
    }
}

/// Byte sink the canonical dataset encoding can be replayed into: the
/// real encoder, the streaming fingerprint hasher and the exact length
/// counter all consume the *same* `put_dataset` walk, so the three can
/// never disagree about the canonical byte layout (the fingerprint
/// contract `fingerprint == fnv1a64(encoded payload)` is pinned by
/// `dataset_fingerprint_is_content_addressed`).
trait ByteSink {
    fn put_u8(&mut self, v: u8);
    fn put_u64(&mut self, v: u64);
    fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }
    fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }
    fn put_f64s(&mut self, v: &[f64]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_f64(x);
        }
    }
    fn put_u64s(&mut self, v: &[u64]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_u64(x);
        }
    }
}

impl ByteSink for Enc {
    fn put_u8(&mut self, v: u8) {
        self.u8(v);
    }
    fn put_u64(&mut self, v: u64) {
        self.u64(v);
    }
}

/// Streaming FNV-1a sink: fingerprints a dataset without materializing
/// its multi-gigabyte canonical encoding (bit-identical to
/// [`fnv1a64`] over the [`Enc`] bytes by construction — same walk, same
/// byte order).
pub struct FnvHasher {
    h: u64,
}

impl FnvHasher {
    pub fn new() -> Self {
        FnvHasher { h: 0xcbf29ce484222325 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

impl Default for FnvHasher {
    fn default() -> Self {
        Self::new()
    }
}

impl ByteSink for FnvHasher {
    fn put_u8(&mut self, v: u8) {
        self.update(&[v]);
    }
    fn put_u64(&mut self, v: u64) {
        self.update(&v.to_le_bytes());
    }
}

/// Exact encoded-byte counter (the chunked-vs-whole ship decision needs
/// the payload size *before* committing to a potentially huge encode).
struct CountSink {
    n: usize,
}

impl ByteSink for CountSink {
    fn put_u8(&mut self, _: u8) {
        self.n += 1;
    }
    fn put_u64(&mut self, _: u64) {
        self.n += 8;
    }
}

// ---------------------------------------------------------------------------
// Enum tags (stable: the `all()` orders are append-only by convention)
// ---------------------------------------------------------------------------

fn put_rule(e: &mut Enc, r: RuleKind) {
    let tag = RuleKind::all().iter().position(|k| *k == r).expect("rule listed in all()");
    e.u8(tag as u8);
}

fn get_rule(d: &mut Dec) -> Result<RuleKind, WireError> {
    let tag = d.u8()?;
    RuleKind::all()
        .get(tag as usize)
        .copied()
        .ok_or(WireError::Malformed("unknown screening-rule tag"))
}

fn put_solver(e: &mut Enc, s: SolverKind) {
    let tag = SolverKind::all().iter().position(|k| *k == s).expect("solver listed in all()");
    e.u8(tag as u8);
}

fn get_solver(d: &mut Dec) -> Result<SolverKind, WireError> {
    let tag = d.u8()?;
    SolverKind::all()
        .get(tag as usize)
        .copied()
        .ok_or(WireError::Malformed("unknown solver tag"))
}

fn put_sweep(e: &mut Enc, s: SweepMode) {
    let tag = SweepMode::all().iter().position(|k| *k == s).expect("sweep listed in all()");
    e.u8(tag as u8);
}

fn get_sweep(d: &mut Dec) -> Result<SweepMode, WireError> {
    let tag = d.u8()?;
    SweepMode::all()
        .get(tag as usize)
        .copied()
        .ok_or(WireError::Malformed("unknown sweep-mode tag"))
}

// ---------------------------------------------------------------------------
// Solver-type encodings
// ---------------------------------------------------------------------------

fn put_solve_options(e: &mut Enc, o: &SolveOptions) {
    e.f64(o.tol);
    e.usize_(o.max_epochs);
    e.usize_(o.fce);
    put_rule(e, o.rule);
    e.bool(o.record_history);
    put_sweep(e, o.sweep);
    e.usize_(o.sweep_threads);
    // v3: the sweep-tuning knobs travel with the request — cd_floor and
    // groups_per_round shape the parallel-CD trajectory, so a worker must
    // run the coordinator's values, not its own defaults.
    e.usize_(o.tuning.xt_floor);
    e.usize_(o.tuning.residual_floor);
    e.usize_(o.tuning.omega_dual_floor);
    e.usize_(o.tuning.prox_floor);
    e.usize_(o.tuning.cd_floor);
    e.usize_(o.tuning.groups_per_round);
}

fn get_solve_options(d: &mut Dec) -> Result<SolveOptions, WireError> {
    Ok(SolveOptions {
        tol: d.f64()?,
        max_epochs: d.usize_()?,
        fce: d.usize_()?,
        rule: get_rule(d)?,
        record_history: d.bool()?,
        sweep: get_sweep(d)?,
        sweep_threads: d.usize_()?,
        tuning: SweepTuning {
            xt_floor: d.usize_()?,
            residual_floor: d.usize_()?,
            omega_dual_floor: d.usize_()?,
            prox_floor: d.usize_()?,
            cd_floor: d.usize_()?,
            groups_per_round: d.usize_()?,
        },
    })
}

fn put_path_options(e: &mut Enc, o: &PathOptions) {
    e.f64(o.delta);
    e.usize_(o.t_count);
    put_solve_options(e, &o.solve);
}

fn get_path_options(d: &mut Dec) -> Result<PathOptions, WireError> {
    Ok(PathOptions { delta: d.f64()?, t_count: d.usize_()?, solve: get_solve_options(d)? })
}

fn put_snapshot(e: &mut Enc, s: &DualSnapshot) {
    e.f64s(&s.theta);
    e.f64s(&s.xt_theta);
    e.f64(s.dual_norm_xt_rho);
    e.f64(s.theta_aug_sq);
    e.f64(s.primal);
    e.f64(s.dual);
    e.f64(s.gap);
    e.f64(s.radius);
}

fn get_snapshot(d: &mut Dec) -> Result<DualSnapshot, WireError> {
    Ok(DualSnapshot {
        theta: d.f64s()?,
        xt_theta: d.f64s()?,
        dual_norm_xt_rho: d.f64()?,
        theta_aug_sq: d.f64()?,
        primal: d.f64()?,
        dual: d.f64()?,
        gap: d.f64()?,
        radius: d.f64()?,
    })
}

fn put_handoff(e: &mut Enc, h: &DualHandoff) {
    e.f64(h.lambda);
    e.f64s(&h.beta);
    put_snapshot(e, &h.snap);
}

fn get_handoff(d: &mut Dec) -> Result<DualHandoff, WireError> {
    Ok(DualHandoff { lambda: d.f64()?, beta: d.f64s()?, snap: get_snapshot(d)? })
}

fn put_opt_handoff(e: &mut Enc, h: Option<&DualHandoff>) {
    match h {
        None => e.u8(0),
        Some(h) => {
            e.u8(1);
            put_handoff(e, h);
        }
    }
}

fn get_opt_handoff(d: &mut Dec) -> Result<Option<DualHandoff>, WireError> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(get_handoff(d)?)),
        _ => Err(WireError::Malformed("option tag is neither 0 nor 1")),
    }
}

fn put_active(e: &mut Enc, a: &ActiveSet) {
    e.bools(&a.feature);
    e.bools(&a.group);
}

fn get_active(d: &mut Dec) -> Result<ActiveSet, WireError> {
    Ok(ActiveSet { feature: d.bools()?, group: d.bools()? })
}

fn put_check(e: &mut Enc, c: &CheckEvent) {
    e.usize_(c.epoch);
    e.f64(c.gap);
    e.f64(c.radius);
    e.usize_(c.active_features);
    e.usize_(c.active_groups);
    e.f64(c.elapsed_s);
}

fn get_check(d: &mut Dec) -> Result<CheckEvent, WireError> {
    Ok(CheckEvent {
        epoch: d.usize_()?,
        gap: d.f64()?,
        radius: d.f64()?,
        active_features: d.usize_()?,
        active_groups: d.usize_()?,
        elapsed_s: d.f64()?,
    })
}

fn put_solve_result(e: &mut Enc, r: &SolveResult) {
    e.f64s(&r.beta);
    e.f64(r.gap);
    e.usize_(r.epochs);
    e.bool(r.converged);
    e.f64(r.elapsed_s);
    put_active(e, &r.active);
    e.usize_(r.history.len());
    for c in &r.history {
        put_check(e, c);
    }
    e.usize_(r.gap_evals);
}

fn get_solve_result(d: &mut Dec) -> Result<SolveResult, WireError> {
    Ok(SolveResult {
        beta: d.f64s()?,
        gap: d.f64()?,
        epochs: d.usize_()?,
        converged: d.bool()?,
        elapsed_s: d.f64()?,
        active: get_active(d)?,
        history: {
            // A CheckEvent is ≥ 48 bytes on the wire: bound the count
            // against the remaining input before allocating.
            let n = d.count(48)?;
            (0..n).map(|_| get_check(d)).collect::<Result<Vec<_>, _>>()?
        },
        gap_evals: d.usize_()?,
    })
}

fn put_path_result(e: &mut Enc, r: &PathResult) {
    e.f64s(&r.lambdas);
    e.usize_(r.results.len());
    for res in &r.results {
        put_solve_result(e, res);
    }
    e.f64(r.total_s);
}

fn get_path_result(d: &mut Dec) -> Result<PathResult, WireError> {
    Ok(PathResult {
        lambdas: d.f64s()?,
        results: {
            // A SolveResult is ≥ 50 bytes on the wire (conservative).
            let n = d.count(50)?;
            (0..n).map(|_| get_solve_result(d)).collect::<Result<Vec<_>, _>>()?
        },
        total_s: d.f64()?,
    })
}

// ---------------------------------------------------------------------------
// Dataset shipping
// ---------------------------------------------------------------------------

/// The design matrix in transferable form.
#[derive(Clone, Debug)]
pub enum WireDesign {
    /// Column-major dense payload (`data.len() == n_rows · n_cols`).
    Dense { n_rows: usize, n_cols: usize, data: Vec<f64> },
    /// CSC triplets (`indptr.len() == n_cols + 1`, rows strictly
    /// increasing within each column).
    Csc {
        n_rows: usize,
        n_cols: usize,
        indptr: Vec<u64>,
        indices: Vec<u64>,
        values: Vec<f64>,
    },
}

/// The datafit in transferable form. Encodes which loss a problem is fit
/// under plus the loss's own parameters (the quadratic ridge); decode
/// validates the parameters before any problem constructor can `assert!`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WireDatafit {
    /// Least squares, optionally ridge-augmented (`ridge = 0` is plain).
    Quadratic { ridge: f64 },
    /// Binary logistic regression (labels in `[0, 1]`).
    Logistic,
    /// Multi-task least squares over `tasks` response columns (v5). The
    /// dataset's `y` then holds `n_rows · tasks` entries, task-major.
    MultiTask { tasks: u64 },
}

impl WireDatafit {
    /// Snapshot any solver datafit for shipping.
    pub fn of<F: Datafit>(f: &F) -> Self {
        match f.kind() {
            FitKind::Quadratic => WireDatafit::Quadratic { ridge: f.ridge() },
            FitKind::Logistic => WireDatafit::Logistic,
            FitKind::MultiTask => WireDatafit::MultiTask { tasks: f.tasks() as u64 },
        }
    }

    /// Stable lowercase name (matches [`FitKind::name`]).
    pub fn name(&self) -> &'static str {
        match self {
            WireDatafit::Quadratic { .. } => FitKind::Quadratic.name(),
            WireDatafit::Logistic => FitKind::Logistic.name(),
            WireDatafit::MultiTask { .. } => FitKind::MultiTask.name(),
        }
    }

    /// Number of response columns the dataset's `y` must cover per design
    /// row (1 for every scalar datafit).
    pub fn tasks(&self) -> u64 {
        match self {
            WireDatafit::MultiTask { tasks } => *tasks,
            _ => 1,
        }
    }
}

/// A whole problem instance on the wire: design + `y` + group partition
/// + `τ` + weights + datafit. Shipped once per worker and addressed by
/// [`fingerprint`](Self::fingerprint) thereafter.
#[derive(Clone, Debug)]
pub struct WireDataset {
    pub design: WireDesign,
    pub y: Vec<f64>,
    pub group_sizes: Vec<u64>,
    pub tau: f64,
    pub weights: Vec<f64>,
    pub datafit: WireDatafit,
}

/// A problem decoded from a [`WireDataset`], preserving backend and
/// datafit.
#[derive(Clone, Debug)]
pub enum ProblemPayload {
    Dense(SglProblem<Matrix>),
    Csc(SglProblem<CscMatrix>),
    DenseLogistic(SglProblem<Matrix, Logistic>),
    CscLogistic(SglProblem<CscMatrix, Logistic>),
    DenseMultiTask(SglProblem<Matrix, MultiTaskQuadratic>),
    CscMultiTask(SglProblem<CscMatrix, MultiTaskQuadratic>),
}

impl WireDataset {
    /// Snapshot a dense problem (any datafit) for shipping.
    pub fn from_dense<F: Datafit>(pb: &SglProblem<Matrix, F>) -> Self {
        WireDataset {
            design: WireDesign::Dense {
                n_rows: pb.x.n_rows(),
                n_cols: pb.x.n_cols(),
                data: pb.x.as_slice().to_vec(),
            },
            y: pb.y.clone(),
            group_sizes: (0..pb.groups.n_groups()).map(|g| pb.groups.size(g) as u64).collect(),
            tau: pb.tau,
            weights: pb.weights.clone(),
            datafit: WireDatafit::of(&pb.datafit),
        }
    }

    /// Snapshot a CSC problem (any datafit) for shipping (triplet form,
    /// no dense detour).
    pub fn from_csc<F: Datafit>(pb: &SglProblem<CscMatrix, F>) -> Self {
        WireDataset {
            design: WireDesign::Csc {
                n_rows: pb.x.n_rows(),
                n_cols: pb.x.n_cols(),
                indptr: pb.x.indptr().iter().map(|&v| v as u64).collect(),
                indices: pb.x.row_indices().iter().map(|&v| v as u64).collect(),
                values: pb.x.values().to_vec(),
            },
            y: pb.y.clone(),
            group_sizes: (0..pb.groups.n_groups()).map(|g| pb.groups.size(g) as u64).collect(),
            tau: pb.tau,
            weights: pb.weights.clone(),
            datafit: WireDatafit::of(&pb.datafit),
        }
    }

    /// 64-bit FNV-1a digest of the canonical encoding. Floats hash by
    /// bit pattern, so two datasets share a fingerprint iff they are
    /// bit-identical — the address a fleet uses after shipping once.
    /// Streamed through [`FnvHasher`], so no byte of the (potentially
    /// multi-gigabyte) encoding is ever materialized.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FnvHasher::new();
        put_dataset(&mut h, self);
        h.finish()
    }

    /// Exact byte length of the canonical payload encoding (what a
    /// [`Message::ShipDataset`] frame's body would occupy past the
    /// version/tag header) — the chunked-vs-whole ship decision, costed
    /// without encoding anything.
    pub fn wire_len(&self) -> usize {
        let mut c = CountSink { n: 0 };
        put_dataset(&mut c, self);
        c.n
    }

    /// Split into a chunked ship: the [`ChunkBegin`] header (fingerprint,
    /// shape, every non-design field) plus column-range [`ChunkPart`]s
    /// whose design payload stays within `budget` bytes apiece. Every
    /// chunk carries at least one column, so a single column wider than
    /// the budget still ships (as an oversized singleton chunk); callers
    /// pick budgets far enough under [`MAX_FRAME`] that this cannot
    /// overflow a frame for any realistic row count.
    pub fn to_chunks(&self, budget: usize) -> (ChunkBegin, Vec<ChunkPart>) {
        let fingerprint = self.fingerprint();
        let (csc, n_rows, n_cols) = match &self.design {
            WireDesign::Dense { n_rows, n_cols, .. } => (false, *n_rows, *n_cols),
            WireDesign::Csc { n_rows, n_cols, .. } => (true, *n_rows, *n_cols),
        };
        let begin = ChunkBegin {
            fingerprint,
            csc,
            n_rows,
            n_cols,
            y: self.y.clone(),
            group_sizes: self.group_sizes.clone(),
            tau: self.tau,
            weights: self.weights.clone(),
            datafit: self.datafit,
        };
        // Per-column payload cost: dense columns are n_rows values; CSC
        // columns are their nnz (index + value) plus one indptr entry.
        let col_bytes = |j: usize| -> usize {
            match &self.design {
                WireDesign::Dense { n_rows, .. } => n_rows * 8,
                WireDesign::Csc { indptr, .. } => {
                    (indptr[j + 1] - indptr[j]) as usize * 16 + 8
                }
            }
        };
        let mut chunks = Vec::new();
        let mut start = 0;
        while start < n_cols {
            let mut end = start + 1;
            let mut bytes = col_bytes(start);
            while end < n_cols && bytes + col_bytes(end) <= budget {
                bytes += col_bytes(end);
                end += 1;
            }
            let payload = match &self.design {
                WireDesign::Dense { n_rows, data, .. } => ChunkPayload::Dense {
                    data: data[start * n_rows..end * n_rows].to_vec(),
                },
                WireDesign::Csc { indptr, indices, values, .. } => {
                    let (lo, hi) = (indptr[start] as usize, indptr[end] as usize);
                    ChunkPayload::Csc {
                        indptr: indptr[start..=end].to_vec(),
                        indices: indices[lo..hi].to_vec(),
                        values: values[lo..hi].to_vec(),
                    }
                }
            };
            chunks.push(ChunkPart { fingerprint, col_start: start, col_end: end, payload });
            start = end;
        }
        (begin, chunks)
    }

    pub fn backend_name(&self) -> &'static str {
        match self.design {
            WireDesign::Dense { .. } => "dense",
            WireDesign::Csc { .. } => "csc",
        }
    }

    /// Reconstruct the problem, re-running the deterministic
    /// precomputations (column norms, spectral norms, `λ_max`) on the
    /// receiving side — same input bits, same algorithm, same results.
    /// Every structural invariant the problem constructors `assert!` is
    /// validated here first, so malformed wire data is a typed
    /// [`WireError::Malformed`], never a worker panic.
    pub fn into_problem(self) -> Result<ProblemPayload, WireError> {
        let WireDataset { design, y, group_sizes, tau, weights, datafit } = self;
        if group_sizes.is_empty() {
            return Err(WireError::Malformed("dataset has no groups"));
        }
        // Datafit parameters are validated first; `tasks` is the number
        // of y columns each design row must cover (1 for scalar fits).
        let tasks: usize = match datafit {
            WireDatafit::Quadratic { ridge } => {
                if !(ridge.is_finite() && ridge >= 0.0) {
                    return Err(WireError::Malformed("ridge must be finite and non-negative"));
                }
                1
            }
            WireDatafit::Logistic => {
                if !y.iter().all(|v| v.is_finite() && (0.0..=1.0).contains(v)) {
                    return Err(WireError::Malformed("logistic labels must lie in [0, 1]"));
                }
                1
            }
            WireDatafit::MultiTask { tasks } => {
                if tasks == 0 {
                    return Err(WireError::Malformed(
                        "multi-task datafit needs at least one task",
                    ));
                }
                usize::try_from(tasks).map_err(|_| WireError::Malformed("usize overflow"))?
            }
        };
        let mut sizes = Vec::with_capacity(group_sizes.len());
        let mut p: usize = 0;
        for &s in &group_sizes {
            let s = usize::try_from(s).map_err(|_| WireError::Malformed("usize overflow"))?;
            if s == 0 {
                return Err(WireError::Malformed("empty group in dataset"));
            }
            p = p.checked_add(s).ok_or(WireError::Malformed("group sizes overflow"))?;
            sizes.push(s);
        }
        if weights.len() != sizes.len() {
            return Err(WireError::Malformed("weights/groups length mismatch"));
        }
        if !(0.0..=1.0).contains(&tau) {
            return Err(WireError::Malformed("tau outside [0, 1]"));
        }
        if tau == 0.0 && !weights.iter().all(|&w| w > 0.0) {
            return Err(WireError::Malformed("tau = 0 requires positive weights"));
        }
        match design {
            WireDesign::Dense { n_rows, n_cols, data } => {
                if n_cols != p {
                    return Err(WireError::Malformed("groups do not cover the design columns"));
                }
                let y_len = n_rows
                    .checked_mul(tasks)
                    .ok_or(WireError::Malformed("y length overflow"))?;
                if y.len() != y_len {
                    return Err(WireError::Malformed("y/design row mismatch"));
                }
                let total = n_rows
                    .checked_mul(n_cols)
                    .ok_or(WireError::Malformed("dense design too large"))?;
                if data.len() != total {
                    return Err(WireError::Malformed("dense payload size mismatch"));
                }
                let x = Matrix::from_col_major(data, n_rows, n_cols);
                let groups = Groups::from_sizes(&sizes);
                Ok(match datafit {
                    WireDatafit::Quadratic { ridge } => {
                        ProblemPayload::Dense(SglProblem::with_datafit(
                            x,
                            y,
                            groups,
                            tau,
                            weights,
                            Quadratic::with_ridge(ridge),
                        ))
                    }
                    WireDatafit::Logistic => ProblemPayload::DenseLogistic(
                        SglProblem::with_datafit(x, y, groups, tau, weights, Logistic),
                    ),
                    WireDatafit::MultiTask { .. } => {
                        ProblemPayload::DenseMultiTask(SglProblem::with_datafit(
                            x,
                            y,
                            groups,
                            tau,
                            weights,
                            MultiTaskQuadratic::new(tasks),
                        ))
                    }
                })
            }
            WireDesign::Csc { n_rows, n_cols, indptr, indices, values } => {
                if n_cols != p {
                    return Err(WireError::Malformed("groups do not cover the design columns"));
                }
                let y_len = n_rows
                    .checked_mul(tasks)
                    .ok_or(WireError::Malformed("y length overflow"))?;
                if y.len() != y_len {
                    return Err(WireError::Malformed("y/design row mismatch"));
                }
                if indptr.len() != n_cols + 1 {
                    return Err(WireError::Malformed("csc indptr length mismatch"));
                }
                if indices.len() != values.len() {
                    return Err(WireError::Malformed("csc indices/values length mismatch"));
                }
                if indptr.first() != Some(&0)
                    || *indptr.last().expect("indptr non-empty") != indices.len() as u64
                {
                    return Err(WireError::Malformed("csc indptr endpoints mismatch"));
                }
                let mut iptr = Vec::with_capacity(indptr.len());
                for w in indptr.windows(2) {
                    if w[1] < w[0] {
                        return Err(WireError::Malformed("csc indptr must be non-decreasing"));
                    }
                }
                for &v in &indptr {
                    iptr.push(
                        usize::try_from(v).map_err(|_| WireError::Malformed("usize overflow"))?,
                    );
                }
                let mut rows = Vec::with_capacity(indices.len());
                for &v in &indices {
                    let i =
                        usize::try_from(v).map_err(|_| WireError::Malformed("usize overflow"))?;
                    if i >= n_rows {
                        return Err(WireError::Malformed("csc row index out of bounds"));
                    }
                    rows.push(i);
                }
                // Strictly increasing rows within each column: the sparse
                // kernels binary-search row windows, so this invariant
                // must hold on arrival, not by trust.
                for j in 0..n_cols {
                    let col = &rows[iptr[j]..iptr[j + 1]];
                    for w in col.windows(2) {
                        if w[1] <= w[0] {
                            return Err(WireError::Malformed(
                                "csc rows must be strictly increasing within a column",
                            ));
                        }
                    }
                }
                let x = CscMatrix::from_raw(n_rows, n_cols, iptr, rows, values);
                let groups = Groups::from_sizes(&sizes);
                Ok(match datafit {
                    WireDatafit::Quadratic { ridge } => {
                        ProblemPayload::Csc(SglProblem::with_datafit(
                            x,
                            y,
                            groups,
                            tau,
                            weights,
                            Quadratic::with_ridge(ridge),
                        ))
                    }
                    WireDatafit::Logistic => ProblemPayload::CscLogistic(
                        SglProblem::with_datafit(x, y, groups, tau, weights, Logistic),
                    ),
                    WireDatafit::MultiTask { .. } => {
                        ProblemPayload::CscMultiTask(SglProblem::with_datafit(
                            x,
                            y,
                            groups,
                            tau,
                            weights,
                            MultiTaskQuadratic::new(tasks),
                        ))
                    }
                })
            }
        }
    }
}

fn put_datafit<S: ByteSink>(e: &mut S, f: &WireDatafit) {
    match f {
        WireDatafit::Quadratic { ridge } => {
            e.put_u8(0);
            e.put_f64(*ridge);
        }
        WireDatafit::Logistic => e.put_u8(1),
        WireDatafit::MultiTask { tasks } => {
            e.put_u8(2);
            e.put_u64(*tasks);
        }
    }
}

fn get_datafit(d: &mut Dec) -> Result<WireDatafit, WireError> {
    Ok(match d.u8()? {
        0 => WireDatafit::Quadratic { ridge: d.f64()? },
        1 => WireDatafit::Logistic,
        2 => WireDatafit::MultiTask { tasks: d.u64()? },
        _ => return Err(WireError::Malformed("unknown datafit tag")),
    })
}

fn put_dataset<S: ByteSink>(e: &mut S, ds: &WireDataset) {
    match &ds.design {
        WireDesign::Dense { n_rows, n_cols, data } => {
            e.put_u8(0);
            e.put_usize(*n_rows);
            e.put_usize(*n_cols);
            e.put_f64s(data);
        }
        WireDesign::Csc { n_rows, n_cols, indptr, indices, values } => {
            e.put_u8(1);
            e.put_usize(*n_rows);
            e.put_usize(*n_cols);
            e.put_u64s(indptr);
            e.put_u64s(indices);
            e.put_f64s(values);
        }
    }
    e.put_f64s(&ds.y);
    e.put_u64s(&ds.group_sizes);
    e.put_f64(ds.tau);
    e.put_f64s(&ds.weights);
    put_datafit(e, &ds.datafit);
}

fn get_dataset(d: &mut Dec) -> Result<WireDataset, WireError> {
    let design = match d.u8()? {
        0 => WireDesign::Dense { n_rows: d.usize_()?, n_cols: d.usize_()?, data: d.f64s()? },
        1 => WireDesign::Csc {
            n_rows: d.usize_()?,
            n_cols: d.usize_()?,
            indptr: d.u64s()?,
            indices: d.u64s()?,
            values: d.f64s()?,
        },
        _ => return Err(WireError::Malformed("unknown design tag")),
    };
    Ok(WireDataset {
        design,
        y: d.f64s()?,
        group_sizes: d.u64s()?,
        tau: d.f64()?,
        weights: d.f64s()?,
        datafit: get_datafit(d)?,
    })
}

// ---------------------------------------------------------------------------
// Chunked dataset streaming (v6)
// ---------------------------------------------------------------------------

/// Opening frame of a chunked dataset ship (v6): the declared content
/// fingerprint, the design's kind and shape, and every non-design field.
/// The design payload follows as column-range [`ChunkPart`]s and the
/// ship is sealed by [`Message::ShipEnd`]; only the seal is acknowledged,
/// so a multi-chunk transfer costs one round trip, same as a whole-frame
/// ship.
#[derive(Clone, Debug)]
pub struct ChunkBegin {
    /// [`WireDataset::fingerprint`] of the assembled dataset — verified
    /// against the assembly on [`ChunkAssembler::finish`], so a dropped
    /// or corrupted chunk can never be stored as the real dataset.
    pub fingerprint: u64,
    /// `true` for a CSC design, `false` for column-major dense.
    pub csc: bool,
    pub n_rows: usize,
    pub n_cols: usize,
    pub y: Vec<f64>,
    pub group_sizes: Vec<u64>,
    pub tau: f64,
    pub weights: Vec<f64>,
    pub datafit: WireDatafit,
}

/// One column range of a chunked ship (v6). Ranges must arrive in order
/// and contiguously — the assembler rejects gaps, overlaps, duplicates
/// and out-of-order ranges with typed [`WireError::Malformed`]s.
#[derive(Clone, Debug)]
pub struct ChunkPart {
    /// Echoes [`ChunkBegin::fingerprint`] so an interleaved or stale
    /// chunk can never splice into the wrong ship.
    pub fingerprint: u64,
    /// First design column this chunk carries.
    pub col_start: usize,
    /// One past the last design column this chunk carries.
    pub col_end: usize,
    pub payload: ChunkPayload,
}

/// The design slice inside one [`ChunkPart`].
#[derive(Clone, Debug)]
pub enum ChunkPayload {
    /// Column-major dense values: `n_rows · (col_end − col_start)`.
    Dense { data: Vec<f64> },
    /// The *absolute* `indptr[col_start ..= col_end]` slice of the full
    /// matrix plus the row indices/values those columns own — absolute
    /// offsets make every chunk self-describing and let the assembler
    /// verify continuity instead of trusting it.
    Csc { indptr: Vec<u64>, indices: Vec<u64>, values: Vec<f64> },
}

/// Worker-side reassembly of a chunked ship: feed [`ChunkBegin`] to
/// [`new`](Self::new), each [`ChunkPart`] to [`chunk`](Self::chunk), and
/// seal with [`finish`](Self::finish), which verifies full column
/// coverage *and* that the assembly hashes to the declared fingerprint.
/// Pure (no sockets), so protocol fuzzers drive it directly.
pub struct ChunkAssembler {
    begin: ChunkBegin,
    next_col: usize,
    dense: Vec<f64>,
    indptr: Vec<u64>,
    indices: Vec<u64>,
    values: Vec<f64>,
}

impl ChunkAssembler {
    pub fn new(begin: ChunkBegin) -> Result<Self, WireError> {
        if !begin.csc {
            begin
                .n_rows
                .checked_mul(begin.n_cols)
                .ok_or(WireError::Malformed("chunked dense design too large"))?;
        }
        let indptr = if begin.csc { vec![0] } else { Vec::new() };
        Ok(ChunkAssembler {
            begin,
            next_col: 0,
            dense: Vec::new(),
            indptr,
            indices: Vec::new(),
            values: Vec::new(),
        })
    }

    /// The fingerprint this assembly was opened for.
    pub fn fingerprint(&self) -> u64 {
        self.begin.fingerprint
    }

    pub fn chunk(&mut self, part: ChunkPart) -> Result<(), WireError> {
        if part.fingerprint != self.begin.fingerprint {
            return Err(WireError::Malformed("chunk fingerprint does not match the open ship"));
        }
        if part.col_start < self.next_col {
            return Err(WireError::Malformed(
                "chunk column range duplicates or overlaps delivered columns",
            ));
        }
        if part.col_start > self.next_col {
            return Err(WireError::Malformed(
                "chunk column range is out of order or leaves a gap",
            ));
        }
        if part.col_end <= part.col_start || part.col_end > self.begin.n_cols {
            return Err(WireError::Malformed("chunk column range is empty or out of bounds"));
        }
        let cols = part.col_end - part.col_start;
        match (self.begin.csc, part.payload) {
            (false, ChunkPayload::Dense { data }) => {
                let want = self
                    .begin
                    .n_rows
                    .checked_mul(cols)
                    .ok_or(WireError::Malformed("chunk payload size overflow"))?;
                if data.len() != want {
                    return Err(WireError::Malformed("dense chunk payload size mismatch"));
                }
                self.dense.extend_from_slice(&data);
            }
            (true, ChunkPayload::Csc { indptr, indices, values }) => {
                if indptr.len() != cols + 1 {
                    return Err(WireError::Malformed("csc chunk indptr length mismatch"));
                }
                // Absolute continuity: the chunk's first offset must be
                // exactly where the previous chunk left off.
                if indptr[0] != *self.indptr.last().expect("assembler indptr seeded") {
                    return Err(WireError::Malformed(
                        "csc chunk indptr does not continue the previous chunk",
                    ));
                }
                for w in indptr.windows(2) {
                    if w[1] < w[0] {
                        return Err(WireError::Malformed(
                            "csc chunk indptr must be non-decreasing",
                        ));
                    }
                }
                let nnz = (indptr[cols] - indptr[0]) as usize;
                if indices.len() != nnz || values.len() != nnz {
                    return Err(WireError::Malformed("csc chunk payload size mismatch"));
                }
                self.indptr.extend_from_slice(&indptr[1..]);
                self.indices.extend_from_slice(&indices);
                self.values.extend_from_slice(&values);
            }
            _ => {
                return Err(WireError::Malformed(
                    "chunk payload kind does not match the declared design",
                ))
            }
        }
        self.next_col = part.col_end;
        Ok(())
    }

    /// Seal the ship: every column must be covered and the assembled
    /// dataset must hash to the declared fingerprint (streamed, no
    /// second encode). The caller still runs
    /// [`WireDataset::into_problem`] for structural validation.
    pub fn finish(self, end_fingerprint: u64) -> Result<WireDataset, WireError> {
        if end_fingerprint != self.begin.fingerprint {
            return Err(WireError::Malformed("ship-end fingerprint does not match the open ship"));
        }
        if self.next_col != self.begin.n_cols {
            return Err(WireError::Malformed("chunked ship ended before covering every column"));
        }
        let ChunkAssembler { begin, dense, indptr, indices, values, .. } = self;
        let design = if begin.csc {
            WireDesign::Csc {
                n_rows: begin.n_rows,
                n_cols: begin.n_cols,
                indptr,
                indices,
                values,
            }
        } else {
            WireDesign::Dense { n_rows: begin.n_rows, n_cols: begin.n_cols, data: dense }
        };
        let ds = WireDataset {
            design,
            y: begin.y,
            group_sizes: begin.group_sizes,
            tau: begin.tau,
            weights: begin.weights,
            datafit: begin.datafit,
        };
        if ds.fingerprint() != begin.fingerprint {
            return Err(WireError::Malformed(
                "assembled dataset does not hash to the declared fingerprint",
            ));
        }
        Ok(ds)
    }
}

fn put_chunk_begin(e: &mut Enc, b: &ChunkBegin) {
    e.u64(b.fingerprint);
    e.bool(b.csc);
    e.usize_(b.n_rows);
    e.usize_(b.n_cols);
    e.f64s(&b.y);
    e.u64s(&b.group_sizes);
    e.f64(b.tau);
    e.f64s(&b.weights);
    put_datafit(e, &b.datafit);
}

fn get_chunk_begin(d: &mut Dec) -> Result<ChunkBegin, WireError> {
    Ok(ChunkBegin {
        fingerprint: d.u64()?,
        csc: d.bool()?,
        n_rows: d.usize_()?,
        n_cols: d.usize_()?,
        y: d.f64s()?,
        group_sizes: d.u64s()?,
        tau: d.f64()?,
        weights: d.f64s()?,
        datafit: get_datafit(d)?,
    })
}

fn put_chunk_part(e: &mut Enc, c: &ChunkPart) {
    e.u64(c.fingerprint);
    e.usize_(c.col_start);
    e.usize_(c.col_end);
    match &c.payload {
        ChunkPayload::Dense { data } => {
            e.u8(0);
            e.f64s(data);
        }
        ChunkPayload::Csc { indptr, indices, values } => {
            e.u8(1);
            e.u64s(indptr);
            e.u64s(indices);
            e.f64s(values);
        }
    }
}

fn get_chunk_part(d: &mut Dec) -> Result<ChunkPart, WireError> {
    let fingerprint = d.u64()?;
    let col_start = d.usize_()?;
    let col_end = d.usize_()?;
    let payload = match d.u8()? {
        0 => ChunkPayload::Dense { data: d.f64s()? },
        1 => ChunkPayload::Csc { indptr: d.u64s()?, indices: d.u64s()?, values: d.f64s()? },
        _ => return Err(WireError::Malformed("unknown chunk payload tag")),
    };
    Ok(ChunkPart { fingerprint, col_start, col_end, payload })
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// One λ-range shard assignment: a [`SolveRequest`] restricted to an
/// explicit grid slice, addressing its dataset by fingerprint and
/// carrying the predecessor shard's [`DualHandoff`] (if any) so the
/// remote rule replays it at epoch 0, exactly like a local resume.
///
/// [`SolveRequest`]: crate::coordinator::service::SolveRequest
#[derive(Clone, Debug)]
pub struct ShardRequest {
    /// [`WireDataset::fingerprint`] of a previously shipped dataset.
    pub dataset: u64,
    /// Datafit the shard must be solved under. Redundant with the
    /// dataset's own datafit *by construction*, and verified against it
    /// by the worker ([`RemoteErrorKind::BadRequest`] on mismatch): a
    /// request can never silently solve a classification shard as a
    /// regression because a fingerprint collided or a store was stale.
    pub datafit: WireDatafit,
    /// The shard's explicit non-increasing λ grid.
    pub lambdas: Vec<f64>,
    pub solver: SolverKind,
    pub opts: PathOptions,
    /// Terminal state of the predecessor shard, `None` for a path head.
    pub handoff: Option<DualHandoff>,
}

/// Why a remote worker rejected or failed a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemoteErrorKind {
    /// The referenced dataset fingerprint has not been shipped to this
    /// worker (e.g. it restarted): reship and retry.
    UnknownDataset,
    /// The solve itself panicked (degenerate grid, shape mismatch, ...).
    SolveFailed,
    /// The request was structurally invalid for this worker.
    BadRequest,
}

impl RemoteErrorKind {
    fn tag(self) -> u8 {
        match self {
            RemoteErrorKind::UnknownDataset => 0,
            RemoteErrorKind::SolveFailed => 1,
            RemoteErrorKind::BadRequest => 2,
        }
    }

    fn from_tag(t: u8) -> Result<Self, WireError> {
        Ok(match t {
            0 => RemoteErrorKind::UnknownDataset,
            1 => RemoteErrorKind::SolveFailed,
            2 => RemoteErrorKind::BadRequest,
            _ => return Err(WireError::Malformed("unknown error kind tag")),
        })
    }
}

/// Compact liveness context a worker piggybacks on every
/// [`Pong`](Message::Pong) (v4) and pushes as unsolicited
/// [`Progress`](Message::Progress) frames mid-solve (v6): enough for a
/// coordinator's heartbeat line to show what the worker is doing
/// without a full stats scrape, and enough for the liveness policy to
/// tell "still converging" from "lost power".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Shards currently being solved on the worker.
    pub in_flight: u64,
    /// λ-shard solves completed since the worker started.
    pub solves: u64,
    /// Seconds (whole) since the worker started listening.
    pub uptime_ticks: u64,
    /// Epochs completed on the most recently checked in-flight λ (v6);
    /// 0 when idle.
    pub epoch: u64,
    /// Duality gap at the last gap check of the in-flight λ, as IEEE-754
    /// bits (v6) — bits rather than `f64` keep the summary `Eq` and the
    /// frame bit-exact. NaN bits mean "no gap observed yet".
    pub gap_bits: u64,
}

impl Default for WorkerSummary {
    fn default() -> Self {
        WorkerSummary {
            in_flight: 0,
            solves: 0,
            uptime_ticks: 0,
            epoch: 0,
            // NaN, not 0.0: a zero default would read as "converged".
            gap_bits: f64::NAN.to_bits(),
        }
    }
}

impl WorkerSummary {
    /// The last observed duality gap (NaN when none was observed).
    pub fn gap(&self) -> f64 {
        f64::from_bits(self.gap_bits)
    }
}

fn put_worker_summary(e: &mut Enc, s: &WorkerSummary) {
    e.u64(s.in_flight);
    e.u64(s.solves);
    e.u64(s.uptime_ticks);
    e.u64(s.epoch);
    e.u64(s.gap_bits);
}

fn get_worker_summary(d: &mut Dec) -> Result<WorkerSummary, WireError> {
    Ok(WorkerSummary {
        in_flight: d.u64()?,
        solves: d.u64()?,
        uptime_ticks: d.u64()?,
        epoch: d.u64()?,
        gap_bits: d.u64()?,
    })
}

fn put_timer_stats(e: &mut Enc, t: &TimerStats) {
    e.u64(t.count);
    e.f64(t.sum);
    e.f64(t.min);
    e.f64(t.max);
}

fn get_timer_stats(d: &mut Dec) -> Result<TimerStats, WireError> {
    Ok(TimerStats { count: d.u64()?, sum: d.f64()?, min: d.f64()?, max: d.f64()? })
}

fn put_metrics_snapshot(e: &mut Enc, s: &MetricsSnapshot) {
    e.usize_(s.counters.len());
    for (k, v) in &s.counters {
        e.str_(k);
        e.u64(*v);
    }
    e.usize_(s.gauges.len());
    for (k, v) in &s.gauges {
        e.str_(k);
        e.f64(*v);
    }
    e.usize_(s.timers.len());
    for (k, stats, sparse) in &s.timers {
        e.str_(k);
        put_timer_stats(e, stats);
        e.usize_(sparse.len());
        for &(i, c) in sparse {
            e.u64(i);
            e.u64(c);
        }
    }
}

fn get_metrics_snapshot(d: &mut Dec) -> Result<MetricsSnapshot, WireError> {
    // A counter/gauge entry is ≥ 16 wire bytes (8-byte name length +
    // 8-byte value), a timer ≥ 48: bound every count against the
    // remaining input before allocating.
    let n = d.count(16)?;
    let mut counters = Vec::with_capacity(n);
    for _ in 0..n {
        counters.push((d.str_()?, d.u64()?));
    }
    let n = d.count(16)?;
    let mut gauges = Vec::with_capacity(n);
    for _ in 0..n {
        gauges.push((d.str_()?, d.f64()?));
    }
    let n = d.count(48)?;
    let mut timers = Vec::with_capacity(n);
    for _ in 0..n {
        let name = d.str_()?;
        let stats = get_timer_stats(d)?;
        let m = d.count(16)?;
        let mut sparse = Vec::with_capacity(m);
        for _ in 0..m {
            sparse.push((d.u64()?, d.u64()?));
        }
        timers.push((name, stats, sparse));
    }
    Ok(MetricsSnapshot { counters, gauges, timers })
}

/// Typed error frame a worker sends instead of closing the socket.
#[derive(Clone, Debug)]
pub struct RemoteError {
    pub kind: RemoteErrorKind,
    pub detail: String,
}

impl fmt::Display for RemoteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}: {}", self.kind, self.detail)
    }
}

/// Every frame the λ-shard serving protocol speaks. The coordinator
/// writes requests, the worker answers each with exactly one reply
/// frame ([`Pong`](Message::Pong), [`DatasetKnown`](Message::DatasetKnown),
/// [`ShardDone`](Message::ShardDone),
/// [`StatsReply`](Message::StatsReply) or [`Error`](Message::Error)).
//
// The payload variants dwarf the heartbeat ones by design; messages are
// built, encoded and dropped in one motion, so boxing them would only
// add indirection on the hot shipping path.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
pub enum Message {
    /// Heartbeat probe (echoed back as [`Pong`](Message::Pong)).
    Ping { seq: u64 },
    /// Heartbeat echo, carrying the worker's [`WorkerSummary`] (v4).
    Pong { seq: u64, summary: WorkerSummary },
    /// Does the worker hold this dataset?
    HasDataset { fingerprint: u64 },
    DatasetKnown { fingerprint: u64, known: bool },
    /// Ship a dataset; acknowledged with `DatasetKnown { known: true }`.
    ShipDataset(WireDataset),
    /// Solve one λ-range shard (see [`ShardRequest`]).
    SolveShard(ShardRequest),
    /// Successful shard outcome plus the outgoing handoff.
    ShardDone { result: PathResult, handoff: Option<DualHandoff> },
    /// Typed failure reply.
    Error(RemoteError),
    /// Scrape the worker's whole metrics registry (v4); answered with
    /// [`StatsReply`](Message::StatsReply).
    StatsRequest,
    /// The worker's registry snapshot — absolute totals, so a
    /// coordinator merge overwrites rather than accumulates.
    StatsReply(MetricsSnapshot),
    /// A worker announcing itself to the coordinator's registration
    /// listener (v6): `addr` is the address the worker *serves* on (its
    /// own listen socket, not the ephemeral registration connection).
    /// Answered with [`Registered`](Message::Registered).
    Register { addr: String },
    /// Registration ack (v6); `worker` is the coordinator-side slot
    /// index, returned for log lines only.
    Registered { worker: u64 },
    /// Unsolicited mid-solve liveness push (v6): a worker streams these
    /// on its solve connection while a shard runs, so the coordinator
    /// can requeue shards whose worker went silent without ever putting
    /// a deadline on legitimate long solves. Never a reply — the real
    /// reply frame follows once the solve ends.
    Progress { summary: WorkerSummary },
    /// Open a chunked dataset ship (v6). Not acknowledged; the single
    /// ack comes after [`ShipEnd`](Message::ShipEnd).
    ShipBegin(ChunkBegin),
    /// One column range of an open chunked ship (v6). Not acknowledged.
    ShipChunk(ChunkPart),
    /// Seal a chunked ship (v6); acknowledged with
    /// `DatasetKnown { known: true }` once the assembly verifies against
    /// the declared fingerprint.
    ShipEnd { fingerprint: u64 },
}

const TAG_PING: u8 = 1;
const TAG_PONG: u8 = 2;
const TAG_HAS_DATASET: u8 = 3;
const TAG_DATASET_KNOWN: u8 = 4;
const TAG_SHIP_DATASET: u8 = 5;
const TAG_SOLVE_SHARD: u8 = 6;
const TAG_SHARD_DONE: u8 = 7;
const TAG_ERROR: u8 = 8;
const TAG_STATS_REQUEST: u8 = 9;
const TAG_STATS_REPLY: u8 = 10;
const TAG_REGISTER: u8 = 11;
const TAG_REGISTERED: u8 = 12;
const TAG_PROGRESS: u8 = 13;
const TAG_SHIP_BEGIN: u8 = 14;
const TAG_SHIP_CHUNK: u8 = 15;
const TAG_SHIP_END: u8 = 16;

impl Message {
    fn tag(&self) -> u8 {
        match self {
            Message::Ping { .. } => TAG_PING,
            Message::Pong { .. } => TAG_PONG,
            Message::HasDataset { .. } => TAG_HAS_DATASET,
            Message::DatasetKnown { .. } => TAG_DATASET_KNOWN,
            Message::ShipDataset(_) => TAG_SHIP_DATASET,
            Message::SolveShard(_) => TAG_SOLVE_SHARD,
            Message::ShardDone { .. } => TAG_SHARD_DONE,
            Message::Error(_) => TAG_ERROR,
            Message::StatsRequest => TAG_STATS_REQUEST,
            Message::StatsReply(_) => TAG_STATS_REPLY,
            Message::Register { .. } => TAG_REGISTER,
            Message::Registered { .. } => TAG_REGISTERED,
            Message::Progress { .. } => TAG_PROGRESS,
            Message::ShipBegin(_) => TAG_SHIP_BEGIN,
            Message::ShipChunk(_) => TAG_SHIP_CHUNK,
            Message::ShipEnd { .. } => TAG_SHIP_END,
        }
    }

    fn put_body(&self, e: &mut Enc) {
        match self {
            Message::Ping { seq } => e.u64(*seq),
            Message::Pong { seq, summary } => {
                e.u64(*seq);
                put_worker_summary(e, summary);
            }
            Message::HasDataset { fingerprint } => e.u64(*fingerprint),
            Message::DatasetKnown { fingerprint, known } => {
                e.u64(*fingerprint);
                e.bool(*known);
            }
            Message::ShipDataset(ds) => put_dataset(e, ds),
            Message::SolveShard(req) => {
                e.u64(req.dataset);
                put_datafit(e, &req.datafit);
                e.f64s(&req.lambdas);
                put_solver(e, req.solver);
                put_path_options(e, &req.opts);
                put_opt_handoff(e, req.handoff.as_ref());
            }
            Message::ShardDone { result, handoff } => {
                put_path_result(e, result);
                put_opt_handoff(e, handoff.as_ref());
            }
            Message::Error(err) => {
                e.u8(err.kind.tag());
                e.str_(&err.detail);
            }
            Message::StatsRequest => {}
            Message::StatsReply(snap) => put_metrics_snapshot(e, snap),
            Message::Register { addr } => e.str_(addr),
            Message::Registered { worker } => e.u64(*worker),
            Message::Progress { summary } => put_worker_summary(e, summary),
            Message::ShipBegin(b) => put_chunk_begin(e, b),
            Message::ShipChunk(c) => put_chunk_part(e, c),
            Message::ShipEnd { fingerprint } => e.u64(*fingerprint),
        }
    }

    fn get_body(tag: u8, d: &mut Dec) -> Result<Message, WireError> {
        Ok(match tag {
            TAG_PING => Message::Ping { seq: d.u64()? },
            TAG_PONG => Message::Pong { seq: d.u64()?, summary: get_worker_summary(d)? },
            TAG_HAS_DATASET => Message::HasDataset { fingerprint: d.u64()? },
            TAG_DATASET_KNOWN => {
                Message::DatasetKnown { fingerprint: d.u64()?, known: d.bool()? }
            }
            TAG_SHIP_DATASET => Message::ShipDataset(get_dataset(d)?),
            TAG_SOLVE_SHARD => Message::SolveShard(ShardRequest {
                dataset: d.u64()?,
                datafit: get_datafit(d)?,
                lambdas: d.f64s()?,
                solver: get_solver(d)?,
                opts: get_path_options(d)?,
                handoff: get_opt_handoff(d)?,
            }),
            TAG_SHARD_DONE => Message::ShardDone {
                result: get_path_result(d)?,
                handoff: get_opt_handoff(d)?,
            },
            TAG_ERROR => Message::Error(RemoteError {
                kind: RemoteErrorKind::from_tag(d.u8()?)?,
                detail: d.str_()?,
            }),
            TAG_STATS_REQUEST => Message::StatsRequest,
            TAG_STATS_REPLY => Message::StatsReply(get_metrics_snapshot(d)?),
            TAG_REGISTER => Message::Register { addr: d.str_()? },
            TAG_REGISTERED => Message::Registered { worker: d.u64()? },
            TAG_PROGRESS => Message::Progress { summary: get_worker_summary(d)? },
            TAG_SHIP_BEGIN => Message::ShipBegin(get_chunk_begin(d)?),
            TAG_SHIP_CHUNK => Message::ShipChunk(get_chunk_part(d)?),
            TAG_SHIP_END => Message::ShipEnd { fingerprint: d.u64()? },
            got => return Err(WireError::BadTag { got }),
        })
    }

    /// Encode into one complete frame (length prefix included).
    ///
    /// Panics if the body exceeds [`MAX_FRAME`] — a silent `as u32` wrap
    /// of the length prefix would desync the stream and read as a peer
    /// failure. Paths that must stay alive across oversized payloads
    /// (the fleet's ship path, the worker's reply path) use
    /// [`try_encode`](Self::try_encode) and turn the failure into a
    /// typed frame instead.
    pub fn encode(&self) -> Vec<u8> {
        self.try_encode().unwrap_or_else(|e| {
            panic!("unframeable message (ship the dataset in a streamed form instead): {e}")
        })
    }

    /// [`encode`](Self::encode) with the oversize case as a typed
    /// [`WireError::Oversized`] instead of a panic.
    pub fn try_encode(&self) -> Result<Vec<u8>, WireError> {
        let mut e = Enc::new();
        // Length placeholder, patched below: one buffer end to end, no
        // second allocation-plus-memcpy of a potentially huge body.
        e.buf.extend_from_slice(&[0u8; 4]);
        e.u8(WIRE_VERSION);
        e.u8(self.tag());
        self.put_body(&mut e);
        let mut out = e.buf;
        let body_len = out.len() - 4;
        if body_len > MAX_FRAME {
            return Err(WireError::Oversized { len: body_len });
        }
        out[..4].copy_from_slice(&(body_len as u32).to_le_bytes());
        Ok(out)
    }

    /// Decode one frame from the front of `bytes`; returns the message
    /// and the number of bytes consumed. Never panics: every malformed
    /// input is a typed [`WireError`].
    pub fn decode(bytes: &[u8]) -> Result<(Message, usize), WireError> {
        if bytes.len() < 4 {
            return Err(WireError::Truncated { needed: 4, have: bytes.len() });
        }
        let len = u32::from_le_bytes(bytes[0..4].try_into().expect("4 bytes")) as usize;
        if len > MAX_FRAME {
            return Err(WireError::Oversized { len });
        }
        if len < 2 {
            return Err(WireError::Malformed("frame shorter than its header"));
        }
        if bytes.len() < 4 + len {
            return Err(WireError::Truncated { needed: 4 + len, have: bytes.len() });
        }
        let msg = Self::parse_body(&bytes[4..4 + len])?;
        Ok((msg, 4 + len))
    }

    fn parse_body(body: &[u8]) -> Result<Message, WireError> {
        let got = body[0];
        if got != WIRE_VERSION {
            return Err(WireError::BadVersion { got });
        }
        let tag = body[1];
        let mut d = Dec::new(&body[2..]);
        let msg = Self::get_body(tag, &mut d)?;
        d.finish()?;
        Ok(msg)
    }

    /// Write one frame (and flush — these are request/response sockets).
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(&self.encode())?;
        w.flush()
    }

    /// Blocking read of one frame; a connection closed *between* frames
    /// is `Ok(None)`, mid-frame it is [`WireError::Io`].
    pub fn read_opt<R: Read>(r: &mut R) -> Result<Option<Message>, WireError> {
        Ok(Self::read_opt_with_body(r)?.map(|(msg, _)| msg))
    }

    /// [`read_opt`](Self::read_opt), also handing back the raw frame
    /// body (`version ∥ tag ∥ payload`) that produced the message. The
    /// payload bytes ARE the canonical encoding, so a receiver can hash
    /// `body[2..]` for a dataset fingerprint without re-encoding
    /// anything — the buffer was allocated for the read regardless.
    pub fn read_opt_with_body<R: Read>(
        r: &mut R,
    ) -> Result<Option<(Message, Vec<u8>)>, WireError> {
        let mut len4 = [0u8; 4];
        let first = loop {
            match r.read(&mut len4[..1]) {
                Ok(n) => break n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(WireError::Io(e.to_string())),
            }
        };
        if first == 0 {
            return Ok(None);
        }
        let io = |e: std::io::Error| WireError::Io(e.to_string());
        r.read_exact(&mut len4[1..]).map_err(io)?;
        let len = u32::from_le_bytes(len4) as usize;
        if len > MAX_FRAME {
            return Err(WireError::Oversized { len });
        }
        if len < 2 {
            return Err(WireError::Malformed("frame shorter than its header"));
        }
        // Validate the 2-byte header *before* committing any payload
        // allocation: garbage from an arbitrary peer (the worker
        // listener is unauthenticated) must be rejected for the cost of
        // 6 bytes, not a length-prefix-sized buffer.
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr).map_err(io)?;
        if hdr[0] != WIRE_VERSION {
            return Err(WireError::BadVersion { got: hdr[0] });
        }
        if !(TAG_PING..=TAG_SHIP_END).contains(&hdr[1]) {
            return Err(WireError::BadTag { got: hdr[1] });
        }
        // Read the payload in bounded chunks: a peer that *claims* a
        // huge frame only costs memory as it actually delivers bytes.
        let mut body = Vec::with_capacity(len.min(1 << 24));
        body.extend_from_slice(&hdr);
        let mut remaining = len - 2;
        let mut chunk = [0u8; 16 * 1024];
        while remaining > 0 {
            let n = remaining.min(chunk.len());
            r.read_exact(&mut chunk[..n]).map_err(io)?;
            body.extend_from_slice(&chunk[..n]);
            remaining -= n;
        }
        let msg = Self::parse_body(&body)?;
        Ok(Some((msg, body)))
    }

    /// Blocking read of one frame; any close is an [`WireError::Io`]
    /// (use [`read_opt`](Self::read_opt) where clean close is expected).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Message, WireError> {
        match Self::read_opt(r)? {
            Some(m) => Ok(m),
            None => Err(WireError::Io("connection closed".to_string())),
        }
    }
}

/// 64-bit FNV-1a over a byte slice (the dataset fingerprint hash).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Message) -> Message {
        let frame = msg.encode();
        let (decoded, used) = Message::decode(&frame).expect("roundtrip decode");
        assert_eq!(used, frame.len(), "whole frame consumed");
        // Canonical-bytes equality is the strongest equality we can ask
        // for in the presence of NaNs.
        assert_eq!(decoded.encode(), frame, "re-encode is byte-identical");
        decoded
    }

    #[test]
    fn ping_pong_roundtrip() {
        match roundtrip(&Message::Ping { seq: 42 }) {
            Message::Ping { seq } => assert_eq!(seq, 42),
            other => panic!("wrong variant {other:?}"),
        }
        let summary = WorkerSummary {
            in_flight: 3,
            solves: 1234,
            uptime_ticks: 99,
            epoch: 4096,
            gap_bits: 1e-7f64.to_bits(),
        };
        match roundtrip(&Message::Pong { seq: u64::MAX, summary }) {
            Message::Pong { seq, summary: s } => {
                assert_eq!(seq, u64::MAX);
                assert_eq!(s, summary);
                assert_eq!(s.gap(), 1e-7);
            }
            other => panic!("wrong variant {other:?}"),
        }
        // The idle default reads as "no gap observed", not "converged".
        assert!(WorkerSummary::default().gap().is_nan());
    }

    #[test]
    fn register_and_progress_roundtrip() {
        match roundtrip(&Message::Register { addr: "10.0.0.7:7171".to_string() }) {
            Message::Register { addr } => assert_eq!(addr, "10.0.0.7:7171"),
            other => panic!("wrong variant {other:?}"),
        }
        match roundtrip(&Message::Registered { worker: 3 }) {
            Message::Registered { worker } => assert_eq!(worker, 3),
            other => panic!("wrong variant {other:?}"),
        }
        let summary = WorkerSummary {
            in_flight: 1,
            epoch: 250,
            gap_bits: 0.5f64.to_bits(),
            ..Default::default()
        };
        match roundtrip(&Message::Progress { summary }) {
            Message::Progress { summary: s } => assert_eq!(s, summary),
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn stats_frames_roundtrip() {
        match roundtrip(&Message::StatsRequest) {
            Message::StatsRequest => {}
            other => panic!("wrong variant {other:?}"),
        }
        let snap = MetricsSnapshot {
            counters: vec![("solves".to_string(), 17), ("shards".to_string(), u64::MAX)],
            gauges: vec![("in_flight".to_string(), 2.5), ("nan_gauge".to_string(), f64::NAN)],
            timers: vec![(
                "solve_s".to_string(),
                TimerStats { count: 3, sum: 1.5, min: 0.25, max: 1.0 },
                vec![(0, 1), (137, 2)],
            )],
        };
        let back = roundtrip(&Message::StatsReply(snap.clone()));
        let Message::StatsReply(rt) = back else { panic!("wrong variant") };
        assert_eq!(rt.counters, snap.counters);
        assert_eq!(rt.gauges[0], snap.gauges[0]);
        assert!(rt.gauges[1].1.is_nan(), "NaN gauge survives by bits");
        assert_eq!(rt.timers.len(), 1);
        let (name, stats, sparse) = &rt.timers[0];
        assert_eq!(name, "solve_s");
        assert_eq!(stats.count, 3);
        assert_eq!(sparse.len(), 2);
        assert_eq!(sparse[0], (0, 1));
        assert_eq!(sparse[1], (137, 2));
        // Empty registry is a valid (minimal) reply.
        roundtrip(&Message::StatsReply(MetricsSnapshot::default()));
    }

    #[test]
    fn stats_reply_fuzz_never_panics() {
        // Truncate a real StatsReply frame at every length: each cut is
        // a typed error, never a panic or a bogus success.
        let snap = MetricsSnapshot {
            counters: vec![("a".to_string(), 1)],
            gauges: vec![("g".to_string(), 0.5)],
            timers: vec![(
                "t".to_string(),
                TimerStats { count: 1, sum: 0.1, min: 0.1, max: 0.1 },
                vec![(4, 1)],
            )],
        };
        let frame = Message::StatsReply(snap).encode();
        for cut in 0..frame.len() {
            assert!(Message::decode(&frame[..cut]).is_err(), "cut {cut} must not decode");
        }
        // Corrupt every byte of the body in turn: decode may succeed
        // (some bytes are value payload) but must never panic, and a
        // corrupted length prefix must stay typed.
        for i in 4..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0xff;
            let _ = Message::decode(&bad);
        }
        // A huge claimed element count must be rejected before allocation.
        let mut huge = Message::StatsReply(MetricsSnapshot::default()).encode();
        // Body layout: [len4][ver][tag][counters len u64]... — blow up the count.
        huge[6..14].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(
            Message::decode(&huge),
            Err(WireError::Truncated { .. } | WireError::Malformed(_))
        ));
    }

    #[test]
    fn handoff_floats_survive_bit_exactly() {
        let snap = DualSnapshot {
            theta: vec![f64::NAN, -0.0, f64::INFINITY, f64::from_bits(1)],
            xt_theta: vec![f64::NEG_INFINITY, f64::MIN_POSITIVE / 2.0],
            dual_norm_xt_rho: f64::from_bits(0x7ff8_dead_beef_0001),
            theta_aug_sq: f64::from_bits(0x0000_0000_0000_0003),
            primal: 1.5,
            dual: -2.5,
            gap: 0.0,
            radius: f64::MAX,
        };
        let h = DualHandoff { lambda: 0.25, beta: vec![0.0, -0.0, 3.5e-310], snap };
        let msg = Message::SolveShard(ShardRequest {
            dataset: 7,
            datafit: WireDatafit::Quadratic { ridge: 0.5 },
            lambdas: vec![1.0, 0.5],
            solver: SolverKind::Fista,
            opts: PathOptions::default(),
            handoff: Some(h),
        });
        let back = roundtrip(&msg);
        let Message::SolveShard(req) = back else { panic!("wrong variant") };
        assert_eq!(req.datafit, WireDatafit::Quadratic { ridge: 0.5 });
        let h = req.handoff.expect("handoff survives");
        assert_eq!(h.beta[1].to_bits(), (-0.0f64).to_bits());
        assert!(h.snap.theta[0].is_nan());
        assert_eq!(
            h.snap.dual_norm_xt_rho.to_bits(),
            0x7ff8_dead_beef_0001,
            "NaN payload preserved"
        );
        assert_eq!(h.snap.theta_aug_sq.to_bits(), 3, "subnormal aug term preserved");
    }

    #[test]
    fn truncation_and_version_are_typed_errors() {
        let frame = Message::Ping { seq: 9 }.encode();
        for cut in 0..frame.len() {
            match Message::decode(&frame[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("cut {cut}: expected Truncated, got {other:?}"),
            }
        }
        let mut bad = frame.clone();
        bad[4] = WIRE_VERSION.wrapping_add(3);
        assert!(matches!(
            Message::decode(&bad),
            Err(WireError::BadVersion { got }) if got == WIRE_VERSION.wrapping_add(3)
        ));
        // A v1 peer (pre-datafit layout) must be rejected outright, not
        // have its body misread under the v2 field order.
        let mut v1 = frame.clone();
        v1[4] = 1;
        assert!(matches!(Message::decode(&v1), Err(WireError::BadVersion { got: 1 })));
        let mut badtag = frame.clone();
        badtag[5] = 250;
        assert!(matches!(Message::decode(&badtag), Err(WireError::BadTag { got: 250 })));
        // Trailing garbage inside the declared frame length.
        let mut long = frame;
        long[0] += 1; // lengthen the frame by one byte…
        long.push(0); // …and supply it
        assert!(matches!(Message::decode(&long), Err(WireError::Malformed(_))));
    }

    #[test]
    fn dataset_fingerprint_is_content_addressed() {
        let ds = WireDataset {
            design: WireDesign::Dense { n_rows: 2, n_cols: 2, data: vec![1.0, 2.0, 3.0, 4.0] },
            y: vec![0.5, -0.5],
            group_sizes: vec![1, 1],
            tau: 0.3,
            weights: vec![1.0, 1.0],
            datafit: WireDatafit::Quadratic { ridge: 0.0 },
        };
        assert_eq!(ds.fingerprint(), ds.clone().fingerprint());
        // Same (X, y), different datafit: different cache identity.
        let mut logit = ds.clone();
        logit.y = vec![0.5, 0.5]; // valid logistic labels
        logit.datafit = WireDatafit::Logistic;
        let mut quad = logit.clone();
        quad.datafit = WireDatafit::Quadratic { ridge: 0.0 };
        assert_ne!(logit.fingerprint(), quad.fingerprint());
        // The contract the worker relies on to avoid re-encoding: the
        // fingerprint equals FNV-1a over the frame's payload bytes
        // (after the 4-byte length, version and tag).
        let frame = Message::ShipDataset(ds.clone()).encode();
        assert_eq!(ds.fingerprint(), fnv1a64(&frame[6..]));
        let mut other = ds.clone();
        other.tau = 0.30000000000000004; // one ulp away: different bits
        assert_ne!(ds.fingerprint(), other.fingerprint());
        let back = roundtrip(&Message::ShipDataset(ds.clone()));
        let Message::ShipDataset(rt) = back else { panic!("wrong variant") };
        assert_eq!(rt.fingerprint(), ds.fingerprint());
        assert!(matches!(rt.into_problem(), Ok(ProblemPayload::Dense(_))));
    }

    #[test]
    fn malformed_datasets_are_typed_not_panics() {
        let base = WireDataset {
            design: WireDesign::Csc {
                n_rows: 3,
                n_cols: 2,
                indptr: vec![0, 1, 2],
                indices: vec![0, 5], // out of bounds
                values: vec![1.0, 2.0],
            },
            y: vec![0.0; 3],
            group_sizes: vec![2],
            tau: 0.5,
            weights: vec![1.0],
            datafit: WireDatafit::Quadratic { ridge: 0.0 },
        };
        assert!(matches!(base.clone().into_problem(), Err(WireError::Malformed(_))));
        let mut no_groups = base.clone();
        no_groups.group_sizes = vec![];
        assert!(matches!(no_groups.into_problem(), Err(WireError::Malformed(_))));
        let mut bad_tau = base.clone();
        bad_tau.tau = f64::NAN;
        assert!(matches!(bad_tau.into_problem(), Err(WireError::Malformed(_))));
        // Datafit parameters are validated before any constructor assert.
        let mut bad_ridge = base.clone();
        bad_ridge.datafit = WireDatafit::Quadratic { ridge: -1.0 };
        assert!(matches!(bad_ridge.into_problem(), Err(WireError::Malformed(_))));
        let mut nan_ridge = base.clone();
        nan_ridge.datafit = WireDatafit::Quadratic { ridge: f64::NAN };
        assert!(matches!(nan_ridge.into_problem(), Err(WireError::Malformed(_))));
        let mut bad_labels = base;
        bad_labels.datafit = WireDatafit::Logistic;
        bad_labels.y = vec![0.0, 1.0, 2.0]; // 2.0 outside [0, 1]
        assert!(matches!(bad_labels.into_problem(), Err(WireError::Malformed(_))));
    }

    #[test]
    fn logistic_dataset_roundtrips_with_its_datafit() {
        let ds = WireDataset {
            design: WireDesign::Dense {
                n_rows: 3,
                n_cols: 2,
                data: vec![1.0, -1.0, 0.5, 2.0, 0.0, -0.25],
            },
            y: vec![1.0, 0.0, 1.0],
            group_sizes: vec![2],
            tau: 0.4,
            weights: vec![2.0f64.sqrt()],
            datafit: WireDatafit::Logistic,
        };
        let back = roundtrip(&Message::ShipDataset(ds.clone()));
        let Message::ShipDataset(rt) = back else { panic!("wrong variant") };
        assert_eq!(rt.datafit, WireDatafit::Logistic);
        let ProblemPayload::DenseLogistic(pb) = rt.into_problem().expect("valid dataset")
        else {
            panic!("datafit lost in transit")
        };
        assert_eq!(pb.n(), 3);
        assert_eq!(pb.p(), 2);
    }

    #[test]
    fn zero_row_csc_dataset_roundtrips_and_builds() {
        let ds = WireDataset {
            design: WireDesign::Csc {
                n_rows: 0,
                n_cols: 3,
                indptr: vec![0, 0, 0, 0],
                indices: vec![],
                values: vec![],
            },
            y: vec![],
            group_sizes: vec![1, 2],
            tau: 0.4,
            weights: vec![1.0, 2.0f64.sqrt()],
            datafit: WireDatafit::Quadratic { ridge: 0.0 },
        };
        let back = roundtrip(&Message::ShipDataset(ds));
        let Message::ShipDataset(rt) = back else { panic!("wrong variant") };
        let ProblemPayload::Csc(pb) = rt.into_problem().expect("valid zero-row dataset") else {
            panic!("backend changed in transit")
        };
        assert_eq!(pb.n(), 0);
        assert_eq!(pb.p(), 3);
    }

    #[test]
    fn error_frames_roundtrip() {
        let back = roundtrip(&Message::Error(RemoteError {
            kind: RemoteErrorKind::UnknownDataset,
            detail: "dataset 00deadbeef not shipped".to_string(),
        }));
        let Message::Error(e) = back else { panic!("wrong variant") };
        assert_eq!(e.kind, RemoteErrorKind::UnknownDataset);
        assert!(e.detail.contains("deadbeef"));
    }

    #[test]
    fn reader_distinguishes_clean_close_from_mid_frame_close() {
        let frame = Message::Ping { seq: 1 }.encode();
        let mut whole: &[u8] = &frame;
        assert!(matches!(Message::read_opt(&mut whole), Ok(Some(Message::Ping { seq: 1 }))));
        let mut empty: &[u8] = &[];
        assert!(matches!(Message::read_opt(&mut empty), Ok(None)));
        let mut partial: &[u8] = &frame[..3];
        assert!(matches!(Message::read_opt(&mut partial), Err(WireError::Io(_))));
    }

    fn dense_ds(n_rows: usize, n_cols: usize) -> WireDataset {
        WireDataset {
            design: WireDesign::Dense {
                n_rows,
                n_cols,
                data: (0..n_rows * n_cols).map(|i| (i as f64).sin()).collect(),
            },
            y: (0..n_rows).map(|i| (i as f64).cos()).collect(),
            group_sizes: vec![n_cols as u64],
            tau: 0.3,
            weights: vec![(n_cols as f64).sqrt()],
            datafit: WireDatafit::Quadratic { ridge: 0.0 },
        }
    }

    fn csc_ds() -> WireDataset {
        // Deliberately ragged columns (including an empty one) so chunk
        // boundaries land on uneven nnz counts.
        WireDataset {
            design: WireDesign::Csc {
                n_rows: 4,
                n_cols: 5,
                indptr: vec![0, 2, 2, 5, 6, 8],
                indices: vec![0, 3, 0, 1, 2, 2, 1, 3],
                values: vec![1.0, -2.0, 0.5, 3.0, -0.25, 4.0, 7.0, -1.5],
            },
            y: vec![0.1, -0.2, 0.3, -0.4],
            group_sizes: vec![2, 3],
            tau: 0.6,
            weights: vec![2.0f64.sqrt(), 3.0f64.sqrt()],
            datafit: WireDatafit::MultiTask { tasks: 1 },
        }
    }

    #[test]
    fn wire_len_matches_encoded_body() {
        for ds in [dense_ds(3, 4), csc_ds()] {
            let frame = Message::ShipDataset(ds.clone()).encode();
            // Frame = 4-byte length + version + tag + dataset body.
            assert_eq!(ds.wire_len(), frame.len() - 6);
        }
    }

    #[test]
    fn chunked_ship_reassembles_bit_identically() {
        for ds in [dense_ds(3, 7), csc_ds()] {
            // A budget this small forces one-or-two-column chunks; each
            // chunk frame must individually survive the codec.
            let (begin, parts) = ds.to_chunks(64);
            assert!(parts.len() >= 3, "budget must force multiple chunks");
            let back = roundtrip(&Message::ShipBegin(begin.clone()));
            let Message::ShipBegin(begin) = back else { panic!("wrong variant") };
            let mut asm = ChunkAssembler::new(begin).expect("valid begin");
            for part in parts {
                let Message::ShipChunk(part) = roundtrip(&Message::ShipChunk(part)) else {
                    panic!("wrong variant")
                };
                asm.chunk(part).expect("in-order chunk accepted");
            }
            let rt = asm.finish(ds.fingerprint()).expect("assembly verifies");
            // Bit-identity: the assembled dataset re-encodes to the very
            // bytes a whole-frame ship would have produced.
            assert_eq!(Message::ShipDataset(rt).encode(), Message::ShipDataset(ds).encode());
        }
    }

    #[test]
    fn chunk_budget_smaller_than_one_column_still_ships() {
        // Every column of dense_ds(8, 3) needs 64 payload bytes; a
        // 1-byte budget must degrade to one column per chunk, never an
        // empty chunk or an infinite loop.
        let ds = dense_ds(8, 3);
        let (begin, parts) = ds.to_chunks(1);
        assert_eq!(parts.len(), 3);
        let mut asm = ChunkAssembler::new(begin).unwrap();
        for part in parts {
            asm.chunk(part).unwrap();
        }
        asm.finish(ds.fingerprint()).expect("assembly verifies");
    }

    #[test]
    fn chunk_assembler_rejects_protocol_abuse() {
        let ds = csc_ds();
        let (begin, parts) = ds.to_chunks(64);
        let fresh = || ChunkAssembler::new(begin.clone()).unwrap();

        // Wrong-ship chunk: fingerprint mismatch.
        let mut asm = fresh();
        let mut alien = parts[0].clone();
        alien.fingerprint ^= 1;
        assert!(matches!(asm.chunk(alien), Err(WireError::Malformed(_))));

        // Out-of-order / gap.
        let mut asm = fresh();
        assert!(matches!(asm.chunk(parts[1].clone()), Err(WireError::Malformed(_))));

        // Duplicate / overlap.
        let mut asm = fresh();
        asm.chunk(parts[0].clone()).unwrap();
        assert!(matches!(asm.chunk(parts[0].clone()), Err(WireError::Malformed(_))));

        // Payload kind not matching the declared design.
        let mut asm = fresh();
        let mut wrong_kind = parts[0].clone();
        wrong_kind.payload = ChunkPayload::Dense { data: vec![0.0; 8] };
        assert!(matches!(asm.chunk(wrong_kind), Err(WireError::Malformed(_))));

        // CSC indptr that does not continue the previous chunk.
        let mut asm = fresh();
        asm.chunk(parts[0].clone()).unwrap();
        let mut discontinuous = parts[1].clone();
        if let ChunkPayload::Csc { indptr, .. } = &mut discontinuous.payload {
            for v in indptr.iter_mut() {
                *v += 1;
            }
        }
        assert!(matches!(asm.chunk(discontinuous), Err(WireError::Malformed(_))));

        // Early seal: not every column delivered.
        let mut asm = fresh();
        asm.chunk(parts[0].clone()).unwrap();
        assert!(matches!(asm.finish(ds.fingerprint()), Err(WireError::Malformed(_))));

        // Seal fingerprint disagreeing with the opened ship.
        let mut asm = fresh();
        for part in parts.clone() {
            asm.chunk(part).unwrap();
        }
        assert!(matches!(asm.finish(ds.fingerprint() ^ 1), Err(WireError::Malformed(_))));

        // Declared fingerprint that the (complete) assembly fails to
        // hash to — a corrupted-in-flight ship must not be stored.
        let mut lying = begin.clone();
        lying.fingerprint ^= 1;
        let mut asm = ChunkAssembler::new(lying).unwrap();
        for mut part in parts {
            part.fingerprint ^= 1;
            asm.chunk(part).unwrap();
        }
        assert!(matches!(
            asm.finish(ds.fingerprint() ^ 1),
            Err(WireError::Malformed("assembled dataset does not hash to the declared fingerprint"))
        ));
    }
}
