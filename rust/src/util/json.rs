//! Tiny JSON emitter used by the report/metrics code (serde is unavailable
//! offline). Write-only: experiment outputs are JSON/CSV for downstream
//! plotting; configs are parsed by `config::toml` instead.

/// A JSON value that can be built programmatically and serialized.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a field (builder style).
    pub fn with(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val.into();
            } else {
                fields.push((key.to_string(), val.into()));
            }
        }
        self
    }

    pub fn array<T: Into<Json>>(items: Vec<T>) -> Json {
        Json::Arr(items.into_iter().map(Into::into).collect())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth + 1);
        let close_pad = "  ".repeat(depth);
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close_pad);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&close_pad);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn fmt_num(x: f64) -> String {
    if !x.is_finite() {
        // JSON has no inf/nan; emit null like most encoders.
        return "null".to_string();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(xs: Vec<f64>) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
        assert_eq!(Json::Bool(true).dump(), "true");
        assert_eq!(Json::Null.dump(), "null");
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::Str("a\"b\nc".to_string()).dump(), "\"a\\\"b\\nc\"");
    }

    #[test]
    fn object_builder() {
        let j = Json::obj().with("name", "sgl").with("n", 100usize).with("ok", true);
        assert_eq!(j.dump(), "{\"name\":\"sgl\",\"n\":100,\"ok\":true}");
    }

    #[test]
    fn with_overwrites() {
        let j = Json::obj().with("a", 1.0).with("a", 2.0);
        assert_eq!(j.dump(), "{\"a\":2}");
    }

    #[test]
    fn arrays_and_pretty() {
        let j = Json::obj().with("xs", vec![1.0, 2.5]);
        assert_eq!(j.dump(), "{\"xs\":[1,2.5]}");
        let p = j.pretty();
        assert!(p.contains("\"xs\": [\n"));
    }
}
